//! Quickstart: the smallest end-to-end use of the DeltaKWS public API.
//!
//! Loads (or trains, on first run) the ΔGRU weights, synthesises one "yes"
//! utterance, runs it through the full chip twin — fixed-point IIR FEx →
//! ΔRNN accelerator with near-V_TH SRAM — and prints the decision plus the
//! chip's headline telemetry (power, energy/decision, latency, sparsity).
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts` once, for training on first use)

use deltakws::chip::KwsChip;
use deltakws::config::RunConfig;
use deltakws::util::prng::Pcg;
use deltakws::{audio, exp, CLASS_LABELS};

fn main() -> anyhow::Result<()> {
    let cfg = RunConfig::default();

    // 1. weights: load results/weights.bin or train via PJRT on first run
    let params = exp::ensure_weights(&cfg)?;

    // 2. one synthetic "yes" utterance, quantised to the chip's 12-bit ADC
    let mut rng = Pcg::new(2024);
    let wave = audio::synth_utterance(11, &mut rng); // class 11 == "yes"
    let audio12 = audio::quantize_12b(&wave);

    // 3. the chip twin at the paper's design point (Δ_TH = 0.2, 10 channels)
    let mut chip = KwsChip::new(params, cfg.chip_config());
    let decision = chip.process_utterance(&audio12);

    println!("predicted keyword : {}", CLASS_LABELS[decision.class]);
    println!("frames processed  : {}", decision.frames);

    // 4. chip telemetry (the paper's Table II metrics)
    let report = chip.report();
    println!("power             : {:.2} µW (paper: 5.22 µW)", report.power.total_uw());
    println!(
        "energy/decision   : {:.1} nJ (paper: 36.11 nJ)",
        report.energy_per_decision_nj
    );
    println!("computing latency : {:.2} ms (paper: 6.9 ms)", report.latency_ms);
    println!(
        "temporal sparsity : {:.0}% combined ({:.0}% input deltas)",
        report.sparsity * 100.0,
        report.input_sparsity * 100.0
    );
    Ok(())
}

//! Always-on wakeword demo: the workload the chip was built for.
//!
//! Synthesises a minutes-long continuous track (background noise +
//! keywords and "unknown" fillers at known offsets), streams it through
//! the full detection pipeline — frame-incremental chip twin, energy VAD
//! clock-gating the ΔRNN between utterances, posterior smoothing +
//! wakeword state machine — in real-time-style chunks, and scores the
//! emitted detections against the ground-truth schedule: **miss rate**,
//! **false-accepts/hour** and **detection latency**, plus the energy story
//! (ΔRNN duty cycle, average power with and without VAD gating).
//!
//! Run: `cargo run --release --example wakeword -- [seconds] [keywords] [seed]`

use deltakws::audio::track::{synth_track, TrackConfig};
use deltakws::config::RunConfig;
use deltakws::exp;
use deltakws::stream::metrics::{score_track, DEFAULT_TOLERANCE_MS};
use deltakws::stream::vad::VadConfig;
use deltakws::stream::{StreamConfig, StreamPipeline};
use deltakws::CLASS_LABELS;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let duration_s: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(60);
    let keywords: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(20);
    let seed: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(42);
    let cfg = RunConfig::default();
    let params = exp::ensure_weights(&cfg)?;
    // validated chip configuration (serving API v2): out-of-range
    // channels/Δ_TH surface as a typed error instead of a silent no-op chip
    let chip_cfg = cfg.chip_config_checked()?;

    let tcfg = TrackConfig {
        duration_s,
        keywords,
        fillers: (keywords / 3).max(1),
        noise: (0.001, 0.003),
    };
    println!(
        "rendering a {duration_s} s track: {keywords} keywords + {} fillers (seed {seed})",
        tcfg.fillers
    );
    let (audio12, sched) = synth_track(&tcfg, seed);

    // stream in 32 ms chunks (256 samples), the way a host MCU would feed
    // the SPI front door
    let mut pipe =
        StreamPipeline::new(params.clone(), StreamConfig::for_chip(chip_cfg.clone()));
    let mut events = Vec::new();
    for chunk in audio12.chunks(256) {
        events.extend(pipe.push_audio(chunk).expect("32 ms chunks fit the frame buffer"));
    }

    let score = score_track(&sched, &events, pipe.samples_in, DEFAULT_TOLERANCE_MS);
    println!("\n== detection report ==");
    println!(
        "keywords   : {} scheduled, {} hit, {} missed  (miss rate {:.1}%)",
        score.keywords,
        score.hits,
        score.misses,
        score.miss_rate() * 100.0
    );
    println!(
        "false acc. : {} in {:.0} s  ({:.1}/hour)",
        score.false_accepts,
        score.duration_s,
        score.false_accepts_per_hour()
    );
    match score.median_latency_ms() {
        Some(l) => println!("latency    : median {l:.0} ms from keyword-window onset"),
        None => println!("latency    : n/a (no hits)"),
    }
    for ev in events.iter().take(8) {
        println!(
            "  t={:6.2} s  detected '{}' (onset frame {})",
            ev.time_ms() / 1e3,
            CLASS_LABELS[ev.class],
            ev.onset_frame
        );
    }
    if events.len() > 8 {
        println!("  ... {} more", events.len() - 8);
    }

    // energy story: VAD-gated vs always-on
    let gated_report = pipe.report();
    let gated_activity = pipe.chip.activity();
    let mut always_on = StreamPipeline::new(
        params,
        StreamConfig::for_chip(chip_cfg).with_vad(VadConfig::disabled()),
    );
    for chunk in audio12.chunks(256) {
        always_on.push_audio(chunk).expect("32 ms chunks fit the frame buffer");
    }
    let on_report = always_on.report();
    println!("\n== always-on energy ==");
    println!(
        "ΔRNN duty cycle : {:.1}%  ({} of {} frames clock-gated by the VAD)",
        pipe.duty_cycle() * 100.0,
        gated_activity.gated_frames,
        gated_activity.frames
    );
    println!(
        "avg chip power  : {:.2} µW gated   vs {:.2} µW always-on  ({:.1}% saved)",
        gated_report.power.total_uw(),
        on_report.power.total_uw(),
        (1.0 - gated_report.power.total_uw() / on_report.power.total_uw()) * 100.0
    );
    println!(
        "sparsity        : {:.1}% lane-level within speech (gated frames excluded)",
        gated_report.sparsity * 100.0
    );
    Ok(())
}

//! Sustained-load soak run: the coordinator under minutes of simulated
//! audio, with the telemetry guarantees checked live.
//!
//! Runs the acceptance workload (≥50k mixed utterance/stream jobs across
//! ≥4 workers) twice: once with the pre-refactor telemetry cost emulated
//! alongside (global mutex push + per-completion float rollup at the
//! pool's completion rate — the baseline), once clean. Prints sustained
//! decisions/sec for both, the histogram-vs-exact percentile cross-check,
//! and the flat-memory proof. The clean number is the throughput baseline
//! later scaling PRs are judged against (README "Soak throughput" table).
//!
//! Since the serving API v2, the harness runs entirely on the ticket
//! surface: each producer thread submits through its own `Client` and
//! claims its own completions — the exact-percentile cross-check doubles
//! as a mailbox-isolation check at soak scale.
//!
//! Weights are deterministic-random: load characteristics (frame counts,
//! cycle counts, queueing) do not depend on model quality.
//!
//! Run: `cargo run --release --example soak -- [workers] [utterances] [producers] [streams]`
//!
//! Scale mode (the v3 scheduler's 10k–100k-session proof):
//!   `cargo run --release --example soak -- scale smoke`     — CI cell (2k sessions)
//!   `cargo run --release --example soak -- scale matrix`    — 10k / 50k / 100k cells
//!   `cargo run --release --example soak -- scale <sessions>`— one custom cell
//! Each cell asserts flat memory, parking coverage, typed shedding and
//! bit-exactness internally; the results land in `results/soak_scale.json`
//! for `tools/bench_report.py` to baseline-diff as the `scheduler` block.

use deltakws::accel::gru::QuantParams;
use deltakws::chip::ChipConfig;
use deltakws::coordinator::soak::{
    run_scale_soak, run_soak, ScaleSoakConfig, ScaleSoakReport, SoakConfig, SoakReport,
};
use deltakws::obs::MetricsSnapshot;
use deltakws::util::json::Json;
use deltakws::util::prng::Pcg;

fn rng_quant(seed: u64) -> QuantParams {
    let mut rng = Pcg::new(seed);
    let mut q = QuantParams::zeroed();
    q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
    q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q
}

fn print_report(label: &str, r: &SoakReport) {
    println!("\n== soak: {label} ==");
    println!(
        "load       : {} utterances + {} stream chunks ({:.0} s simulated audio) in {:.2} s wall",
        r.utterances_done,
        r.chunks_done,
        r.simulated_audio_s,
        r.wall.as_secs_f64()
    );
    println!("throughput : {:.0} decisions/s sustained", r.decisions_per_sec);
    println!(
        "latency    : p50 {:.2} ms / p99 {:.2} ms (histogram)  vs  {:.2} / {:.2} ms exact — {:.2}% off",
        r.p50_us as f64 / 1e3,
        r.p99_us as f64 / 1e3,
        r.exact_p50_us as f64 / 1e3,
        r.exact_p99_us as f64 / 1e3,
        r.percentile_rel_err() * 100.0
    );
    println!(
        "telemetry  : {} B at 10% of run, {} B at end (flat ✓); {} producer retries; \
         {} steals; {} backpressure rejections",
        r.telemetry_bytes_early,
        r.telemetry_bytes_final,
        r.producer_retries,
        r.final_stats.steals,
        r.final_stats.rejected_full
    );
    println!(
        "sessions   : {} B live pipeline state at the 10% checkpoint, {} B after close (bounded ✓)",
        r.session_bytes_early, r.session_bytes_final
    );
    println!(
        "chip       : {:.1}% temporal sparsity, {:.1}% ΔRNN duty cycle over {} frames",
        r.final_stats.activity.sparsity() * 100.0,
        r.final_stats.activity.duty_cycle() * 100.0,
        r.final_stats.activity.frames
    );
    println!(
        "steady     : {:.0} decisions/s / {:.0} chunks/s over the warmed-up window ({:.1} s)",
        r.steady.decisions_per_sec(),
        r.steady.chunks_per_sec(),
        r.steady.elapsed_us as f64 / 1e6
    );
}

fn print_scale_report(r: &ScaleSoakReport) {
    println!("\n== scale soak: {} sessions ==", r.sessions);
    println!(
        "shape      : {} workers, {} active sessions ({:.0} sessions/core), \
         {} rounds x {} chunks in {:.2} s wall",
        r.workers,
        r.active_sessions,
        r.sessions_per_core,
        r.rounds,
        r.chunks_done,
        r.wall.as_secs_f64()
    );
    println!(
        "parking    : {} parked at the quiesced checkpoint; {} park transitions; {} steals",
        r.parked_at_checkpoint, r.park_transitions, r.steals
    );
    println!(
        "memory     : {} B session state early vs {} B late (flat ✓); {} B telemetry",
        r.session_bytes_early, r.session_bytes_late, r.telemetry_bytes
    );
    println!(
        "latency    : chunk p50 {:.2} ms / p99 {:.2} ms; sched p50 {} µs / p99 {} µs",
        r.chunk_p50_us as f64 / 1e3,
        r.chunk_p99_us as f64 / 1e3,
        r.sched_p50_us,
        r.sched_p99_us
    );
    println!(
        "contracts  : {} typed Overloaded sheds; {} oracle utterances bit-exact; \
         {} witness detections bit-exact",
        r.shed_overloaded, r.oracle_checked, r.witness_detections
    );
}

fn scale_cell_json(r: &ScaleSoakReport) -> Json {
    Json::obj(vec![
        ("sessions", Json::num(r.sessions as f64)),
        ("active_sessions", Json::num(r.active_sessions as f64)),
        ("workers", Json::num(r.workers as f64)),
        ("sessions_per_core", Json::num(r.sessions_per_core)),
        ("chunks_done", Json::num(r.chunks_done as f64)),
        ("wall_s", Json::num(r.wall.as_secs_f64())),
        ("parked_at_checkpoint", Json::num(r.parked_at_checkpoint as f64)),
        ("session_bytes_early", Json::num(r.session_bytes_early as f64)),
        ("session_bytes_late", Json::num(r.session_bytes_late as f64)),
        ("chunk_p50_us", Json::num(r.chunk_p50_us as f64)),
        ("chunk_p99_us", Json::num(r.chunk_p99_us as f64)),
        ("sched_p50_us", Json::num(r.sched_p50_us as f64)),
        ("sched_p99_us", Json::num(r.sched_p99_us as f64)),
        ("steals", Json::num(r.steals as f64)),
        ("park_transitions", Json::num(r.park_transitions as f64)),
        ("shed_overloaded", Json::num(r.shed_overloaded as f64)),
        ("oracle_checked", Json::num(r.oracle_checked as f64)),
        ("witness_detections", Json::num(r.witness_detections as f64)),
        (
            "chunks_per_sec",
            Json::num(r.chunks_done as f64 / r.wall.as_secs_f64().max(1e-9)),
        ),
    ])
}

/// `soak -- scale [smoke|matrix|<sessions>]`: run scale-soak cells and
/// write the machine-readable artifact CI and bench_report.py consume.
fn run_scale_mode(arg: Option<&str>) {
    let cells: Vec<ScaleSoakConfig> = match arg {
        None | Some("smoke") => vec![ScaleSoakConfig::smoke()],
        Some("matrix") => ScaleSoakConfig::matrix().to_vec(),
        Some(n) => {
            let sessions: usize = n.parse().unwrap_or_else(|_| {
                panic!("scale mode takes `smoke`, `matrix` or a session count, got {n:?}")
            });
            vec![ScaleSoakConfig::with_sessions(sessions)]
        }
    };
    let mut reports = Vec::with_capacity(cells.len());
    for cfg in &cells {
        println!(
            "scale soak: {} sessions ({}% idle), {} workers, {} rounds",
            cfg.sessions, cfg.idle_pct, cfg.workers, cfg.rounds
        );
        let r = run_scale_soak(rng_quant(7), ChipConfig::design_point(), cfg);
        print_scale_report(&r);
        reports.push(r);
    }
    let doc = Json::obj(vec![
        ("schema", Json::str("deltakws-soak-scale/1")),
        ("cells", Json::arr(reports.iter().map(scale_cell_json))),
    ]);
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/soak_scale.json", format!("{doc}\n"))
        .expect("write scale soak json");
    println!("\nscale soak results -> results/soak_scale.json");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(String::as_str) == Some("scale") {
        run_scale_mode(args.get(1).map(String::as_str));
        return;
    }
    let mut cfg = SoakConfig::acceptance();
    if let Some(v) = args.first().and_then(|s| s.parse().ok()) {
        cfg.workers = v;
    }
    if let Some(v) = args.get(1).and_then(|s| s.parse().ok()) {
        cfg.utterances = v;
    }
    if let Some(v) = args.get(2).and_then(|s| s.parse().ok()) {
        cfg.producers = v;
    }
    if let Some(v) = args.get(3).and_then(|s| s.parse().ok()) {
        cfg.streams = v;
    }
    println!(
        "soak: {} workers, {} producers, {} utterances, {} streams x {} chunks",
        cfg.workers, cfg.producers, cfg.utterances, cfg.streams, cfg.chunks_per_stream
    );

    // A: pre-refactor telemetry cost emulated alongside (baseline)
    let mut legacy_cfg = cfg.clone();
    legacy_cfg.emulate_legacy_telemetry = true;
    let baseline = run_soak(rng_quant(7), ChipConfig::design_point(), &legacy_cfg);
    print_report("emulated legacy telemetry (baseline)", &baseline);

    // B: sharded telemetry only (the refactored serving spine)
    let sharded = run_soak(rng_quant(7), ChipConfig::design_point(), &cfg);
    print_report("sharded telemetry", &sharded);

    println!(
        "\nsharded vs baseline: {:.0} vs {:.0} decisions/s ({:+.1}%)",
        sharded.decisions_per_sec,
        baseline.decisions_per_sec,
        (sharded.decisions_per_sec / baseline.decisions_per_sec - 1.0) * 100.0
    );
    assert!(
        sharded.percentile_rel_err() <= 0.05,
        "histogram percentiles drifted past 5% of exact"
    );

    // exposition artifact: the clean run's final stats as a schema-stable
    // metrics snapshot (CI validates it with
    // `tools/bench_report.py --validate-metrics`, and bench_report.py
    // ingests it into the BENCH_<n>.json report)
    let snap = MetricsSnapshot::from_stats(&sharded.final_stats);
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/soak_metrics.json", format!("{}\n", snap.to_json()))
        .expect("write soak metrics json");
    std::fs::write("results/soak_metrics.prom", snap.to_prometheus())
        .expect("write soak metrics prom");
    println!("metrics snapshot -> results/soak_metrics.json / results/soak_metrics.prom");
}

//! Streaming serving demo: the coordinator routing live audio streams to a
//! pool of chip-twin workers (the paper's host + many-chips deployment).
//!
//! Eight logical microphone streams submit utterances concurrently from
//! multiple *producer threads*, each holding a cloned [`Client`] handle —
//! exercising the concurrent submission path end-to-end. The router pins
//! streams to workers (state locality), spills around stalls, and applies
//! backpressure when saturated; producers retry with backoff and stop
//! cleanly if the pool disappears. Prints throughput, wall-clock latency
//! percentiles, online accuracy, spill/retry/rejection counts (global and
//! per worker) and aggregated chip telemetry.
//!
//! Run: `cargo run --release --example streaming_serve -- [workers] [requests] [producers]`

use std::time::{Duration, Instant};

use deltakws::config::RunConfig;
use deltakws::coordinator::{Coordinator, Request};
use deltakws::dataset::{Dataset, Split};
use deltakws::exp;

/// Logical microphone streams the demo simulates.
const STREAMS: usize = 8;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let workers: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    // at most one producer per stream, so each stream has a single writer
    let producers: usize =
        args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4).clamp(1, STREAMS);
    let cfg = RunConfig::default();

    let params = exp::ensure_weights(&cfg)?;
    println!(
        "spawning {workers} chip workers; {producers} producer threads serving \
         {requests} requests over {STREAMS} streams"
    );
    let coord = Coordinator::new(params, cfg.chip_config(), workers, 16);
    let ds = Dataset::new(cfg.seed);

    let t0 = Instant::now();
    // each producer thread owns a cloned Client handle and a disjoint set
    // of *streams* (stream s belongs to producer s % producers), so every
    // stream has exactly one writer and sees its requests in submission
    // order regardless of the producer count
    let mut producer_handles = Vec::with_capacity(producers);
    for p in 0..producers {
        let client = coord.client();
        let ds = ds.clone();
        producer_handles.push(std::thread::spawn(move || {
            let mut retries = 0u64;
            let mut submitted = 0u64;
            for i in (0..requests).filter(|i| (i % STREAMS) % producers == p) {
                let utt = ds.utterance(Split::Test, i);
                let mut req = Request {
                    id: 0,
                    stream: (i % STREAMS) as u64,
                    audio12: utt.audio12,
                    label: Some(utt.label),
                };
                // bounded-backoff retry on backpressure; bail out if the
                // pool is gone (Client::is_closed tells the two apart)
                loop {
                    match client.submit(req) {
                        Ok(_) => {
                            submitted += 1;
                            break;
                        }
                        Err(r) => {
                            if client.is_closed() {
                                return (submitted, retries);
                            }
                            retries += 1;
                            req = r;
                            std::thread::sleep(Duration::from_millis(2));
                        }
                    }
                }
            }
            (submitted, retries)
        }));
    }
    // collect concurrently with the producers (the response channel is
    // bounded; draining it is what keeps the workers moving)
    let responses = coord.collect(requests, Duration::from_secs(600));
    let wall = t0.elapsed();
    let (mut submitted, mut retries) = (0u64, 0u64);
    for h in producer_handles {
        let (s, r) = h.join().expect("producer thread panicked");
        submitted += s;
        retries += r;
    }

    let stats = coord.stats();
    println!("\n== serving report ==");
    println!(
        "throughput : {:.1} utterances/s  ({} served of {submitted} submitted in {:.2}s)",
        responses.len() as f64 / wall.as_secs_f64(),
        responses.len(),
        wall.as_secs_f64()
    );
    // `stats.rejected` counts saturated submit *attempts*; the producers
    // retried every one of them, so none of these are dropped requests
    println!(
        "routing    : {} spills; {} submit attempts hit global backpressure \
         ({retries} producer retries, all eventually accepted)",
        stats.spilled, stats.rejected
    );
    println!(
        "latency    : p50 {:.1} ms   p99 {:.1} ms  (wall-clock, queue + simulation)",
        stats.p50_us() as f64 / 1e3,
        stats.p99_us() as f64 / 1e3
    );
    println!("accuracy   : {:.1}% online", stats.accuracy() * 100.0);
    println!(
        "chip       : {:.1}% temporal sparsity over {} frames",
        stats.activity.sparsity() * 100.0,
        stats.activity.frames
    );
    // per-worker routing + chip telemetry
    let reports = coord.reports();
    for (w, lane) in stats.per_worker.iter().enumerate() {
        let chip = reports
            .get(&w)
            .map(|rep| {
                format!(
                    "{:.2} µW, {:.1} nJ/dec, {:.2} ms",
                    rep.power.total_uw(),
                    rep.energy_per_decision_nj,
                    rep.latency_ms
                )
            })
            .unwrap_or_else(|| "idle".into());
        println!(
            "worker {w}: {} completed, {} spilled-in, {} pinned-full, {chip}",
            lane.completed, lane.spilled_in, lane.pinned_full
        );
    }
    // per-stream ordering check (ids are assigned at submission; spills
    // can reorder service, pinned streams stay ordered)
    let mut by_stream: std::collections::HashMap<u64, Vec<u64>> = Default::default();
    for r in &responses {
        by_stream.entry(r.stream).or_default().push(r.id);
    }
    let ordered = by_stream.values().all(|ids| ids.windows(2).all(|w| w[0] < w[1]));
    println!(
        "stream ordering preserved: {ordered}{}",
        if stats.spilled > 0 { "  (spills may reorder)" } else { "" }
    );
    Ok(())
}

//! Streaming serving demo: the coordinator routing live audio streams to a
//! pool of chip-twin workers (the paper's host + many-chips deployment).
//!
//! Eight logical microphone streams submit utterances concurrently; the
//! router pins streams to workers (state locality), spills around stalls,
//! and applies backpressure when saturated. Prints throughput, wall-clock
//! latency percentiles, online accuracy and aggregated chip telemetry.
//!
//! Run: `cargo run --release --example streaming_serve -- [workers] [requests]`

use std::time::{Duration, Instant};

use deltakws::config::RunConfig;
use deltakws::coordinator::{Coordinator, Request};
use deltakws::dataset::{Dataset, Split};
use deltakws::exp;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let workers: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let cfg = RunConfig::default();

    let params = exp::ensure_weights(&cfg)?;
    println!("spawning {workers} chip workers, serving {requests} requests over 8 streams");
    let coord = Coordinator::new(params, cfg.chip_config(), workers, 16);
    let ds = Dataset::new(cfg.seed);

    let t0 = Instant::now();
    let mut submitted = 0usize;
    let mut retries = 0usize;
    for i in 0..requests {
        let utt = ds.utterance(Split::Test, i);
        let mut req = Request {
            id: 0,
            stream: (i % 8) as u64,
            audio12: utt.audio12,
            label: Some(utt.label),
        };
        // bounded retry on backpressure
        loop {
            match coord.submit(req) {
                Ok(_) => {
                    submitted += 1;
                    break;
                }
                Err(r) => {
                    retries += 1;
                    req = r;
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
        }
    }
    let responses = coord.collect(submitted, Duration::from_secs(600));
    let wall = t0.elapsed();

    let stats = coord.stats();
    println!("\n== serving report ==");
    println!(
        "throughput : {:.1} utterances/s  ({} served in {:.2}s, {retries} backpressure retries)",
        responses.len() as f64 / wall.as_secs_f64(),
        responses.len(),
        wall.as_secs_f64()
    );
    println!(
        "latency    : p50 {:.1} ms   p99 {:.1} ms  (wall-clock, queue + simulation)",
        stats.p50_us() as f64 / 1e3,
        stats.p99_us() as f64 / 1e3
    );
    println!("accuracy   : {:.1}% online", stats.accuracy() * 100.0);
    println!(
        "chip       : {:.1}% temporal sparsity over {} frames",
        stats.activity.sparsity() * 100.0,
        stats.activity.frames
    );
    // per-worker chip telemetry
    for (w, rep) in coord.reports() {
        println!(
            "worker {w}: {:.2} µW, {:.1} nJ/dec, {:.2} ms latency (last request)",
            rep.power.total_uw(),
            rep.energy_per_decision_nj,
            rep.latency_ms
        );
    }
    // per-stream ordering check
    let mut by_stream: std::collections::HashMap<u64, Vec<u64>> = Default::default();
    for r in &responses {
        by_stream.entry(r.stream).or_default().push(r.id);
    }
    let ordered = by_stream.values().all(|ids| ids.windows(2).all(|w| w[0] < w[1]));
    println!("stream ordering preserved: {ordered}");
    Ok(())
}

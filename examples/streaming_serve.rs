//! Streaming serving demo: the coordinator routing live audio streams to a
//! pool of chip-twin workers (the paper's host + many-chips deployment).
//!
//! Eight logical microphone streams submit utterances concurrently from
//! multiple *producer threads*, each holding its own [`Client`] handle with
//! its own completion mailbox — exercising the v2 ticket surface
//! end-to-end: every producer claims exactly its own responses (routed by
//! request id), with zero cross-producer interleaving by construction.
//! The v3 scheduler runs every stream's utterances as chained runnables
//! on a work-stealing pool — any worker may serve any request, yet each
//! stream's chain keeps its requests in submission order (`stream_seq`).
//! Saturation applies backpressure; producers retry on typed
//! [`SubmitError::QueueFull`] and stop cleanly on [`SubmitError::Closed`].
//! Prints throughput, wall-clock latency percentiles, online accuracy,
//! steal/retry/rejection counts (global and per worker) and aggregated
//! chip telemetry.
//!
//! Run: `cargo run --release --example streaming_serve -- [workers] [requests] [producers]`

use std::time::{Duration, Instant};

use deltakws::config::RunConfig;
use deltakws::coordinator::{Coordinator, Request, Response, Ticket};
use deltakws::dataset::{Dataset, Split};
use deltakws::exp;
use deltakws::SubmitError;

/// Logical microphone streams the demo simulates.
const STREAMS: usize = 8;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let workers: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    // at most one producer per stream, so each stream has a single writer
    let producers: usize =
        args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4).clamp(1, STREAMS);
    let cfg = RunConfig::default();

    let params = exp::ensure_weights(&cfg)?;
    println!(
        "spawning {workers} chip workers; {producers} producer threads serving \
         {requests} requests over {STREAMS} streams"
    );
    let coord = Coordinator::builder(params, cfg.chip_config_checked()?)
        .workers(workers)
        .queue_depth(16)
        .build()?;
    let ds = Dataset::new(cfg.seed);

    let t0 = Instant::now();
    // each producer thread owns its own Client handle (own mailbox) and a
    // disjoint set of *streams* (stream s belongs to producer s % producers),
    // so every stream has exactly one writer and sees its requests in
    // submission order regardless of the producer count
    let mut producer_handles = Vec::with_capacity(producers);
    for p in 0..producers {
        let client = coord.client();
        let ds = ds.clone();
        producer_handles.push(std::thread::spawn(move || {
            let mut retries = 0u64;
            let mut tickets: Vec<Ticket> = Vec::new();
            // fixed-backoff retry on typed backpressure; stop submitting
            // once the pool reports itself Closed, but keep the tickets
            // already accepted — their responses may have been delivered
            // before the shutdown and are still claimable below
            'submit: for i in (0..requests).filter(|i| (i % STREAMS) % producers == p) {
                let utt = ds.utterance(Split::Test, i);
                let mut req = Request {
                    id: 0,
                    stream: (i % STREAMS) as u64,
                    audio12: utt.audio12,
                    label: Some(utt.label),
                    trace: false,
                    weights: None,
                };
                loop {
                    match client.submit(req) {
                        Ok(t) => {
                            tickets.push(t);
                            break;
                        }
                        Err(SubmitError::QueueFull(r)) => {
                            retries += 1;
                            req = r;
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break 'submit,
                    }
                }
            }
            let submitted = tickets.len() as u64;
            // claim this producer's own responses — nobody else can
            let deadline = Instant::now() + Duration::from_secs(600);
            let mut responses: Vec<Response> = Vec::with_capacity(tickets.len());
            for t in tickets {
                let remaining = deadline.saturating_duration_since(Instant::now());
                match t.wait_timeout(remaining) {
                    Ok(r) => responses.push(r),
                    Err(e) => {
                        eprintln!("producer {p}: lost a response: {e}");
                        break;
                    }
                }
            }
            (responses, submitted, retries)
        }));
    }
    let mut responses: Vec<Response> = Vec::with_capacity(requests);
    let mut submitted = 0u64;
    let mut retries = 0u64;
    for h in producer_handles {
        let (rs, s, r) = h.join().expect("producer thread panicked");
        submitted += s;
        retries += r;
        responses.extend(rs);
    }
    let wall = t0.elapsed();

    let stats = coord.stats();
    println!("\n== serving report ==");
    println!(
        "throughput : {:.1} utterances/s  ({} served of {submitted} submitted in {:.2}s)",
        responses.len() as f64 / wall.as_secs_f64(),
        responses.len(),
        wall.as_secs_f64()
    );
    // `rejected_full` counts saturated submit *attempts*; the producers
    // retried every one of them, so none of these are dropped requests
    println!(
        "routing    : {} steals; {} submit attempts hit global backpressure \
         ({retries} producer retries, all eventually accepted); {} shutdown rejections",
        stats.steals, stats.rejected_full, stats.rejected_closed
    );
    println!(
        "latency    : p50 {:.1} ms   p99 {:.1} ms  (wall-clock, queue + simulation)",
        stats.p50_us() as f64 / 1e3,
        stats.p99_us() as f64 / 1e3
    );
    println!("accuracy   : {:.1}% online", stats.accuracy() * 100.0);
    println!(
        "chip       : {:.1}% temporal sparsity over {} frames",
        stats.activity.sparsity() * 100.0,
        stats.activity.frames
    );
    // per-worker routing + chip telemetry
    let reports = coord.reports();
    for (w, lane) in stats.per_worker.iter().enumerate() {
        let chip = reports
            .get(&w)
            .map(|rep| {
                format!(
                    "{:.2} µW, {:.1} nJ/dec, {:.2} ms",
                    rep.power.total_uw(),
                    rep.energy_per_decision_nj,
                    rep.latency_ms
                )
            })
            .unwrap_or_else(|| "idle".into());
        println!(
            "worker {w}: {} completed, {} stolen, {} stream chunks, {chip}",
            lane.completed, lane.steals, lane.stream_chunks
        );
    }
    // per-stream ordering check: the v3 chain serializes each stream's
    // requests with a dense `stream_seq`, so service order must match
    // submission order (ascending ids) no matter which workers — or how
    // many — ended up serving the chain.
    let mut by_stream: std::collections::HashMap<u64, Vec<&Response>> = Default::default();
    for r in &responses {
        by_stream.entry(r.stream).or_default().push(r);
    }
    let ordered = by_stream.values_mut().all(|rs| {
        rs.sort_by_key(|r| r.stream_seq);
        rs.windows(2).all(|w| w[0].id < w[1].id)
    });
    assert!(ordered, "stream_seq order diverged from submission order");
    println!("stream ordering preserved: {ordered}  (holds across worker migration)");
    Ok(())
}

//! Chip explorer: poke at the twin's internals the way a bring-up engineer
//! probes silicon — feature maps, per-frame firing activity, SRAM bank
//! utilisation, and the column-MUX timing under injected clock skew.
//!
//! Run: `cargo run --release --example chip_explorer -- [keyword]`

use deltakws::chip::KwsChip;
use deltakws::config::RunConfig;
use deltakws::sram::timing::{q_offsets_from_falling_edge, TimingParams};
use deltakws::util::prng::Pcg;
use deltakws::{audio, exp, CLASS_LABELS};

fn main() -> anyhow::Result<()> {
    let keyword = std::env::args().nth(1).unwrap_or_else(|| "stop".into());
    let class = CLASS_LABELS
        .iter()
        .position(|&c| c == keyword)
        .ok_or_else(|| anyhow::anyhow!("unknown keyword '{keyword}' (try: {CLASS_LABELS:?})"))?;
    let cfg = RunConfig::default();
    let params = exp::ensure_weights(&cfg)?;

    let mut rng = Pcg::new(7);
    let wave = audio::synth_utterance(class, &mut rng);
    let audio12 = audio::quantize_12b(&wave);

    let mut chip = KwsChip::new(params, cfg.chip_config());
    // the explorer is exactly what the TraceProbe path exists for: full
    // per-frame diagnostics, paid for only when somebody asks
    let (d, trace) = chip.process_utterance_traced(&audio12);
    println!("'{keyword}' -> predicted '{}'\n", CLASS_LABELS[d.class]);

    // --- feature heat map (ASCII) -----------------------------------------
    println!("IIR feature map (rows = active channels 4..13, cols = frames, darker = louder):");
    let glyphs = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    for ch in (4..14).rev() {
        let mut row = String::with_capacity(64);
        for f in &trace.feat_trace {
            let v = (f[ch] as usize * (glyphs.len() - 1)) / 4095;
            row.push(glyphs[v.min(glyphs.len() - 1)]);
        }
        println!("  ch{ch:>2} |{row}|");
    }

    // --- per-frame firing / latency ----------------------------------------
    println!("\nper-frame fired lanes (of 74) and compute latency:");
    let spark: Vec<char> = "▁▂▃▄▅▆▇█".chars().collect();
    let max_fired = *trace.frame_fired.iter().max().unwrap_or(&1) as f64;
    let line: String = trace
        .frame_fired
        .iter()
        .map(|&f| spark[((f as f64 / max_fired) * (spark.len() - 1) as f64) as usize])
        .collect();
    println!("  fired |{line}|");
    let ms: Vec<f64> =
        trace.frame_cycles.iter().map(|&c| c as f64 / 125_000.0 * 1e3).collect();
    println!(
        "  latency: min {:.2} ms, mean {:.2} ms, max {:.2} ms",
        ms.iter().cloned().fold(f64::MAX, f64::min),
        ms.iter().sum::<f64>() / ms.len() as f64,
        ms.iter().cloned().fold(0.0, f64::max)
    );

    // --- SRAM bank utilisation ----------------------------------------------
    println!("\nSRAM bank reads (12 banks x 2 kB):");
    let total: u64 = chip.accel.sram.bank_reads.iter().sum();
    for (b, &r) in chip.accel.sram.bank_reads.iter().enumerate() {
        let bar = "#".repeat((r * 40 / total.max(1)) as usize);
        println!("  bank {b:>2} |{bar:<40}| {r}");
    }

    // --- column-MUX timing under skew ---------------------------------------
    println!("\nPCHCMX timing: Q-refresh offset from the falling clock edge:");
    for skew in [-400.0, 0.0, 400.0] {
        let p = TimingParams { skew_ns: skew, ..Default::default() };
        let worst = q_offsets_from_falling_edge(&p, 3)
            .iter()
            .fold(0.0f64, |m, &o| m.max(o.abs()));
        println!("  skew {skew:>6.0} ns -> |offset| {worst:.2} ns (skew-resistant)");
    }

    // --- report -------------------------------------------------------------
    let rep = chip.report();
    println!(
        "\nreport: {:.2} µW | {:.1} nJ/dec | {:.2} ms | sparsity {:.0}%",
        rep.power.total_uw(),
        rep.energy_per_decision_nj,
        rep.latency_ms,
        rep.sparsity * 100.0
    );
    Ok(())
}

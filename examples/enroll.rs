//! Few-shot enrollment + mid-stream weight-swap smoke (PR 9).
//!
//! Exercises the whole customization surface end to end and emits the
//! numbers `tools/bench_report.py` ingests into the BENCH_<n>.json
//! trajectory (`results/enroll_metrics.json`, schema deltakws-enroll/1):
//!
//! * enroll a synthetic speaker against a deterministic-random base model
//!   (FC head only, K ≤ 8 shots) and time it per optimisation step;
//! * check the held-out effect: chip-twin accuracy on the speaker's
//!   unseen clips of the target keyword, base vs enrolled;
//! * open a live stream, install the enrolled version mid-stream through
//!   the epoch fence, and confirm the `WeightsSwapped` acknowledgement
//!   (timing the swap request — registry pin + fence submission);
//! * print the registry state (resident versions, lineage).
//!
//! Run: `cargo run --release --example enroll -- [shots] [steps]`

use std::time::Instant;

use deltakws::accel::gru::QuantParams;
use deltakws::chip::{ChipConfig, KwsChip};
use deltakws::coordinator::{Coordinator, StreamEvent};
use deltakws::custom::{EnrollConfig, SpeakerVoice};
use deltakws::util::json::Json;
use deltakws::util::prng::Pcg;

const SPEAKER: u64 = 7;
const TARGET: usize = 11;
const HOLDOUT: usize = 12;

fn rng_quant(seed: u64) -> QuantParams {
    let mut rng = Pcg::new(seed);
    let mut q = QuantParams::zeroed();
    q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
    q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q
}

/// Chip-twin accuracy on the speaker's held-out clips of the target
/// keyword (indices disjoint from every enrollment shot).
fn holdout_accuracy(params: &QuantParams, cfg: &ChipConfig, voice: &SpeakerVoice) -> f64 {
    let mut chip = KwsChip::new(params.clone(), cfg.clone());
    let hits = voice
        .holdout(TARGET, HOLDOUT)
        .iter()
        .filter(|u| chip.process_utterance(&u.audio12).class == TARGET)
        .count();
    hits as f64 / HOLDOUT as f64
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = EnrollConfig::design_point(SPEAKER, TARGET);
    if let Some(v) = args.first().and_then(|s| s.parse().ok()) {
        cfg.shots = v;
    }
    if let Some(v) = args.get(1).and_then(|s| s.parse().ok()) {
        cfg.steps = v;
    }

    let chip_cfg = ChipConfig::design_point();
    let coord = Coordinator::builder(rng_quant(7), chip_cfg.clone())
        .workers(2)
        .build()
        .expect("valid pool");
    let voice = SpeakerVoice::new(SPEAKER);

    // -- enroll ----------------------------------------------------------
    println!(
        "enrolling speaker {SPEAKER} on '{}': {} shots + {} counters, {} steps",
        deltakws::CLASS_LABELS[TARGET],
        cfg.shots,
        cfg.counter_shots,
        cfg.steps
    );
    let out = coord.enroll(None, cfg.clone()).expect("enrollment");
    let us_per_step = out.latency_us as f64 / out.steps as f64;
    println!(
        "  version {} (parent {}), {} steps in {:.1} ms ({:.0} us/step), final loss {:.4}",
        out.version,
        out.parent,
        out.steps,
        out.latency_us as f64 / 1e3,
        us_per_step,
        out.final_loss
    );

    // -- held-out effect -------------------------------------------------
    let base = coord.registry().get(coord.base_version()).expect("base resident");
    let enrolled = coord.registry().get(out.version).expect("enrolled resident");
    let base_acc = holdout_accuracy(&base, &chip_cfg, &voice);
    let enrolled_acc = holdout_accuracy(&enrolled, &chip_cfg, &voice);
    println!(
        "  held-out '{}' accuracy ({} unseen clips): base {:.0}% -> enrolled {:.0}%",
        deltakws::CLASS_LABELS[TARGET],
        HOLDOUT,
        base_acc * 100.0,
        enrolled_acc * 100.0
    );

    // -- mid-stream swap through the epoch fence -------------------------
    let utt = voice.utterance(TARGET, deltakws::custom::speaker::HOLDOUT_BASE + HOLDOUT);
    let sess = coord.open_stream(1).expect("under the high-water mark");
    let half = utt.audio12.len() / 2;
    sess.push_blocking(utt.audio12[..half].to_vec()).expect("pool alive");
    let t_swap = Instant::now();
    coord.swap_weights(&sess, out.version).expect("swap accepted");
    let swap_latency_us = t_swap.elapsed().as_micros() as u64;
    sess.push_blocking(utt.audio12[half..].to_vec()).expect("pool alive");
    let events = sess.close();
    let fence = events.iter().find_map(|e| match e {
        StreamEvent::WeightsSwapped { version, frame, .. } => Some((*version, *frame)),
        _ => None,
    });
    let (fence_version, fence_frame) = fence.expect("swap acknowledged");
    assert_eq!(fence_version, out.version, "fence installed the wrong version");
    let closed_frames = events
        .iter()
        .find_map(|e| match e {
            StreamEvent::Closed { frames, .. } => Some(*frames),
            _ => None,
        })
        .expect("close event");
    println!(
        "  mid-stream swap: request {swap_latency_us} us, fence at frame {fence_frame}/{closed_frames}, zero drops"
    );

    let stats = coord.stats();
    println!(
        "  registry: {} resident versions, {} swaps served, enroll p50 {:.1} ms",
        stats.resident_versions,
        stats.weight_swaps,
        stats.enroll_latency.percentile(0.50) as f64 / 1e3
    );

    // -- artifact for bench_report.py ------------------------------------
    let doc = Json::obj(vec![
        ("schema", Json::str("deltakws-enroll/1")),
        ("speaker", Json::num(SPEAKER as f64)),
        ("target", Json::num(TARGET as f64)),
        ("shots", Json::num(cfg.shots as f64)),
        ("steps", Json::num(out.steps as f64)),
        ("enroll_us", Json::num(out.latency_us as f64)),
        ("us_per_step", Json::num(us_per_step)),
        ("swap_latency_us", Json::num(swap_latency_us as f64)),
        ("fence_frame", Json::num(fence_frame as f64)),
        ("base_accuracy", Json::num(base_acc)),
        ("enrolled_accuracy", Json::num(enrolled_acc)),
        ("final_loss", Json::num(out.final_loss as f64)),
        ("version", Json::str(out.version.to_string())),
        ("parent", Json::str(out.parent.to_string())),
    ]);
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/enroll_metrics.json", format!("{doc}\n"))
        .expect("write enroll metrics");
    println!("enroll metrics -> results/enroll_metrics.json");
}

//! End-to-end driver: train → quantise → deploy → sweep. Proves all three
//! layers compose (recorded in EXPERIMENTS.md §End-to-end).
//!
//! 1. **L1/L2 (build time, already done by `make artifacts`)**: the ΔGRU
//!    forward (Pallas delta_matvec kernel) and the delta-aware `train_step`
//!    were AOT-lowered from JAX to HLO text.
//! 2. **L3 (this binary)**: renders a synthetic-GSCD corpus, featurises it
//!    with the *fixed-point FEx twin*, runs a few hundred `train_step`s
//!    through the execution backend (native by default; PJRT with
//!    `--features pjrt` + artifacts) while logging the loss curve, evaluates
//!    the float model, quantises to the chip's int8/Q8.8 formats, and
//!    finally sweeps Δ_TH on the bit-accurate chip twin — reproducing the
//!    paper's Fig. 12 trade-off on a freshly trained model.
//!
//! Run: `cargo run --release --example train_kws`
//! Flags: `-- [steps] [eval_utts]` (defaults 300, 192)

use deltakws::chip::ChipConfig;
use deltakws::config::RunConfig;
use deltakws::dataset::{Dataset, Split};
use deltakws::exp;
use deltakws::fex::FexConfig;
use deltakws::runtime;
use deltakws::train::{save_weights, Trainer};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let eval_utts: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(192);
    let cfg = RunConfig::default();

    // ---- L3 hosts the training loop; compute runs via the backend --------
    let backend = runtime::backend_for(&cfg.artifacts)?;
    println!("execution backend: {} | artifacts: {}", backend.name(), cfg.artifacts);
    // featurise with the deployed channel selection (train/deploy match)
    let train_ds = Dataset::with_fex(cfg.seed, FexConfig::design_point());
    let mut trainer = Trainer::new(backend, train_ds, cfg.batch, cfg.train_delta_th)?;
    let mut state = trainer.init_state(cfg.seed);

    println!("== phase 1: training ({steps} steps, batch {}) ==", cfg.batch);
    let t0 = std::time::Instant::now();
    trainer.fit(&mut state, steps, true)?;
    let train_wall = t0.elapsed();
    println!(
        "trained in {:.1}s ({:.2} s/step incl. featurisation)",
        train_wall.as_secs_f64(),
        train_wall.as_secs_f64() / steps as f64
    );

    // loss curve for EXPERIMENTS.md
    std::fs::create_dir_all("results")?;
    let mut csv = String::from("step,loss\n");
    for l in &trainer.log {
        csv.push_str(&format!("{},{}\n", l.step, l.loss));
    }
    std::fs::write("results/loss_curve.csv", &csv)?;
    let first = trainer.log.first().map(|l| l.loss).unwrap_or(f32::NAN);
    let last = trainer.log.last().map(|l| l.loss).unwrap_or(f32::NAN);
    println!("loss: {first:.3} -> {last:.3}  (results/loss_curve.csv)");

    println!("\n== phase 2: float evaluation (backend batched forward) ==");
    for th in [0.0f32, 0.1, 0.2] {
        let (acc, sp) = trainer.evaluate(&state, Split::Test, 128, th)?;
        println!("  Δ_TH={th:.1}: accuracy {:.1}%  sparsity {:.1}%", acc * 100.0, sp * 100.0);
    }

    println!("\n== phase 3: quantise + deploy to the chip twin ==");
    let quant = trainer.export(&state);
    save_weights(std::path::Path::new(&cfg.weights), &quant)?;
    println!("int8/Q8.8 weight image -> {}", cfg.weights);

    println!("\n== phase 4: Δ_TH sweep on the bit-accurate chip (Fig. 12) ==");
    println!(
        "{:>6} {:>8} {:>10} {:>9} {:>9} {:>9}",
        "Δ_TH", "acc12%", "E/dec nJ", "lat ms", "spars%", "P µW"
    );
    let eval_ds = Dataset::with_fex(cfg.seed, ChipConfig::design_point().fex.clone());
    for th in [0i16, 26, 51, 77, 102] {
        let chip_cfg = ChipConfig::design_point().with_delta_th(th);
        let (acc12, _a11, rep) = exp::chip_accuracy(&quant, &chip_cfg, &eval_ds, eval_utts);
        println!(
            "{:>6.2} {:>8.1} {:>10.2} {:>9.2} {:>9.1} {:>9.2}",
            th as f64 / 256.0,
            acc12 * 100.0,
            rep.energy_per_decision_nj,
            rep.latency_ms,
            rep.sparsity * 100.0,
            rep.power.total_uw()
        );
    }
    println!("\npaper anchors: Δ=0 -> 121.2 nJ / 16.4 ms; Δ=0.2 -> 36.11 nJ / 6.9 ms / 87% sparsity");
    println!("done — see EXPERIMENTS.md §End-to-end for the recorded run.");
    Ok(())
}

#!/usr/bin/env python3
"""Regenerate the checked-in golden vectors for rust/tests/golden_vectors.rs.

The golden paths are *pure integer arithmetic* (the whole point of the
bit-accurate twin), so this script reproduces them exactly, independent of
the Rust implementation: PCG-XSH-RR 64/32, the fixed-point DF-I biquad with
round-half-away-from-zero shifts and saturation, the leaky-integrator
envelope with floor shift, the priority-encoder log2, and the ΔEncoder.

Run `python3 tools/gen_goldens.py` and paste the printed arrays into
rust/tests/golden_vectors.rs if the modelled hardware ever changes
(a deliberate, reviewed event — that is what makes these regression tests).
"""

M64 = (1 << 64) - 1


class Pcg:
    """PCG-XSH-RR 64/32, bit-exact mirror of rust/src/util/prng.rs."""

    def __init__(self, seed, stream=0xDA3E39CB94B95BDB):
        self.state = 0
        self.inc = ((stream << 1) | 1) & M64
        self.next_u32()
        self.state = (self.state + seed) & M64
        self.next_u32()

    def next_u32(self):
        old = self.state
        self.state = (old * 6364136223846793005 + self.inc) & M64
        xorshifted = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
        rot = old >> 59
        return ((xorshifted >> rot) | (xorshifted << ((-rot) & 31))) & 0xFFFFFFFF


# ---------------------------------------------------------------------------
# fixed-point primitives (rust/src/fixed/mod.rs)
# ---------------------------------------------------------------------------


def sat(v, bits):
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    return max(lo, min(hi, v))


def round_shift(v, sh):
    if sh == 0:
        return v
    half = 1 << (sh - 1)
    if v >= 0:
        return (v + half) >> sh
    return -((-v + half) >> sh)


def log2_linear(v, frac_bits):
    assert v > 0
    p = v.bit_length() - 1
    mant = v - (1 << p)
    if p >= frac_bits:
        frac = mant >> (p - frac_bits)
    else:
        frac = mant << (frac_bits - p)
    return (p << frac_bits) + frac


def log_compress(env_q15):
    v = (1 << 15) + (env_q15 << 12)
    log_q12 = log2_linear(v, 12) - (15 << 12)
    feat = (log_q12 * 2731) >> 15
    return min(feat, 4095)


# ---------------------------------------------------------------------------
# FEx channel pipeline golden (biquad cascade + envelope + log compression)
# ---------------------------------------------------------------------------

# hand-picked quantised coefficients (Q0.11 b, Q1.6 a), strictly stable:
# |a1| = 91/64 = 1.422 < 1 + a2 = 1.828, a2 = 53/64 = 0.828 < 1
B0, A1, A2 = 150, -91, 53
QB_FRAC, QA_FRAC = 11, 6


class FixedBiquad:
    def __init__(self):
        self.x1 = self.x2 = self.y1 = self.y2 = 0
        self.b0, self.a1, self.a2 = B0, A1, A2

    def step(self, x):
        xd = x - self.x2
        num = xd * self.b0
        rec = self.y1 * self.a1 + self.y2 * self.a2
        acc = sat(round_shift(num, QB_FRAC) - round_shift(rec, QA_FRAC), 32)
        y = sat(acc, 16)
        self.x2, self.x1 = self.x1, x
        self.y2, self.y1 = self.y1, y
        return y


def fex_channel_golden():
    rng = Pcg(0xFE0)
    s0, s1 = FixedBiquad(), FixedBiquad()
    env = 0
    feats = []
    for n in range(8000):
        x12 = (rng.next_u32() >> 20) - 2048  # deterministic 12-bit noise
        x = x12 << 4  # Q1.11 -> Q1.15
        y = s1.step(s0.step(x))
        env += (abs(y) - env) >> 5  # Envelope::step (floor shift)
        if (n + 1) % 128 == 0:
            feats.append(log_compress(env))
    return feats  # 62 frames


# ---------------------------------------------------------------------------
# ΔEncoder golden (rust/src/accel/encoder.rs)
# ---------------------------------------------------------------------------


def encoder_golden():
    rng = Pcg(0xDE17A)
    refs = [0] * 16
    th = 20
    fired_total = 0
    h = 0
    first_events = []
    for _ in range(40):
        cur = [rng.next_u32() % 512 for _ in range(16)]
        for lane in range(16):
            d = cur[lane] - refs[lane]
            if d != 0 and abs(d) >= th:
                refs[lane] = cur[lane]
                fired_total += 1
                if len(first_events) < 8:
                    first_events.append((lane, d))
                h = (h * 1000003 + (lane * 100000 + (d + 70000))) & M64
    return fired_total, h, first_events


def fmt(xs, per_line=10):
    lines = []
    for i in range(0, len(xs), per_line):
        lines.append(", ".join(str(v) for v in xs[i : i + per_line]))
    return ",\n    ".join(lines)


if __name__ == "__main__":
    feats = fex_channel_golden()
    print(f"// FEx channel golden ({len(feats)} frames):")
    print(f"const FEX_GOLDEN: [i64; {len(feats)}] = [\n    {fmt(feats)},\n];")
    fired, h, first = encoder_golden()
    print(f"\n// encoder golden: fired_total={fired} hash=0x{h:016x}")
    print(f"const ENC_FIRED_TOTAL: usize = {fired};")
    print(f"const ENC_HASH: u64 = 0x{h:016x};")
    print(f"const ENC_FIRST_EVENTS: [(u16, i32); {len(first)}] = {first!r};")

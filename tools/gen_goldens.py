#!/usr/bin/env python3
"""Regenerate the checked-in golden vectors for rust/tests/golden_vectors.rs.

The golden paths are *pure integer arithmetic* (the whole point of the
bit-accurate twin), so this script reproduces them exactly, independent of
the Rust implementation: PCG-XSH-RR 64/32, the fixed-point DF-I biquad with
round-half-away-from-zero shifts and saturation, the leaky-integrator
envelope with floor shift, the priority-encoder log2, and the ΔEncoder.

Run `python3 tools/gen_goldens.py` and paste the printed arrays into
rust/tests/golden_vectors.rs if the modelled hardware ever changes
(a deliberate, reviewed event — that is what makes these regression tests).
"""

M64 = (1 << 64) - 1


class Pcg:
    """PCG-XSH-RR 64/32, bit-exact mirror of rust/src/util/prng.rs."""

    def __init__(self, seed, stream=0xDA3E39CB94B95BDB):
        self.state = 0
        self.inc = ((stream << 1) | 1) & M64
        self.next_u32()
        self.state = (self.state + seed) & M64
        self.next_u32()

    def next_u32(self):
        old = self.state
        self.state = (old * 6364136223846793005 + self.inc) & M64
        xorshifted = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
        rot = old >> 59
        return ((xorshifted >> rot) | (xorshifted << ((-rot) & 31))) & 0xFFFFFFFF

    def next_u64(self):
        return (self.next_u32() << 32) | self.next_u32()

    def below(self, n):
        """Unbiased uniform in [0, n) via rejection (mirror of prng.rs)."""
        assert n > 0
        zone = M64 - (M64 % n)
        while True:
            v = self.next_u64()
            if v < zone:
                return v % n


# ---------------------------------------------------------------------------
# fixed-point primitives (rust/src/fixed/mod.rs)
# ---------------------------------------------------------------------------


def sat(v, bits):
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    return max(lo, min(hi, v))


def round_shift(v, sh):
    if sh == 0:
        return v
    half = 1 << (sh - 1)
    if v >= 0:
        return (v + half) >> sh
    return -((-v + half) >> sh)


def log2_linear(v, frac_bits):
    assert v > 0
    p = v.bit_length() - 1
    mant = v - (1 << p)
    if p >= frac_bits:
        frac = mant >> (p - frac_bits)
    else:
        frac = mant << (frac_bits - p)
    return (p << frac_bits) + frac


def log_compress(env_q15):
    v = (1 << 15) + (env_q15 << 12)
    log_q12 = log2_linear(v, 12) - (15 << 12)
    feat = (log_q12 * 2731) >> 15
    return min(feat, 4095)


# ---------------------------------------------------------------------------
# FEx channel pipeline golden (biquad cascade + envelope + log compression)
# ---------------------------------------------------------------------------

# hand-picked quantised coefficients (Q0.11 b, Q1.6 a), strictly stable:
# |a1| = 91/64 = 1.422 < 1 + a2 = 1.828, a2 = 53/64 = 0.828 < 1
B0, A1, A2 = 150, -91, 53
QB_FRAC, QA_FRAC = 11, 6


class FixedBiquad:
    def __init__(self):
        self.x1 = self.x2 = self.y1 = self.y2 = 0
        self.b0, self.a1, self.a2 = B0, A1, A2

    def step(self, x):
        xd = x - self.x2
        num = xd * self.b0
        rec = self.y1 * self.a1 + self.y2 * self.a2
        acc = sat(round_shift(num, QB_FRAC) - round_shift(rec, QA_FRAC), 32)
        y = sat(acc, 16)
        self.x2, self.x1 = self.x1, x
        self.y2, self.y1 = self.y1, y
        return y


def fex_channel_golden():
    rng = Pcg(0xFE0)
    s0, s1 = FixedBiquad(), FixedBiquad()
    env = 0
    feats = []
    for n in range(8000):
        x12 = (rng.next_u32() >> 20) - 2048  # deterministic 12-bit noise
        x = x12 << 4  # Q1.11 -> Q1.15
        y = s1.step(s0.step(x))
        env += (abs(y) - env) >> 5  # Envelope::step (floor shift)
        if (n + 1) % 128 == 0:
            feats.append(log_compress(env))
    return feats  # 62 frames


# ---------------------------------------------------------------------------
# ΔEncoder golden (rust/src/accel/encoder.rs)
# ---------------------------------------------------------------------------


def encoder_golden():
    rng = Pcg(0xDE17A)
    refs = [0] * 16
    th = 20
    fired_total = 0
    h = 0
    first_events = []
    for _ in range(40):
        cur = [rng.next_u32() % 512 for _ in range(16)]
        for lane in range(16):
            d = cur[lane] - refs[lane]
            if d != 0 and abs(d) >= th:
                refs[lane] = cur[lane]
                fired_total += 1
                if len(first_events) < 8:
                    first_events.append((lane, d))
                h = (h * 1000003 + (lane * 100000 + (d + 70000))) & M64
    return fired_total, h, first_events


# ---------------------------------------------------------------------------
# Long-form track schedule golden (rust/src/audio/track.rs::schedule)
# ---------------------------------------------------------------------------

TRACK_SCHED_STREAM = 0x7363_6865_6475_6C65  # "schedule"
SAMPLE_RATE = 8000
UTT_SAMPLES = 8000
NUM_CLASSES = 12


def track_schedule_golden(duration_s=60, keywords=20, fillers=6, seed=0x517EAD):
    """Integer-exact mirror of audio::track::schedule at the design point."""
    n = keywords + fillers
    total = duration_s * SAMPLE_RATE
    assert n * UTT_SAMPLES <= total
    span = total // n
    jitter = span - UTT_SAMPLES
    filler_every = n // fillers if fillers > 0 else 0
    rng = Pcg(seed, TRACK_SCHED_STREAM)
    out = []
    placed = 0
    for i in range(n):
        is_filler = filler_every > 0 and placed < fillers and (i + 1) % filler_every == 0
        if is_filler:
            placed += 1
            cls = 1
        else:
            cls = 2 + rng.below(NUM_CLASSES - 2)
        onset = i * span + (rng.below(jitter) if jitter > 0 else 0)
        out.append((cls, onset))
    return out


# ---------------------------------------------------------------------------
# Wakeword detector golden (rust/src/stream/detector.rs)
# ---------------------------------------------------------------------------


class Detector:
    """Integer-exact mirror of stream::detector::Detector."""

    FIRST_KEYWORD_CLASS = 2

    def __init__(self, window, margin_q, on_frames, refractory_frames):
        self.cfg_window = window
        self.margin_q = margin_q
        self.on_frames = on_frames
        self.refractory_frames = refractory_frames
        self.window = []
        self.sums = [0] * NUM_CLASSES
        self.run_class = NUM_CLASSES
        self.run_len = 0
        self.run_start = 0
        self.refractory = 0

    def _flush(self):
        self.window = []
        self.sums = [0] * NUM_CLASSES

    def _disarm(self):
        self.run_class = NUM_CLASSES
        self.run_len = 0

    def step(self, index, logits, gated):
        if gated:
            self._flush()
            self._disarm()
            if self.refractory > 0:
                self.refractory -= 1
            return None
        self.window.append(list(logits))
        for k in range(NUM_CLASSES):
            self.sums[k] += logits[k]
        if len(self.window) > self.cfg_window:
            old = self.window.pop(0)
            for k in range(NUM_CLASSES):
                self.sums[k] -= old[k]
        if self.refractory > 0:
            self.refractory -= 1
            self._disarm()
            return None
        if len(self.window) < self.cfg_window:
            return None
        best = 0
        for k in range(1, NUM_CLASSES):
            if self.sums[k] > self.sums[best]:
                best = k
        second = None
        for k in range(NUM_CLASSES):
            if k != best and (second is None or self.sums[k] > second):
                second = self.sums[k]
        margin = self.sums[best] - second
        if best < self.FIRST_KEYWORD_CLASS or margin < self.margin_q:
            self._disarm()
            return None
        if best == self.run_class:
            self.run_len += 1
        else:
            self.run_class = best
            self.run_len = 1
            self.run_start = index
        if self.run_len < self.on_frames:
            return None
        ev = (best, index, self.run_start, margin)
        self.refractory = self.refractory_frames
        self._disarm()
        self._flush()
        return ev


def detector_golden():
    """Drive the detector mirror with a PCG logit stream (two keyword
    bursts, one VAD-gated gap) and return the emitted events."""
    det = Detector(window=8, margin_q=120_000, on_frames=3, refractory_frames=25)
    rng = Pcg(0xDE7EC7)
    events = []
    for t in range(200):
        logits = [rng.below(2000) for _ in range(NUM_CLASSES)]
        if 40 <= t < 80:
            logits[5] += 50_000
        if 120 <= t < 160:
            logits[9] += 50_000
        gated = 90 <= t < 100
        ev = det.step(t, logits, gated)
        if ev is not None:
            events.append(ev)
    return events


def fmt(xs, per_line=10):
    lines = []
    for i in range(0, len(xs), per_line):
        lines.append(", ".join(str(v) for v in xs[i : i + per_line]))
    return ",\n    ".join(lines)


if __name__ == "__main__":
    feats = fex_channel_golden()
    print(f"// FEx channel golden ({len(feats)} frames):")
    print(f"const FEX_GOLDEN: [i64; {len(feats)}] = [\n    {fmt(feats)},\n];")
    fired, h, first = encoder_golden()
    print(f"\n// encoder golden: fired_total={fired} hash=0x{h:016x}")
    print(f"const ENC_FIRED_TOTAL: usize = {fired};")
    print(f"const ENC_HASH: u64 = 0x{h:016x};")
    print(f"const ENC_FIRST_EVENTS: [(u16, i32); {len(first)}] = {first!r};")
    sched = track_schedule_golden()
    print(f"\n// track schedule golden (60 s, 20 keywords + 6 fillers, seed 0x517EAD):")
    print(f"const TRACK_GOLDEN: [(usize, usize); {len(sched)}] = [")
    for cls, onset in sched:
        print(f"    ({cls}, {onset}),")
    print("];")
    dets = detector_golden()
    print(f"\n// detector golden (window 8, margin 120000, on 3, refractory 25):")
    print(f"const DETECTOR_GOLDEN: [(usize, u64, u64, i64); {len(dets)}] = [")
    for cls, frame, onset, margin in dets:
        print(f"    ({cls}, {frame}, {onset}, {margin}),")
    print("];")

#!/usr/bin/env python3
"""Run the bench suite in smoke mode and emit BENCH_5.json.

The first point on the repo's bench trajectory (ISSUE 5 satellite): runs
`hotpath_bench` (probed-vs-unprobed frame path) and `soak_bench`
(sustained decisions/sec) with DELTAKWS_BENCH_SMOKE=1 + DELTAKWS_BENCH_JSON=1,
parses the machine-readable `results/bench.jsonl` the in-crate harness
appends, and folds the numbers relevant to the probe-layer refactor into
one JSON artifact:

  {
    "frames_per_sec": {"lean": ..., "traced": ...},   # consume+decide layer
    "probe_overhead_x": {...},                         # traced/lean per case
    "utterance_frames_per_sec": {...},
    "soak_decisions_per_sec": ...,
    "cases": {bench: {case: mean_ns}}
  }

Intended for CI (non-blocking step, artifact upload) and local use:

  python3 tools/bench_report.py --out BENCH_5.json
  python3 tools/bench_report.py --skip-build   # parse an existing jsonl
"""

import argparse
import json
import os
import re
import subprocess
import sys

BENCHES = ["hotpath_bench", "soak_bench"]
# cargo runs bench binaries with cwd set to the package root (rust/), so
# the harness's results/bench.jsonl lands there when invoked from the
# repo root; accept either location (newest wins)
JSONL_CANDIDATES = [
    os.path.join("rust", "results", "bench.jsonl"),
    os.path.join("results", "bench.jsonl"),
]


def find_jsonl():
    existing = [p for p in JSONL_CANDIDATES if os.path.exists(p)]
    if not existing:
        return None
    return max(existing, key=os.path.getmtime)


def run_benches():
    env = dict(os.environ)
    env["DELTAKWS_BENCH_SMOKE"] = "1"
    env["DELTAKWS_BENCH_JSON"] = "1"
    for bench in BENCHES:
        print(f"== running {bench} (smoke mode) ==", flush=True)
        subprocess.run(
            ["cargo", "bench", "--bench", bench],
            env=env,
            check=True,
        )


def parse_jsonl(path):
    cases = {}
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            cases.setdefault(rec["bench"], {})[rec["case"]] = rec["mean_ns"]
    return cases


def frames_per_sec(mean_ns, frames_per_iter):
    return frames_per_iter / (mean_ns * 1e-9) if mean_ns else None


def build_report(cases):
    hot = cases.get("hotpath (probe A/B)", {})
    soak = cases.get("soak", {})

    def ratio(traced_label, lean_label):
        a, b = hot.get(traced_label), hot.get(lean_label)
        return round(a / b, 3) if a and b else None

    report = {
        "schema": "deltakws-bench-report/1",
        "suite": "smoke",
        "cases": cases,
        # the consume+decide layer the probe refactor moved off the
        # default path: lean accumulator vs per-decision trace
        "frames_per_sec": {
            "lean": frames_per_sec(
                hot.get("frame consume+decide, lean accumulator"), 62.0
            ),
            "traced": frames_per_sec(
                hot.get("frame consume+decide, traced (per-decision trace)"), 62.0
            ),
        },
        "utterance_frames_per_sec": {
            "lean": frames_per_sec(hot.get("utterance decode, lean (NoProbe)"), 62.0),
            "traced": frames_per_sec(
                hot.get("utterance decode, traced (TraceProbe)"), 62.0
            ),
        },
        "probe_overhead_x": {
            "utterance_decode": ratio(
                "utterance decode, traced (TraceProbe)",
                "utterance decode, lean (NoProbe)",
            ),
            "sparse_accel_frames": ratio(
                "accel.step_frame sparse, traced", "accel.step_frame sparse, lean"
            ),
            "frame_consume_decide": ratio(
                "frame consume+decide, traced (per-decision trace)",
                "frame consume+decide, lean accumulator",
            ),
        },
    }
    lean = report["frames_per_sec"]["lean"]
    traced = report["frames_per_sec"]["traced"]
    if lean and traced:
        report["lean_speedup_x"] = round(lean / traced, 3)

    # soak decisions/sec: the micro-soak case label embeds its utterance
    # count ("micro soak: 150 utterances, ...") and times one whole run
    for label, mean_ns in soak.items():
        m = re.match(r"micro soak: (\d+) utterances", label)
        if m and mean_ns:
            report["soak_decisions_per_sec"] = round(
                int(m.group(1)) / (mean_ns * 1e-9), 1
            )
            break
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_5.json", help="output JSON path")
    ap.add_argument(
        "--skip-build",
        action="store_true",
        help="parse an existing results/bench.jsonl instead of running cargo bench",
    )
    args = ap.parse_args()

    if not args.skip_build:
        # start from a clean slate so stale lines don't pollute the report
        for path in JSONL_CANDIDATES:
            if os.path.exists(path):
                os.remove(path)
        run_benches()

    jsonl = find_jsonl()
    if jsonl is None:
        print(
            f"error: none of {JSONL_CANDIDATES} found (did the benches run?)",
            file=sys.stderr,
        )
        return 1

    report = build_report(parse_jsonl(jsonl))
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")
    ratios = report.get("probe_overhead_x", {})
    print(f"probe overhead (traced/lean): {ratios}")
    if "lean_speedup_x" in report:
        print(f"lean consume+decide speedup: {report['lean_speedup_x']}x")
    if "soak_decisions_per_sec" in report:
        print(f"soak decisions/sec: {report['soak_decisions_per_sec']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python3
"""Run the bench suite in smoke mode and emit BENCH_<N>.json.

One point on the repo's bench trajectory per PR (`ls BENCH_*.json` shows
the history). Runs `hotpath_bench` (probed-vs-unprobed frame path +
scalar/simd/batched datapath A/B), `delta_sweep` (Fig. 12 sweep + the
speedup-vs-sparsity curve) and `soak_bench` (sustained decisions/sec)
with DELTAKWS_BENCH_SMOKE=1 + DELTAKWS_BENCH_JSON=1, parses the
machine-readable `results/bench.jsonl` the in-crate harness appends, and
folds the relevant numbers into one JSON artifact:

  {
    "frames_per_sec": {"lean": ..., "traced": ...},   # consume+decide layer
    "probe_overhead_x": {...},                         # traced/lean per case
    "utterance_frames_per_sec": {...},
    "datapath_speedup_x": {"simd": ..., "batched": ...},
    "speedup_vs_sparsity": [{"sparsity_pct": 0, "simd_speedup_x": ...}, ...],
    "soak_decisions_per_sec": ...,
    "metrics_snapshot": {...},                         # soak's obs snapshot
    "cases": {bench: {case: mean_ns}},
    "baseline": {"path": ..., "ratios": {...}}         # vs BENCH_<N-1>.json
  }

Since PR 7 the report also ingests the soak run's metrics exposition
(results/soak_metrics.json, written by examples/soak.rs) after validating
it against the pinned metrics schema (deltakws-metrics/3 since PR 10:
steal/parking counters, scheduler gauges, sched_latency_us histogram),
and tracks the flight-recorder overhead ratio
(probe_overhead_x.utterance_decode_recorder) as a trajectory case.
`--validate-metrics PATH` runs the schema check alone (exit 0/1) — the
CI smoke step for the observability surface.

Since PR 8 the report also ingests the static-analysis counts from
deltakws-lint's JSON report (results/lint_report.json, schema
deltakws-lint/1) as report["static_analysis"] — unsuppressed findings
stay 0 (the blocking CI lint job guarantees it), and the reasoned
suppression count is tracked against the baseline like any other
trajectory metric.

Since PR 9 the report also ingests the few-shot customization numbers
(results/enroll_metrics.json, written by examples/enroll.rs): enrollment
latency per step and the mid-stream weight-swap service latency become
report["customization"] and are tracked against the baseline.

Since PR 10 the report also ingests the scale-soak artifact
(results/soak_scale.json, written by `examples/soak.rs -- scale`):
sessions/core, chunk and scheduling p99, steal and park counts become
report["scheduler"] — the v3 work-stealing scheduler's trajectory block,
baseline-diffed like every other tracked number.

The issue number is derived automatically (max N among existing
BENCH_*.json in the working directory — i.e. refresh the newest point)
unless pinned with --issue; the baseline defaults to BENCH_<N-1>.json
when present. Intended for CI (non-blocking step, artifact upload) and
local use:

  python3 tools/bench_report.py                  # auto: BENCH_<N>.json
  python3 tools/bench_report.py --issue 6        # pin the trajectory point
  python3 tools/bench_report.py --skip-build     # parse an existing jsonl
  python3 tools/bench_report.py --validate-metrics results/soak_metrics.json
"""

import argparse
import glob
import json
import os
import re
import subprocess
import sys

BENCHES = ["hotpath_bench", "delta_sweep", "soak_bench"]
# cargo runs bench binaries with cwd set to the package root (rust/), so
# the harness's results/bench.jsonl lands there when invoked from the
# repo root; accept either location (newest wins)
JSONL_CANDIDATES = [
    os.path.join("rust", "results", "bench.jsonl"),
    os.path.join("results", "bench.jsonl"),
]
# first PR that committed a bench artifact (fallback when none exist yet;
# PR 5's report only lived as a CI artifact)
FIRST_ISSUE = 6
# the soak example writes its metrics snapshot next to bench.jsonl — same
# cwd ambiguity, same resolution (newest wins)
METRICS_CANDIDATES = [
    os.path.join("rust", "results", "soak_metrics.json"),
    os.path.join("results", "soak_metrics.json"),
]
METRICS_SCHEMA = "deltakws-metrics/3"
# the `le` sequence of both exposed histograms, null = +Inf
METRICS_LE = [128, 512, 2048, 8192, 32768, 131072, 524288, 2097152, None]
# deltakws-lint writes its JSON report here in CI (`--json`); the counts
# become trajectory metrics like throughput — "how many static-analysis
# exceptions does the tree carry" is tracked per PR
LINT_CANDIDATES = [
    os.path.join("results", "lint_report.json"),
    os.path.join("rust", "results", "lint_report.json"),
]
LINT_SCHEMA = "deltakws-lint/1"
# examples/enroll.rs writes its customization numbers here — same cwd
# ambiguity as the soak snapshot, same resolution (newest wins)
ENROLL_CANDIDATES = [
    os.path.join("results", "enroll_metrics.json"),
    os.path.join("rust", "results", "enroll_metrics.json"),
]
ENROLL_SCHEMA = "deltakws-enroll/1"
# `examples/soak.rs -- scale` writes the scale-soak cells here — same cwd
# ambiguity as the soak snapshot, same resolution (newest wins)
SOAK_SCALE_CANDIDATES = [
    os.path.join("results", "soak_scale.json"),
    os.path.join("rust", "results", "soak_scale.json"),
]
SOAK_SCALE_SCHEMA = "deltakws-soak-scale/1"

SPARSITY_RE = re.compile(r"step_frame (scalar|simd) @ s=(\d+)")
BATCHED_RE = re.compile(r"step_frames_batched x(\d+) @ s=(\d+)")


def existing_issues():
    """Trajectory points already committed: BENCH_<N>.json in cwd."""
    out = []
    for path in glob.glob("BENCH_*.json"):
        m = re.fullmatch(r"BENCH_(\d+)\.json", os.path.basename(path))
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def resolve_issue(arg):
    if arg != "auto":
        return int(arg)
    issues = existing_issues()
    # refresh the newest committed point; fall back to the first artifact
    return issues[-1] if issues else FIRST_ISSUE


def find_jsonl():
    existing = [p for p in JSONL_CANDIDATES if os.path.exists(p)]
    if not existing:
        return None
    return max(existing, key=os.path.getmtime)


def run_benches(features):
    env = dict(os.environ)
    env["DELTAKWS_BENCH_SMOKE"] = "1"
    env["DELTAKWS_BENCH_JSON"] = "1"
    for bench in BENCHES:
        print(f"== running {bench} (smoke mode) ==", flush=True)
        cmd = ["cargo", "bench", "--bench", bench]
        if features:
            cmd += ["--features", features]
        subprocess.run(cmd, env=env, check=True)


def parse_jsonl(path):
    cases = {}
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            cases.setdefault(rec["bench"], {})[rec["case"]] = rec["mean_ns"]
    return cases


def frames_per_sec(mean_ns, frames_per_iter):
    return frames_per_iter / (mean_ns * 1e-9) if mean_ns else None


def sparsity_curve(sweep_cases):
    """Fold the `@ s=N` labels into one row per sparsity point."""
    points = {}

    def point(pct):
        return points.setdefault(int(pct), {"sparsity_pct": int(pct)})

    for label, mean_ns in sweep_cases.items():
        m = SPARSITY_RE.fullmatch(label)
        if m and mean_ns:
            kind, pct = m.group(1), m.group(2)
            p = point(pct)
            p[f"{kind}_mean_ns"] = mean_ns
            p[f"{kind}_frames_per_sec"] = frames_per_sec(mean_ns, 1.0)
            continue
        m = BATCHED_RE.fullmatch(label)
        if m and mean_ns:
            n, pct = int(m.group(1)), m.group(2)
            p = point(pct)
            # mean_ns is per iteration = per n frames; report per-frame
            p["batch_sessions"] = n
            p["batched_mean_ns_per_frame"] = mean_ns / n
            p["batched_frames_per_sec"] = frames_per_sec(mean_ns, float(n))
    for p in points.values():
        scalar = p.get("scalar_mean_ns")
        if scalar and p.get("simd_mean_ns"):
            p["simd_speedup_x"] = round(scalar / p["simd_mean_ns"], 3)
        if scalar and p.get("batched_mean_ns_per_frame"):
            p["batched_speedup_x"] = round(
                scalar / p["batched_mean_ns_per_frame"], 3
            )
    return [points[k] for k in sorted(points)]


def validate_metrics(doc):
    """Check a metrics-snapshot JSON document against the pinned
    deltakws-metrics/3 schema. Returns a list of problems (empty = valid)."""
    problems = []
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    if doc.get("schema") != METRICS_SCHEMA:
        problems.append(
            f"schema tag {doc.get('schema')!r} != {METRICS_SCHEMA!r}"
        )
    for key in (
        "seq",
        "captured_us",
        "counters",
        "gauges",
        "activity",
        "latency_us",
        "chunk_latency_us",
        "sched_latency_us",
        "enroll_latency_us",
        "per_worker",
        "recorder",
        "rates",
    ):
        if key not in doc:
            problems.append(f"missing top-level key {key!r}")
    counters = doc.get("counters", {})
    if isinstance(counters, dict):
        for key in (
            "completed",
            "correct",
            "labelled",
            "rejected_full",
            "rejected_closed",
            "steals",
            "park_transitions",
            "shed_overloaded",
            "fused_batches",
            "stream_events_dropped",
            "weight_swaps",
        ):
            if key not in counters:
                problems.append(f"missing counters.{key}")
    else:
        problems.append("counters is not an object")
    gauges = doc.get("gauges", {})
    if isinstance(gauges, dict):
        for key in (
            "accuracy",
            "session_bytes",
            "sessions_parked",
            "sessions_runnable",
            "telemetry_bytes",
            "resident_weight_versions",
        ):
            if key not in gauges:
                problems.append(f"missing gauges.{key}")
    else:
        problems.append("gauges is not an object")
    activity = doc.get("activity", {})
    if isinstance(activity, dict):
        for key in ("frames", "gated_frames", "sparsity", "duty_cycle"):
            if key not in activity:
                problems.append(f"missing activity.{key}")
    else:
        problems.append("activity is not an object")
    for hist in (
        "latency_us",
        "chunk_latency_us",
        "sched_latency_us",
        "enroll_latency_us",
    ):
        h = doc.get(hist)
        if not isinstance(h, dict):
            problems.append(f"{hist} is not an object")
            continue
        for key in ("count", "sum", "mean", "p50", "p90", "p99", "buckets"):
            if key not in h:
                problems.append(f"missing {hist}.{key}")
        buckets = h.get("buckets")
        if isinstance(buckets, list):
            les = [b.get("le") for b in buckets if isinstance(b, dict)]
            if les != METRICS_LE:
                problems.append(f"{hist} le sequence {les} != {METRICS_LE}")
        else:
            problems.append(f"{hist}.buckets is not a list")
    if not isinstance(doc.get("per_worker"), list):
        problems.append("per_worker is not a list")
    return problems


def find_metrics_snapshot():
    existing = [p for p in METRICS_CANDIDATES if os.path.exists(p)]
    if not existing:
        return None
    return max(existing, key=os.path.getmtime)


def ingest_metrics_snapshot(report):
    """Attach the soak run's metrics snapshot (validated) to the report.
    Non-fatal: a missing snapshot just leaves the key out; an invalid one
    is reported and skipped."""
    path = find_metrics_snapshot()
    if path is None:
        print("no soak metrics snapshot found; skipping ingest")
        return
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"metrics snapshot {path} unreadable ({e}); skipping ingest")
        return
    problems = validate_metrics(doc)
    if problems:
        print(f"metrics snapshot {path} failed validation; skipping ingest:")
        for p in problems:
            print(f"  - {p}")
        return
    report["metrics_snapshot"] = doc
    print(f"ingested metrics snapshot {path} "
          f"({doc['counters']['completed']} decisions)")


def ingest_lint_report(report):
    """Attach the deltakws-lint counts to the report. Non-fatal: a missing
    or mis-schema'd lint report just leaves the key out."""
    existing = [p for p in LINT_CANDIDATES if os.path.exists(p)]
    if not existing:
        print("no lint report found; skipping ingest")
        return
    path = max(existing, key=os.path.getmtime)
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"lint report {path} unreadable ({e}); skipping ingest")
        return
    if doc.get("schema") != LINT_SCHEMA:
        print(f"lint report {path} schema {doc.get('schema')!r} != "
              f"{LINT_SCHEMA!r}; skipping ingest")
        return
    counts = doc.get("counts", {})
    report["static_analysis"] = {
        "schema": LINT_SCHEMA,
        "files_scanned": doc.get("files_scanned"),
        "findings": counts.get("findings"),
        "suppressions": counts.get("suppressed"),
        "per_rule": counts.get("per_rule", {}),
    }
    print(f"ingested lint report {path} "
          f"({counts.get('findings')} findings, "
          f"{counts.get('suppressed')} suppressions over "
          f"{doc.get('files_scanned')} files)")


def ingest_enroll_metrics(report):
    """Attach the customization numbers from examples/enroll.rs to the
    report. Non-fatal: missing or mis-schema'd files leave the key out."""
    existing = [p for p in ENROLL_CANDIDATES if os.path.exists(p)]
    if not existing:
        print("no enroll metrics found; skipping ingest")
        return
    path = max(existing, key=os.path.getmtime)
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"enroll metrics {path} unreadable ({e}); skipping ingest")
        return
    if doc.get("schema") != ENROLL_SCHEMA:
        print(f"enroll metrics {path} schema {doc.get('schema')!r} != "
              f"{ENROLL_SCHEMA!r}; skipping ingest")
        return
    report["customization"] = doc
    print(f"ingested enroll metrics {path} "
          f"({doc.get('steps')} steps in {doc.get('enroll_us')} us, "
          f"swap {doc.get('swap_latency_us')} us)")


def ingest_soak_scale(report):
    """Attach the scale-soak cells from `examples/soak.rs -- scale` as
    report["scheduler"]. The largest cell's headline numbers are
    flattened next to the raw cells so diff_baseline can track them as
    scalars. Non-fatal: missing or mis-schema'd files leave the key out."""
    existing = [p for p in SOAK_SCALE_CANDIDATES if os.path.exists(p)]
    if not existing:
        print("no scale-soak artifact found; skipping ingest")
        return
    path = max(existing, key=os.path.getmtime)
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"scale-soak artifact {path} unreadable ({e}); skipping ingest")
        return
    if doc.get("schema") != SOAK_SCALE_SCHEMA:
        print(f"scale-soak artifact {path} schema {doc.get('schema')!r} != "
              f"{SOAK_SCALE_SCHEMA!r}; skipping ingest")
        return
    cells = doc.get("cells", [])
    if not cells:
        print(f"scale-soak artifact {path} has no cells; skipping ingest")
        return
    head = max(cells, key=lambda c: c.get("sessions", 0))
    report["scheduler"] = {
        "schema": SOAK_SCALE_SCHEMA,
        "cells": cells,
        # headline scalars from the largest cell, tracked vs baseline
        "sessions": head.get("sessions"),
        "sessions_per_core": head.get("sessions_per_core"),
        "chunk_p99_us": head.get("chunk_p99_us"),
        "sched_p99_us": head.get("sched_p99_us"),
        "chunks_per_sec": head.get("chunks_per_sec"),
        "steals": head.get("steals"),
        "park_transitions": head.get("park_transitions"),
    }
    print(f"ingested scale-soak artifact {path} "
          f"({len(cells)} cell(s), largest {head.get('sessions')} sessions: "
          f"chunk p99 {head.get('chunk_p99_us')} us, "
          f"sched p99 {head.get('sched_p99_us')} us)")


def build_report(cases, issue):
    hot = cases.get("hotpath (probe A/B)", {})
    sweep = cases.get("delta_sweep (Fig. 12)", {})
    soak = cases.get("soak", {})

    def ratio(traced_label, lean_label):
        a, b = hot.get(traced_label), hot.get(lean_label)
        return round(a / b, 3) if a and b else None

    report = {
        "schema": "deltakws-bench-report/2",
        "suite": "smoke",
        "issue": issue,
        "cases": cases,
        # the consume+decide layer the probe refactor moved off the
        # default path: lean accumulator vs per-decision trace
        "frames_per_sec": {
            "lean": frames_per_sec(
                hot.get("frame consume+decide, lean accumulator"), 62.0
            ),
            "traced": frames_per_sec(
                hot.get("frame consume+decide, traced (per-decision trace)"), 62.0
            ),
        },
        "utterance_frames_per_sec": {
            "lean": frames_per_sec(hot.get("utterance decode, lean (NoProbe)"), 62.0),
            "traced": frames_per_sec(
                hot.get("utterance decode, traced (TraceProbe)"), 62.0
            ),
            "recorder": frames_per_sec(
                hot.get("utterance decode, recorder (RecorderProbe+ring)"), 62.0
            ),
        },
        "probe_overhead_x": {
            "utterance_decode": ratio(
                "utterance decode, traced (TraceProbe)",
                "utterance decode, lean (NoProbe)",
            ),
            "utterance_decode_recorder": ratio(
                "utterance decode, recorder (RecorderProbe+ring)",
                "utterance decode, lean (NoProbe)",
            ),
            "sparse_accel_frames": ratio(
                "accel.step_frame sparse, traced", "accel.step_frame sparse, lean"
            ),
            "frame_consume_decide": ratio(
                "frame consume+decide, traced (per-decision trace)",
                "frame consume+decide, lean accumulator",
            ),
        },
        # scalar oracle vs fast kernels vs batched stepper, same bits
        "datapath_speedup_x": {
            "simd": ratio(
                "step_frame design point, scalar oracle",
                "step_frame design point, simd",
            ),
            "batched_per_frame": None,
        },
        "speedup_vs_sparsity": sparsity_curve(sweep),
    }
    dp_scalar = hot.get("step_frame design point, scalar oracle")
    dp_batch = hot.get("step_frames_batched x8, design point")
    if dp_scalar and dp_batch:
        report["datapath_speedup_x"]["batched_per_frame"] = round(
            dp_scalar / (dp_batch / 8.0), 3
        )
    lean = report["frames_per_sec"]["lean"]
    traced = report["frames_per_sec"]["traced"]
    if lean and traced:
        report["lean_speedup_x"] = round(lean / traced, 3)

    # soak decisions/sec: the micro-soak case label embeds its utterance
    # count ("micro soak: 150 utterances, ...") and times one whole run
    for label, mean_ns in soak.items():
        m = re.match(r"micro soak: (\d+) utterances", label)
        if m and mean_ns:
            report["soak_decisions_per_sec"] = round(
                int(m.group(1)) / (mean_ns * 1e-9), 1
            )
            break
    return report


def diff_baseline(report, baseline_path):
    """Non-fatal comparison against the previous trajectory point."""
    try:
        with open(baseline_path, encoding="utf-8") as f:
            base = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"baseline {baseline_path} unreadable ({e}); skipping diff")
        return None

    def pick(rep, *keys):
        cur = rep
        for k in keys:
            if not isinstance(cur, dict) or cur.get(k) is None:
                return None
            cur = cur[k]
        return cur if isinstance(cur, (int, float)) else None

    tracked = {
        "frames_per_sec.lean": ("frames_per_sec", "lean"),
        "utterance_frames_per_sec.lean": ("utterance_frames_per_sec", "lean"),
        "probe_overhead_x.utterance_decode_recorder": (
            "probe_overhead_x",
            "utterance_decode_recorder",
        ),
        "soak_decisions_per_sec": ("soak_decisions_per_sec",),
        # suppression creep is a ratio worth watching (findings stay 0 —
        # the blocking lint job guarantees that — so only the exception
        # count moves)
        "static_analysis.suppressions": ("static_analysis", "suppressions"),
        # per-step enrollment cost and the mid-stream swap latency are the
        # two customization numbers worth a trajectory
        "customization.us_per_step": ("customization", "us_per_step"),
        "customization.swap_latency_us": ("customization", "swap_latency_us"),
        # the v3 scheduler's headline numbers: tail latency under the
        # parked-session mass, and how far one core's attention stretches
        "scheduler.chunk_p99_us": ("scheduler", "chunk_p99_us"),
        "scheduler.sched_p99_us": ("scheduler", "sched_p99_us"),
        "scheduler.sessions_per_core": ("scheduler", "sessions_per_core"),
        "scheduler.chunks_per_sec": ("scheduler", "chunks_per_sec"),
    }
    ratios = {}
    for name, keys in tracked.items():
        now, then = pick(report, *keys), pick(base, *keys)
        if now and then:
            ratios[name] = round(now / then, 3)
    diff = {"path": baseline_path, "ratios": ratios}
    print(f"vs baseline {baseline_path}: "
          + ", ".join(f"{k} {v}x" for k, v in ratios.items()))
    return diff


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--issue",
        default="auto",
        help="trajectory point N for BENCH_<N>.json (default: newest committed)",
    )
    ap.add_argument("--out", default=None, help="output JSON path (overrides --issue)")
    ap.add_argument(
        "--baseline",
        default="auto",
        help="previous BENCH_*.json to diff against "
        "(default: BENCH_<N-1>.json when present; 'none' to disable)",
    )
    ap.add_argument(
        "--features",
        default="",
        help="cargo feature list for the bench builds (e.g. 'simd')",
    )
    ap.add_argument(
        "--skip-build",
        action="store_true",
        help="parse an existing results/bench.jsonl instead of running cargo bench",
    )
    ap.add_argument(
        "--validate-metrics",
        default=None,
        metavar="PATH",
        help="validate a metrics snapshot against the deltakws-metrics/3 "
        "schema and exit (no benches run)",
    )
    args = ap.parse_args()

    if args.validate_metrics is not None:
        try:
            with open(args.validate_metrics, encoding="utf-8") as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: {args.validate_metrics}: {e}", file=sys.stderr)
            return 1
        problems = validate_metrics(doc)
        if problems:
            print(f"{args.validate_metrics}: schema validation FAILED:")
            for p in problems:
                print(f"  - {p}")
            return 1
        print(f"{args.validate_metrics}: valid {METRICS_SCHEMA} snapshot "
              f"({doc['counters']['completed']} decisions, "
              f"{doc['activity']['frames']} frames)")
        return 0

    issue = resolve_issue(args.issue)
    out = args.out or f"BENCH_{issue}.json"

    if not args.skip_build:
        # start from a clean slate so stale lines don't pollute the report
        for path in JSONL_CANDIDATES:
            if os.path.exists(path):
                os.remove(path)
        run_benches(args.features)

    jsonl = find_jsonl()
    if jsonl is None:
        print(
            f"error: none of {JSONL_CANDIDATES} found (did the benches run?)",
            file=sys.stderr,
        )
        return 1

    report = build_report(parse_jsonl(jsonl), issue)
    ingest_metrics_snapshot(report)
    ingest_lint_report(report)
    ingest_enroll_metrics(report)
    ingest_soak_scale(report)

    baseline = args.baseline
    if baseline == "auto":
        candidate = f"BENCH_{issue - 1}.json"
        baseline = candidate if os.path.exists(candidate) else "none"
    if baseline != "none":
        diff = diff_baseline(report, baseline)
        if diff:
            report["baseline"] = diff

    with open(out, "w", encoding="utf-8") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out}")
    ratios = report.get("probe_overhead_x", {})
    print(f"probe overhead (traced/lean): {ratios}")
    if "lean_speedup_x" in report:
        print(f"lean consume+decide speedup: {report['lean_speedup_x']}x")
    dp = report.get("datapath_speedup_x", {})
    if dp.get("simd"):
        print(f"datapath speedup: simd {dp['simd']}x, "
              f"batched {dp.get('batched_per_frame')}x per frame")
    curve = report.get("speedup_vs_sparsity", [])
    if curve:
        pts = ", ".join(
            f"{p['sparsity_pct']}%: {p.get('simd_speedup_x', '?')}x" for p in curve
        )
        print(f"simd speedup vs sparsity: {pts}")
    if "soak_decisions_per_sec" in report:
        print(f"soak decisions/sec: {report['soak_decisions_per_sec']}")
    sched = report.get("scheduler")
    if sched:
        print(f"scheduler: {sched.get('sessions')} sessions "
              f"({sched.get('sessions_per_core')}/core), "
              f"chunk p99 {sched.get('chunk_p99_us')} us, "
              f"sched p99 {sched.get('sched_p99_us')} us")
    return 0


if __name__ == "__main__":
    sys.exit(main())

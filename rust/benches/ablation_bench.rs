//! Bench: design-choice ablations DESIGN.md calls out.
//!
//! (a) MAC lane count — chip latency & host cost vs parallelism;
//! (b) ΔFIFO depth — burst absorption (high-water, overflow risk);
//! (c) Δ-side — gating x only / h only / both at matched threshold;
//! (d) coarse skip-RNN vs fine-grained ΔRNN at matched feature stream.

mod common;

use deltakws::accel::{AccelConfig, DeltaRnnAccel};
use deltakws::baseline::SkipRnn;
use deltakws::energy::SramKind;
use deltakws::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("ablations");
    let frames = common::feature_stream(21, 128, 0.3, 60);

    println!("(a) MAC lanes (chip latency is cycles/125kHz; host is wall time):");
    for lanes in [1usize, 2, 4, 8, 16] {
        let mut cfg = AccelConfig::design_point().with_delta_th(26);
        cfg.mac_lanes = lanes;
        let mut accel = DeltaRnnAccel::new(common::rng_quant(3), cfg, SramKind::NearVth);
        let mut i = 0usize;
        b.bench_with_items(&format!("step_frame @ {lanes} lanes"), 1.0, "frames", || {
            black_box(accel.step_frame(black_box(&frames[i % frames.len()])));
            i += 1;
        });
        println!(
            "  {lanes:>2} lanes: chip latency {:.2} ms/frame",
            accel.activity.avg_latency_ms()
        );
    }

    println!("\n(b) ΔFIFO depth (burst absorption at 50% firing):");
    // the ring never overflows by construction — a full ring stalls the
    // encoder while the MAC array drains one event (PR 5) — so the sizing
    // signal is high-water vs depth: a saturated ring means the encoder
    // stalled, a high-water below depth means the bursts fit
    let bursty = common::feature_stream(22, 128, 0.5, 70);
    for depth in [4usize, 8, 16, 32, 80] {
        let mut cfg = AccelConfig::design_point().with_delta_th(26);
        cfg.fifo_depth = depth;
        let mut accel = DeltaRnnAccel::new(common::rng_quant(4), cfg, SramKind::NearVth);
        for f in &bursty {
            accel.step_frame(f);
        }
        let hw = accel.fifo.high_water;
        println!(
            "  depth {depth:>2}: high-water {hw:>2}/{depth} {}",
            if hw >= depth { "(saturated: encoder stalled on MAC drain)" } else { "(bursts fit)" }
        );
    }

    println!("\n(c) Δ-side gating at th=0.2:");
    for (label, thx, thh) in [
        ("both", Some(51i16), Some(51i16)),
        ("x only", Some(51), Some(0)),
        ("h only", Some(0), Some(51)),
    ] {
        let mut cfg = AccelConfig::design_point();
        cfg.delta_th_x_q8 = thx;
        cfg.delta_th_h_q8 = thh;
        let mut accel = DeltaRnnAccel::new(common::rng_quant(5), cfg, SramKind::NearVth);
        for f in &frames {
            accel.step_frame(f);
        }
        let a = accel.activity;
        println!(
            "  {label:<7} sparsity {:>5.1}% (x {:>5.1}%, h {:>5.1}%), latency {:.2} ms",
            a.sparsity() * 100.0,
            a.input_sparsity() * 100.0,
            a.hidden_sparsity() * 100.0,
            a.avg_latency_ms()
        );
    }

    println!("\n(d) coarse skip-RNN vs fine ΔRNN (same stream):");
    let mut delta = DeltaRnnAccel::new(
        common::rng_quant(6),
        AccelConfig::design_point().with_delta_th(51),
        SramKind::NearVth,
    );
    for f in &frames {
        delta.step_frame(f);
    }
    let mut skip = SkipRnn::new(common::rng_quant(6), AccelConfig::design_point().active_x, 150);
    for f in &frames {
        skip.step_frame(f);
    }
    println!(
        "  ΔRNN   : {:.1}% lane sparsity, {} SRAM reads",
        delta.activity.sparsity() * 100.0,
        delta.sram.reads
    );
    println!(
        "  skipRNN: {:.0}% frames skipped, {} SRAM reads",
        skip.skip_rate() * 100.0,
        skip.inner.sram.reads
    );
    b.finish();
}

//! Bench: the ΔRNN accelerator hot loop in isolation — the L3 profile
//! target (EXPERIMENTS.md §Perf).
//!
//! Separates the frame-step cost by firing level (the hot path's work is
//! proportional to fired lanes: weight-row streaming + MAC), and measures
//! the components: encoder-only (all-silent), FC-only floor, and the dense
//! worst case. Also covers the dense-GRU baseline for the same workload.

mod common;

use deltakws::accel::{AccelConfig, DeltaRnnAccel};
use deltakws::baseline::DenseGruAccel;
use deltakws::energy::SramKind;
use deltakws::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("accel hot path");

    // firing-level sweep: p_move controls how many lanes fire per frame
    for (label, p_move) in
        [("all-silent", 0.0), ("13% firing", 0.13), ("50% firing", 0.5), ("dense", 1.0)]
    {
        let frames = common::feature_stream(11, 128, p_move, 60);
        let cfg = AccelConfig::design_point().with_delta_th(26);
        let mut accel = DeltaRnnAccel::new(common::rng_quant(2), cfg, SramKind::NearVth);
        // warm the state so "all-silent" is truly silent
        for f in frames.iter().take(8) {
            accel.step_frame(f);
        }
        let mut i = 0usize;
        let s = b.bench_with_items(&format!("step_frame {label}"), 1.0, "frames", || {
            black_box(accel.step_frame(black_box(&frames[i % frames.len()])));
            i += 1;
        });
        let fired = accel.activity.fired_lanes as f64 / accel.activity.frames as f64;
        println!(
            "{label:<12} {:>8.2} µs/frame  ({:>9.0} frames/s, avg {fired:.1} lanes fired)",
            s.mean_ns / 1e3,
            1e9 / s.mean_ns
        );
    }

    // dense baseline: input-independent cost
    let frames = common::feature_stream(12, 128, 0.3, 60);
    let mut dense = DenseGruAccel::new(
        common::rng_quant(2),
        AccelConfig::design_point().active_x,
        SramKind::NearVth,
    );
    let mut i = 0usize;
    let s = b.bench_with_items("dense-GRU baseline step", 1.0, "frames", || {
        black_box(dense.step_frame(black_box(&frames[i % frames.len()])));
        i += 1;
    });
    println!(
        "dense-GRU     {:>8.2} µs/frame  ({:>9.0} frames/s) — no elision",
        s.mean_ns / 1e3,
        1e9 / s.mean_ns
    );
    b.finish();
}

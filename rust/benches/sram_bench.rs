//! Bench: the weight-SRAM twin (Fig. 13 context) — read path cost, bank
//! counter overheads, and the timing-DES generation rate.

mod common;

use deltakws::energy::SramKind;
use deltakws::sram::timing::{simulate, TimingParams};
use deltakws::sram::{WeightSram, WORDS};
use deltakws::util::bench::{black_box, Bench};
use deltakws::util::prng::Pcg;

fn main() {
    let mut b = Bench::new("sram");

    let mut sram = WeightSram::new(SramKind::NearVth);
    for a in 0..WORDS {
        sram.write_word(a, (a * 7) as u16);
    }

    // sequential row stream (the MAC array's access pattern: 96-word rows)
    let mut addr = 0usize;
    let s = b.bench_with_items("sequential row read (96 words)", 96.0, "words", || {
        let base = (addr * 96) % (WORDS - 96);
        let mut acc = 0u32;
        for w in 0..96 {
            acc = acc.wrapping_add(sram.read_word(base + w) as u32);
        }
        black_box(acc);
        addr += 1;
    });
    println!(
        "row stream: {:.2} ns/word ({:.0} Mwords/s host)",
        s.mean_ns / 96.0,
        96.0 / s.mean_ns * 1e3
    );

    // random word reads (FC access pattern)
    let mut rng = Pcg::new(9);
    let s = b.bench_with_items("random word read", 1.0, "words", || {
        black_box(sram.read_word(rng.below(WORDS)));
    });
    println!("random read: {:.2} ns/word", s.mean_ns);

    // energy accounting consistency
    let reads_before = sram.reads;
    sram.read_word(0);
    assert_eq!(sram.reads, reads_before + 1);
    println!(
        "energy so far: {:.1} nJ near-Vth ({} reads)",
        sram.read_energy_nj(),
        sram.reads
    );

    // Fig. 13 timing DES generation
    let p = TimingParams { skew_ns: 200.0, ..Default::default() };
    let s = b.bench_with_items("timing DES, 100 cycles", 100.0, "cycles", || {
        black_box(simulate(black_box(&p), 100));
    });
    println!("timing DES: {:.1} ns/cycle simulated", s.mean_ns / 100.0);
    b.finish();
}

//! Streaming-pipeline benchmarks: the always-on hot path.
//!
//! Measures the frame-incremental chip API against the batch wrapper, the
//! full VAD-gated detection pipeline on speech vs silence (the VAD's
//! simulation-speed win mirrors the silicon's energy win: gated frames
//! skip the ΔRNN entirely), and the bare detector state machine.
//!
//! Run: `cargo bench --bench stream_bench` (DELTAKWS_BENCH_SMOKE=1 for CI).

mod common;

use deltakws::audio::track::{synth_track, TrackConfig};
use deltakws::chip::{ChipConfig, KwsChip};
use deltakws::stream::detector::{Detector, DetectorConfig};
use deltakws::stream::vad::VadConfig;
use deltakws::stream::{StreamConfig, StreamPipeline};
use deltakws::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("stream");
    let utt = common::utterance(5, 11);

    // frame-incremental chip API vs the batch wrapper (same work)
    let mut chip = KwsChip::new(common::rng_quant(1), ChipConfig::design_point());
    b.bench_with_items("chip.process_utterance (batch)", 1.0, "utt", || {
        black_box(chip.process_utterance(black_box(&utt)));
    });
    let mut chip2 = KwsChip::new(common::rng_quant(1), ChipConfig::design_point());
    b.bench_with_items("chip.push_samples+poll (256-sample chunks)", 1.0, "utt", || {
        chip2.reset();
        for c in utt.chunks(256) {
            chip2.push_samples(c).expect("chunk fits");
            while let Some(f) = chip2.poll_frame() {
                black_box(f);
            }
        }
    });

    // full pipeline on 2 s of speech-bearing track vs 2 s of near-silence
    let speech = synth_track(
        &TrackConfig { duration_s: 2, keywords: 2, fillers: 0, noise: (0.001, 0.002) },
        3,
    )
    .0;
    let silence = synth_track(
        &TrackConfig { duration_s: 2, keywords: 0, fillers: 0, noise: (0.001, 0.002) },
        3,
    )
    .0;
    for (label, audio) in [("speech", &speech), ("silence", &silence)] {
        let mut pipe =
            StreamPipeline::new(common::rng_quant(2), StreamConfig::design_point());
        b.bench_with_items(
            &format!("pipeline 2 s {label}, vad on"),
            2.0,
            "s",
            || {
                for c in audio.chunks(256) {
                    black_box(pipe.push_audio(c).expect("chunk fits"));
                }
            },
        );
    }
    let mut pipe = StreamPipeline::new(
        common::rng_quant(2),
        StreamConfig::design_point().with_vad(VadConfig::disabled()),
    );
    b.bench_with_items("pipeline 2 s speech, vad off", 2.0, "s", || {
        for c in speech.chunks(256) {
            black_box(pipe.push_audio(c).expect("chunk fits"));
        }
    });

    // bare wakeword state machine
    let mut det = Detector::new(DetectorConfig::design_point());
    let mut t = 0u64;
    b.bench_with_items("detector.step", 1.0, "frames", || {
        let mut logits = [0i64; deltakws::NUM_CLASSES];
        logits[(t % 12) as usize] = (t as i64 * 7919) % 100_000;
        black_box(det.step(t, &logits, false));
        t += 1;
    });

    b.finish();
}

//! Bench: paper Table II — end-to-end chip twin + serving coordinator.
//!
//! Full-pipeline cost (audio -> FEx -> CDC FIFO -> ΔRNN -> decision) at the
//! two Table II operating points, plus coordinator throughput scaling over
//! worker count. This is the headline L3 performance artefact for
//! EXPERIMENTS.md §Perf.

mod common;

use std::time::Duration;

use deltakws::chip::{ChipConfig, KwsChip};
use deltakws::coordinator::{Coordinator, Request};
use deltakws::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("e2e (Table II)");
    let utt = common::utterance(11, 11);

    println!("chip twin, full utterance pipeline:");
    for (label, th) in [("Δ_TH=0", 0i16), ("Δ_TH=0.2", 51)] {
        let mut chip =
            KwsChip::new(common::rng_quant(5), ChipConfig::design_point().with_delta_th(th));
        let s = b.bench_with_items(&format!("process_utterance {label}"), 1.0, "utt", || {
            black_box(chip.process_utterance(black_box(&utt)));
        });
        let rep = chip.report();
        println!(
            "  {label:<9} host {:>7.2} ms/utt ({:>5.1} utt/s) | chip: {:.2} ms, {:.1} nJ, {:.0}% sparse",
            s.mean_ns / 1e6,
            1e9 / s.mean_ns,
            rep.latency_ms,
            rep.energy_per_decision_nj,
            rep.sparsity * 100.0
        );
    }

    println!("\ncoordinator scaling (32 requests, queue 16):");
    for workers in [1usize, 2, 4] {
        let coord = Coordinator::builder(common::rng_quant(5), ChipConfig::design_point())
            .workers(workers)
            .queue_depth(16)
            .build()
            .expect("valid bench pool");
        let t0 = std::time::Instant::now();
        let n = 32;
        let reqs: Vec<Request> = (0..n)
            .map(|i| Request {
                id: 0,
                stream: (i % 8) as u64,
                audio12: utt.clone(),
                label: None,
                trace: false,
                weights: None,
            })
            .collect();
        // v2 utterance-benchmark path: batch submission (blocking through
        // backpressure), ticket-routed responses
        let batch = coord.submit_batch(reqs).expect("pool alive");
        let submitted = batch.len();
        let got = batch.wait_all(Duration::from_secs(120)).len();
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "  {workers} worker(s): {:.1} utt/s ({got}/{submitted} submitted of {n} in {wall:.2}s)",
            got as f64 / wall
        );
    }
    b.finish();
}

//! Shared helpers for the bench binaries: deterministic random models and
//! feature streams with controlled temporal sparsity.

use deltakws::accel::gru::{QuantParams, C};
use deltakws::util::prng::Pcg;

/// Deterministic random quantised model (weight values don't affect cycle
/// counts; they do affect firing dynamics, so benches that care drive the
/// encoder with explicit feature streams instead).
#[allow(dead_code)]
pub fn rng_quant(seed: u64) -> QuantParams {
    let mut rng = Pcg::new(seed);
    let mut q = QuantParams::zeroed();
    q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
    q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q
}

/// Feature frame stream whose per-frame change rate approximates a target
/// input sparsity: each frame, every active channel moves by `step` with
/// probability `p_move` (so Δ_TH just below `step` gates at ~1-p_move).
#[allow(dead_code)]
pub fn feature_stream(seed: u64, frames: usize, p_move: f64, step: i16) -> Vec<[i16; C]> {
    let mut rng = Pcg::new(seed);
    let mut cur = [60i16; C];
    let mut out = Vec::with_capacity(frames);
    for _ in 0..frames {
        for slot in cur.iter_mut().take(14).skip(4) {
            if rng.uniform() < p_move {
                let dir = if rng.uniform() < 0.5 { -1 } else { 1 };
                *slot = (*slot + dir * step).clamp(0, 255);
            }
        }
        out.push(cur);
    }
    out
}

/// One quantised synthetic-GSCD utterance.
#[allow(dead_code)]
pub fn utterance(seed: u64, class: usize) -> Vec<i64> {
    let mut rng = Pcg::new(seed);
    let wave = deltakws::audio::synth_utterance(class, &mut rng);
    deltakws::audio::quantize_12b(&wave)
}

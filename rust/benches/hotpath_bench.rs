//! Bench 10 (PR 5 tentpole): the frame hot path, probed vs unprobed.
//!
//! A/Bs the lean `NoProbe` datapath against the opt-in `TraceProbe`
//! instrumentation at three altitudes:
//!
//! * **utterance decode** — the full pipeline (FEx → CDC → ΔRNN →
//!   decision); here the arithmetic dominates, so the probe overhead is
//!   the *residual* the zero-cost claim must keep small;
//! * **sparse accel frames** — `step_frame` on a low-motion feature
//!   stream (the regime the chip lives in), where per-frame bookkeeping
//!   is proportionally largest inside the accelerator;
//! * **frame consume + decide** — the layer this PR actually moved:
//!   folding completed frames into a decision with the lean
//!   `DecisionAccum` vs materializing the old per-decision traces
//!   (three Vec pushes incl. a 128-byte feature copy per frame + the
//!   per-decision allocations). This is the instrumentation tax every
//!   request used to pay and now only traced requests pay — the
//!   lean-vs-traced frames/sec ratio here is the headline number
//!   `tools/bench_report.py` records into BENCH_5.json.
//!
//! Run: `cargo bench --bench hotpath_bench` (DELTAKWS_BENCH_SMOKE=1 for CI).

mod common;

use deltakws::chip::{ChipConfig, DecisionAccum, FrameOut, KwsChip};
use deltakws::probe::{ChipProbe, TraceProbe};
use deltakws::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("hotpath (probe A/B)");
    let utts: Vec<Vec<i64>> = (0..8).map(|i| common::utterance(40 + i, (i % 12) as usize)).collect();

    // --- (1) full utterance decode -------------------------------------
    let mut lean_chip = KwsChip::new(common::rng_quant(9), ChipConfig::design_point());
    let mut i = 0usize;
    let s_utt_lean = b.bench_with_items("utterance decode, lean (NoProbe)", 62.0, "frames", || {
        let u = &utts[i % utts.len()];
        i += 1;
        black_box(lean_chip.process_utterance(black_box(u)));
    });
    let mut traced_chip = KwsChip::new(common::rng_quant(9), ChipConfig::design_point());
    let mut j = 0usize;
    let s_utt_traced =
        b.bench_with_items("utterance decode, traced (TraceProbe)", 62.0, "frames", || {
            let u = &utts[j % utts.len()];
            j += 1;
            black_box(traced_chip.process_utterance_traced(black_box(u)));
        });

    // --- (2) sparse accel frames ---------------------------------------
    // low-motion stream at the design Δ_TH: few lanes fire, the fixed
    // enc/NLU/FC floor dominates — closest to the chip's idle-speech regime
    let frames = common::feature_stream(31, 256, 0.05, 60);
    let mut acc_lean = deltakws::accel::DeltaRnnAccel::new(
        common::rng_quant(10),
        deltakws::accel::AccelConfig::design_point(),
        deltakws::energy::SramKind::NearVth,
    );
    let mut k = 0usize;
    let s_acc_lean = b.bench_with_items("accel.step_frame sparse, lean", 1.0, "frames", || {
        black_box(acc_lean.step_frame(black_box(&frames[k % frames.len()])));
        k += 1;
    });
    let mut acc_traced = deltakws::accel::DeltaRnnAccel::new(
        common::rng_quant(10),
        deltakws::accel::AccelConfig::design_point(),
        deltakws::energy::SramKind::NearVth,
    );
    let mut probe = TraceProbe::default();
    let mut m = 0usize;
    let s_acc_traced = b.bench_with_items("accel.step_frame sparse, traced", 1.0, "frames", || {
        black_box(acc_traced.step_frame_probed(black_box(&frames[m % frames.len()]), &mut probe));
        m += 1;
        if probe.trace.len() >= 62 {
            black_box(probe.take_trace());
        }
    });

    // --- (3) frame consume + decide ------------------------------------
    // the layer this PR moved out of the default path: 62 completed
    // frames folded into a decision, lean accumulator vs per-decision
    // trace materialization (what every request used to pay)
    let window: Vec<FrameOut> = {
        let mut chip = KwsChip::new(common::rng_quant(9), ChipConfig::design_point());
        chip.reset();
        let mut out = Vec::new();
        chip.push_samples(&utts[0]).expect("utterance fits");
        while let Some(f) = chip.poll_frame() {
            out.push(f);
        }
        out
    };
    let n_frames = window.len() as f64;
    let s_lean = b.bench_with_items("frame consume+decide, lean accumulator", n_frames, "frames", || {
        let mut acc = DecisionAccum::new(4);
        for f in &window {
            acc.push(black_box(f));
        }
        black_box(acc.finish());
    });
    let s_traced = b.bench_with_items(
        "frame consume+decide, traced (per-decision trace)",
        n_frames,
        "frames",
        || {
            let mut acc = DecisionAccum::new(4);
            let mut probe = TraceProbe::default();
            for f in &window {
                probe.frame_completed(black_box(f));
                acc.push(black_box(f));
            }
            black_box((acc.finish(), probe.take_trace()));
        },
    );

    println!("\nprobe overhead (traced time / lean time, same work):");
    println!("  utterance decode     : {:.2}x", s_utt_traced.mean_ns / s_utt_lean.mean_ns);
    println!("  sparse accel frames  : {:.2}x", s_acc_traced.mean_ns / s_acc_lean.mean_ns);
    println!(
        "  frame consume+decide : {:.2}x  (lean path {:.2}x the traced frames/sec)",
        s_traced.mean_ns / s_lean.mean_ns,
        s_traced.mean_ns / s_lean.mean_ns
    );
    b.finish();
}

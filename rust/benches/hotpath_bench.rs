//! Bench 10 (PR 5 tentpole): the frame hot path, probed vs unprobed.
//!
//! A/Bs the lean `NoProbe` datapath against the opt-in `TraceProbe`
//! instrumentation at three altitudes:
//!
//! * **utterance decode** — the full pipeline (FEx → CDC → ΔRNN →
//!   decision); here the arithmetic dominates, so the probe overhead is
//!   the *residual* the zero-cost claim must keep small;
//! * **sparse accel frames** — `step_frame` on a low-motion feature
//!   stream (the regime the chip lives in), where per-frame bookkeeping
//!   is proportionally largest inside the accelerator;
//! * **frame consume + decide** — the layer this PR actually moved:
//!   folding completed frames into a decision with the lean
//!   `DecisionAccum` vs materializing the old per-decision traces
//!   (three Vec pushes incl. a 128-byte feature copy per frame + the
//!   per-decision allocations). This is the instrumentation tax every
//!   request used to pay and now only traced requests pay — the
//!   lean-vs-traced frames/sec ratio here is the headline number
//!   `tools/bench_report.py` records into the BENCH_N.json report.
//!
//! PR 6 adds a fourth altitude: **datapath A/B** — the scalar oracle vs
//! the lane-packed fast kernels vs the 8-session batched stepper at the
//! design point, all producing identical bits (`tests/simd_equivalence`).
//!
//! PR 7 adds a fifth: **flight-recorder A/B** — the same utterance decode
//! through a `RecorderProbe` feeding an enabled ring (counter folding +
//! one FrameBatch/Decision event per utterance), quantifying the
//! observability tax relative to the lean path. The lean case itself is
//! unchanged — its frames/sec tracks that the recorder stayed opt-in.
//!
//! Run: `cargo bench --bench hotpath_bench` (DELTAKWS_BENCH_SMOKE=1 for CI).

mod common;

use deltakws::chip::{ChipConfig, DecisionAccum, FrameOut, KwsChip};
use deltakws::obs::recorder::{EventKind, FlightRecorder, RecorderConfig, RecorderProbe};
use deltakws::obs::TraceId;
use deltakws::probe::{ChipProbe, TraceProbe};
use deltakws::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("hotpath (probe A/B)");
    let utts: Vec<Vec<i64>> = (0..8).map(|i| common::utterance(40 + i, (i % 12) as usize)).collect();

    // --- (1) full utterance decode -------------------------------------
    let mut lean_chip = KwsChip::new(common::rng_quant(9), ChipConfig::design_point());
    let mut i = 0usize;
    let s_utt_lean = b.bench_with_items("utterance decode, lean (NoProbe)", 62.0, "frames", || {
        let u = &utts[i % utts.len()];
        i += 1;
        black_box(lean_chip.process_utterance(black_box(u)));
    });
    let mut traced_chip = KwsChip::new(common::rng_quant(9), ChipConfig::design_point());
    let mut j = 0usize;
    let s_utt_traced =
        b.bench_with_items("utterance decode, traced (TraceProbe)", 62.0, "frames", || {
            let u = &utts[j % utts.len()];
            j += 1;
            black_box(traced_chip.process_utterance_traced(black_box(u)));
        });

    // --- (2) sparse accel frames ---------------------------------------
    // low-motion stream at the design Δ_TH: few lanes fire, the fixed
    // enc/NLU/FC floor dominates — closest to the chip's idle-speech regime
    let frames = common::feature_stream(31, 256, 0.05, 60);
    let mut acc_lean = deltakws::accel::DeltaRnnAccel::new(
        common::rng_quant(10),
        deltakws::accel::AccelConfig::design_point(),
        deltakws::energy::SramKind::NearVth,
    );
    let mut k = 0usize;
    let s_acc_lean = b.bench_with_items("accel.step_frame sparse, lean", 1.0, "frames", || {
        black_box(acc_lean.step_frame(black_box(&frames[k % frames.len()])));
        k += 1;
    });
    let mut acc_traced = deltakws::accel::DeltaRnnAccel::new(
        common::rng_quant(10),
        deltakws::accel::AccelConfig::design_point(),
        deltakws::energy::SramKind::NearVth,
    );
    let mut probe = TraceProbe::default();
    let mut m = 0usize;
    let s_acc_traced = b.bench_with_items("accel.step_frame sparse, traced", 1.0, "frames", || {
        black_box(acc_traced.step_frame_probed(black_box(&frames[m % frames.len()]), &mut probe));
        m += 1;
        if probe.trace.len() >= 62 {
            black_box(probe.take_trace());
        }
    });

    // --- (3) frame consume + decide ------------------------------------
    // the layer this PR moved out of the default path: 62 completed
    // frames folded into a decision, lean accumulator vs per-decision
    // trace materialization (what every request used to pay)
    let window: Vec<FrameOut> = {
        let mut chip = KwsChip::new(common::rng_quant(9), ChipConfig::design_point());
        chip.reset();
        let mut out = Vec::new();
        chip.push_samples(&utts[0]).expect("utterance fits");
        while let Some(f) = chip.poll_frame() {
            out.push(f);
        }
        out
    };
    let n_frames = window.len() as f64;
    let s_lean = b.bench_with_items("frame consume+decide, lean accumulator", n_frames, "frames", || {
        let mut acc = DecisionAccum::new(4);
        for f in &window {
            acc.push(black_box(f));
        }
        black_box(acc.finish());
    });
    let s_traced = b.bench_with_items(
        "frame consume+decide, traced (per-decision trace)",
        n_frames,
        "frames",
        || {
            let mut acc = DecisionAccum::new(4);
            let mut probe = TraceProbe::default();
            for f in &window {
                probe.frame_completed(black_box(f));
                acc.push(black_box(f));
            }
            black_box((acc.finish(), probe.take_trace()));
        },
    );

    // --- (4) datapath A/B: scalar oracle vs lane-packed vs batched ------
    // design-regime motion (p_move 0.35): the three datapaths do the same
    // arithmetic bit-for-bit, so any gap here is pure implementation
    let ab_frames = common::feature_stream(33, 256, 0.35, 60);
    let mut acc_scalar = deltakws::accel::DeltaRnnAccel::new(
        common::rng_quant(10),
        deltakws::accel::AccelConfig::design_point().with_simd(false),
        deltakws::energy::SramKind::NearVth,
    );
    let mut p = 0usize;
    let s_dp_scalar =
        b.bench_with_items("step_frame design point, scalar oracle", 1.0, "frames", || {
            black_box(acc_scalar.step_frame(black_box(&ab_frames[p % ab_frames.len()])));
            p += 1;
        });
    let mut acc_simd = deltakws::accel::DeltaRnnAccel::new(
        common::rng_quant(10),
        deltakws::accel::AccelConfig::design_point().with_simd(true),
        deltakws::energy::SramKind::NearVth,
    );
    let mut q = 0usize;
    let s_dp_simd = b.bench_with_items("step_frame design point, simd", 1.0, "frames", || {
        black_box(acc_simd.step_frame(black_box(&ab_frames[q % ab_frames.len()])));
        q += 1;
    });
    let mut host = deltakws::accel::DeltaRnnAccel::new(
        common::rng_quant(10),
        deltakws::accel::AccelConfig::design_point().with_simd(true),
        deltakws::energy::SramKind::NearVth,
    );
    let mut sessions = vec![deltakws::accel::batch::BatchSession::new(); 8];
    let mut r = 0usize;
    let s_dp_batch =
        b.bench_with_items("step_frames_batched x8, design point", 8.0, "frames", || {
            let f = &ab_frames[r % ab_frames.len()];
            for sess in sessions.iter_mut() {
                sess.stage(*f);
            }
            black_box(host.step_frames_batched(&mut sessions));
            r += 1;
        });

    // --- (5) flight-recorder A/B ---------------------------------------
    // the same full decode through an enabled recorder: RecorderProbe
    // folds the per-frame hooks into counters and the ring sees one
    // FrameBatch + one Decision per utterance — the worker-loop pattern
    let rec = FlightRecorder::new(RecorderConfig::default());
    let mut rec_chip = KwsChip::new(common::rng_quant(9), ChipConfig::design_point());
    let mut v = 0usize;
    let s_utt_rec = b.bench_with_items(
        "utterance decode, recorder (RecorderProbe+ring)",
        62.0,
        "frames",
        || {
            let u = &utts[v % utts.len()];
            v += 1;
            let trace = TraceId(v as u64);
            let mut rp = RecorderProbe::new(&rec, 0, trace);
            let d = rec_chip.process_utterance_probed(black_box(u), &mut rp);
            rp.flush_frame_batch();
            rec.record(0, trace, EventKind::Decision { class: d.class as u8, service_us: 0 });
            black_box(d);
        },
    );

    println!("\nprobe overhead (traced time / lean time, same work):");
    println!("  utterance decode     : {:.2}x", s_utt_traced.mean_ns / s_utt_lean.mean_ns);
    println!(
        "  recorder decode      : {:.2}x  (RecorderProbe + ring vs lean)",
        s_utt_rec.mean_ns / s_utt_lean.mean_ns
    );
    println!("  sparse accel frames  : {:.2}x", s_acc_traced.mean_ns / s_acc_lean.mean_ns);
    println!(
        "  frame consume+decide : {:.2}x  (lean path {:.2}x the traced frames/sec)",
        s_traced.mean_ns / s_lean.mean_ns,
        s_traced.mean_ns / s_lean.mean_ns
    );
    println!("\ndatapath speedup at the design point (same bits, different kernels):");
    println!(
        "  simd / scalar        : {:.2}x",
        s_dp_scalar.mean_ns / s_dp_simd.mean_ns
    );
    println!(
        "  batched x8 / scalar  : {:.2}x per frame",
        s_dp_scalar.mean_ns / (s_dp_batch.mean_ns / 8.0)
    );
    b.finish();
}

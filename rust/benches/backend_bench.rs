//! Bench: the native execution backend — batched forward and the full BPTT
//! train step. This is the offline-training hot path (EXPERIMENTS.md
//! §Perf) and the cost model for sizing `deltakws train` runs.

mod common;

use deltakws::runtime::{Backend, IntTensor, NativeBackend, Tensor, TrainState};
use deltakws::util::bench::{black_box, Bench};
use deltakws::util::prng::Pcg;

fn random_params(seed: u64, scale: f32) -> Vec<Tensor> {
    let mut rng = Pcg::new(seed);
    let shapes: [(usize, usize); 5] = [(16, 192), (64, 192), (1, 192), (64, 12), (1, 12)];
    shapes
        .iter()
        .map(|&(r, c)| {
            let data: Vec<f32> =
                (0..r * c).map(|_| (rng.range_f64(-1.0, 1.0) as f32) * scale).collect();
            Tensor::new(if r == 1 { vec![c] } else { vec![r, c] }, data)
        })
        .collect()
}

fn random_feats(seed: u64, batch: usize) -> Tensor {
    let mut rng = Pcg::new(seed);
    let mut data = vec![0f32; batch * 62 * 16];
    let mut cur = [0.3f32; 16];
    for v in data.iter_mut() {
        let c = (rng.below(16), rng.uniform());
        cur[c.0] = (cur[c.0] + (c.1 as f32 - 0.5) * 0.1).clamp(0.0, 0.99);
        *v = cur[c.0];
    }
    Tensor::new(vec![batch, 62, 16], data)
}

fn main() {
    let mut b = Bench::new("execution backend (native)");
    let backend = NativeBackend::new();
    let params = random_params(1, 0.15);

    println!("batched forward (62 frames x 16 ch per utterance):");
    for batch in [1usize, 4, 16] {
        let feats = random_feats(7, batch);
        for th in [0.0f32, 0.2] {
            let s = b.bench_with_items(
                &format!("forward b={batch} th={th}"),
                batch as f64,
                "utt",
                || {
                    black_box(backend.forward(black_box(&params), &feats, th).unwrap());
                },
            );
            println!(
                "  b={batch:<2} th={th:<4} {:>9.2} µs/batch ({:>8.0} utt/s)",
                s.mean_ns / 1e3,
                batch as f64 / (s.mean_ns * 1e-9)
            );
        }
    }

    println!("\ntrain step (forward + BPTT + Adam):");
    for batch in [4usize, 16] {
        let feats = random_feats(9, batch);
        let labels =
            IntTensor::new(vec![batch], (0..batch).map(|i| (i % 12) as i32).collect());
        let mut state = TrainState::init(backend.manifest(), 3);
        let s = b.bench_with_items(&format!("train_step b={batch}"), batch as f64, "utt", || {
            black_box(
                backend.train_step(&mut state, &feats, &labels, 0.0, 1e-3).unwrap(),
            );
        });
        println!(
            "  b={batch:<2} {:>9.2} ms/step ({:>8.0} utt/s)",
            s.mean_ns / 1e6,
            batch as f64 / (s.mean_ns * 1e-9)
        );
    }

    // keep the shared helpers honest even though this bench drives the
    // backend rather than the chip twin
    let _ = common::rng_quant(1);
    b.finish();
}

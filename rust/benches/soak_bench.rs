//! Soak/telemetry benchmarks: the contention the sharded refactor removed.
//!
//! A/Bs the telemetry record primitives under multi-threaded load — one
//! lock-free per-thread histogram shard each (the new worker hot path) vs
//! all threads pushing into a single `Mutex<Vec<u64>>` (the old
//! `Stats.service_us` pattern) — and runs a micro soak end-to-end for a
//! sustained decisions/sec figure.
//!
//! Run: `cargo bench --bench soak_bench` (DELTAKWS_BENCH_SMOKE=1 for CI).

mod common;

use std::sync::Mutex;

use deltakws::chip::ChipConfig;
use deltakws::coordinator::soak::{run_soak, SoakConfig};
use deltakws::util::bench::{black_box, Bench};
use deltakws::util::hist::AtomicLogHistogram;

const THREADS: usize = 4;
const RECORDS: u64 = 8_000;

fn main() {
    let mut b = Bench::new("soak");
    let total = (THREADS as u64 * RECORDS) as f64;

    b.bench_with_items("record: per-thread atomic histogram shards", total, "rec", || {
        let shards: Vec<AtomicLogHistogram> =
            (0..THREADS).map(|_| AtomicLogHistogram::new()).collect();
        std::thread::scope(|s| {
            for (t, shard) in shards.iter().enumerate() {
                s.spawn(move || {
                    for i in 0..RECORDS {
                        shard.record((t as u64 * 37 + i * 13) % 100_000);
                    }
                });
            }
        });
        black_box(shards.iter().map(|h| h.snapshot().count()).sum::<u64>());
    });

    b.bench_with_items("record: one contended Mutex<Vec> (legacy)", total, "rec", || {
        let sink: Mutex<Vec<u64>> = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let sink = &sink;
                s.spawn(move || {
                    for i in 0..RECORDS {
                        sink.lock().unwrap().push((t as u64 * 37 + i * 13) % 100_000);
                    }
                });
            }
        });
        black_box(sink.lock().unwrap().len());
    });

    // end-to-end micro soak: pool spin-up + mixed load + fold
    let smoke = std::env::var("DELTAKWS_BENCH_SMOKE").is_ok();
    let cfg = SoakConfig {
        utterances: if smoke { 150 } else { 2_000 },
        chunks_per_stream: if smoke { 20 } else { 200 },
        ..SoakConfig::quick()
    };
    let label = format!(
        "micro soak: {} utterances, {} workers, {} streams",
        cfg.utterances, cfg.workers, cfg.streams
    );
    b.bench_with_items(&label, cfg.utterances as f64, "dec", || {
        black_box(run_soak(
            common::rng_quant(3),
            ChipConfig::design_point(),
            &cfg,
        ));
    });

    b.finish();
}

//! Bench: paper Fig. 12 — the Δ_TH sweep on the accelerator hot path.
//!
//! Measures simulated-chip metrics (cycles → latency, energy) *and* host
//! simulation throughput per Δ_TH. The chip-side numbers regenerate the
//! Fig. 12 trade-off shape; the host-side numbers are the L3 performance
//! target (EXPERIMENTS.md §Perf: ≥1e5 frames/s/core simulated).

mod common;

use deltakws::accel::{AccelConfig, DeltaRnnAccel};
use deltakws::energy::{self, calib, SramKind};
use deltakws::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("delta_sweep (Fig. 12)");
    let frames = common::feature_stream(7, 256, 0.35, 40);

    println!("chip-side sweep (what the paper measures):");
    println!(
        "{:>6} {:>9} {:>10} {:>9} {:>10}",
        "Δ_TH", "spars%", "lat ms", "E/dec nJ", "frames/s(host)"
    );
    for th in [0i16, 13, 26, 38, 51, 77, 102] {
        let cfg = AccelConfig::design_point().with_delta_th(th);
        // chip metrics on one pass
        let mut probe = DeltaRnnAccel::new(common::rng_quant(1), cfg.clone(), SramKind::NearVth);
        for f in &frames {
            probe.step_frame(f);
        }
        let act = probe.activity;
        let power = energy::chip_power(&act, calib::FEX_DESIGN_UW, SramKind::NearVth);
        let energy_nj = energy::energy_per_decision_nj(&power, &act);

        // host throughput at this sparsity level
        let mut accel = DeltaRnnAccel::new(common::rng_quant(1), cfg, SramKind::NearVth);
        let mut i = 0;
        let stats = b.bench_with_items(&format!("step_frame @ th={th}"), 1.0, "frames", || {
            let r = accel.step_frame(black_box(&frames[i % frames.len()]));
            black_box(r.cycles);
            i += 1;
        });
        println!(
            "{:>6.2} {:>9.1} {:>10.3} {:>9.2} {:>10.0}",
            th as f64 / 256.0,
            act.sparsity() * 100.0,
            act.avg_latency_ms(),
            energy_nj,
            stats.throughput(1.0),
        );
    }
    println!("\npaper anchors: Δ=0 -> 16.4 ms / 121.2 nJ; Δ=0.2 -> 6.9 ms / 36.11 nJ @ 87% (input) sparsity");
    b.finish();
}

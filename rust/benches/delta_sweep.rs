//! Bench: paper Fig. 12 — the Δ_TH sweep on the accelerator hot path.
//!
//! Measures simulated-chip metrics (cycles → latency, energy) *and* host
//! simulation throughput per Δ_TH. The chip-side numbers regenerate the
//! Fig. 12 trade-off shape; the host-side numbers are the L3 performance
//! target (EXPERIMENTS.md §Perf: ≥1e5 frames/s/core simulated).
//!
//! PR 6 adds the **speedup-vs-sparsity curve**: the scalar oracle vs the
//! lane-packed fast datapath vs the 8-session batched stepper across
//! nominal temporal sparsity points (0/25/50/75/87% of moves gated).
//! `tools/bench_report.py` parses the `@ s=N` case labels into the
//! `speedup_vs_sparsity` section of BENCH_N.json.

mod common;

use deltakws::accel::batch::BatchSession;
use deltakws::accel::{AccelConfig, DeltaRnnAccel};
use deltakws::energy::{self, calib, SramKind};
use deltakws::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("delta_sweep (Fig. 12)");
    let frames = common::feature_stream(7, 256, 0.35, 40);

    println!("chip-side sweep (what the paper measures):");
    println!(
        "{:>6} {:>9} {:>10} {:>9} {:>10}",
        "Δ_TH", "spars%", "lat ms", "E/dec nJ", "frames/s(host)"
    );
    for th in [0i16, 13, 26, 38, 51, 77, 102] {
        let cfg = AccelConfig::design_point().with_delta_th(th);
        // chip metrics on one pass
        let mut probe = DeltaRnnAccel::new(common::rng_quant(1), cfg.clone(), SramKind::NearVth);
        for f in &frames {
            probe.step_frame(f);
        }
        let act = probe.activity;
        let power = energy::chip_power(&act, calib::FEX_DESIGN_UW, SramKind::NearVth);
        let energy_nj = energy::energy_per_decision_nj(&power, &act);

        // host throughput at this sparsity level
        let mut accel = DeltaRnnAccel::new(common::rng_quant(1), cfg, SramKind::NearVth);
        let mut i = 0;
        let stats = b.bench_with_items(&format!("step_frame @ th={th}"), 1.0, "frames", || {
            let r = accel.step_frame(black_box(&frames[i % frames.len()]));
            black_box(r.cycles);
            i += 1;
        });
        println!(
            "{:>6.2} {:>9.1} {:>10.3} {:>9.2} {:>10.0}",
            th as f64 / 256.0,
            act.sparsity() * 100.0,
            act.avg_latency_ms(),
            energy_nj,
            stats.throughput(1.0),
        );
    }
    println!("\npaper anchors: Δ=0 -> 16.4 ms / 121.2 nJ; Δ=0.2 -> 6.9 ms / 36.11 nJ @ 87% (input) sparsity");

    // --- speedup vs sparsity: scalar oracle / fast datapath / batched ---
    // nominal sparsity = fraction of frames where an active channel does
    // NOT move past Δ_TH (step > th, so p_move maps straight to firing)
    const BATCH: usize = 8;
    println!("\nhost datapath A/B across temporal sparsity ({BATCH}-session batch):");
    println!(
        "{:>7} {:>9} {:>12} {:>12} {:>13} {:>8} {:>9}",
        "spars%", "measured", "scalar f/s", "simd f/s", "batched f/s", "simd x", "batched x"
    );
    for (pct, p_move) in [(0u32, 1.0f64), (25, 0.75), (50, 0.5), (75, 0.25), (87, 0.13)] {
        let frames = common::feature_stream(900 + pct as u64, 256, p_move, 60);
        let cfg = AccelConfig::design_point();

        // measured lane sparsity on one metrics pass
        let mut meter =
            DeltaRnnAccel::new(common::rng_quant(1), cfg.clone(), SramKind::NearVth);
        for f in &frames {
            meter.step_frame(f);
        }
        let measured = meter.activity.sparsity() * 100.0;

        let mut scalar = DeltaRnnAccel::new(
            common::rng_quant(1),
            cfg.clone().with_simd(false),
            SramKind::NearVth,
        );
        let mut i = 0usize;
        let s_scalar =
            b.bench_with_items(&format!("step_frame scalar @ s={pct}"), 1.0, "frames", || {
                black_box(scalar.step_frame(black_box(&frames[i % frames.len()])));
                i += 1;
            });

        let mut fast = DeltaRnnAccel::new(
            common::rng_quant(1),
            cfg.clone().with_simd(true),
            SramKind::NearVth,
        );
        let mut j = 0usize;
        let s_simd =
            b.bench_with_items(&format!("step_frame simd @ s={pct}"), 1.0, "frames", || {
                black_box(fast.step_frame(black_box(&frames[j % frames.len()])));
                j += 1;
            });

        let mut host =
            DeltaRnnAccel::new(common::rng_quant(1), cfg.with_simd(true), SramKind::NearVth);
        let mut sessions = vec![BatchSession::new(); BATCH];
        let mut t = 0usize;
        let s_batch = b.bench_with_items(
            &format!("step_frames_batched x{BATCH} @ s={pct}"),
            BATCH as f64,
            "frames",
            || {
                let f = &frames[t % frames.len()];
                for sess in sessions.iter_mut() {
                    sess.stage(*f);
                }
                black_box(host.step_frames_batched(&mut sessions));
                t += 1;
            },
        );

        println!(
            "{:>7} {:>9.1} {:>12.0} {:>12.0} {:>13.0} {:>7.2}x {:>8.2}x",
            pct,
            measured,
            s_scalar.throughput(1.0),
            s_simd.throughput(1.0),
            s_batch.throughput(BATCH as f64),
            s_scalar.mean_ns / s_simd.mean_ns,
            s_scalar.mean_ns / (s_batch.mean_ns / BATCH as f64),
        );
    }
    b.finish();
}

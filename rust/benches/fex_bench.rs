//! Bench: paper Fig. 6 + Fig. 7 + Table I — the FEx datapath.
//!
//! * Fig. 6: per-sample cost vs active channel count (host throughput must
//!   scale ~linearly with channels, mirroring the chip's gated slots).
//! * Fig. 7: the three datapath architectures at fixed channels — the
//!   *numerics* are identical; the model's power/area factors are printed.
//! * Table I: sustained sample throughput of the bit-accurate FEx
//!   (real-time factor vs the chip's 8 kHz input).

mod common;

use deltakws::fex::biquad::Arch;
use deltakws::fex::{area, Fex, FexConfig};
use deltakws::util::bench::{black_box, Bench};
use deltakws::util::prng::Pcg;

fn main() {
    let mut b = Bench::new("fex (Fig. 6 / Fig. 7 / Table I)");
    // 1 s of pseudo-speech input
    let mut rng = Pcg::new(3);
    let audio: Vec<i64> = (0..8000)
        .map(|i| {
            let t = i as f64 / 8000.0;
            let v = 0.4 * (2.0 * std::f64::consts::PI * 700.0 * t).sin()
                + 0.2 * (2.0 * std::f64::consts::PI * 1800.0 * t).sin()
                + 0.05 * rng.normal();
            (v.clamp(-0.999, 0.999) * 2047.0) as i64
        })
        .collect();

    println!("Fig. 6 — channel-count scaling (per-sample serial pipeline):");
    for n in [1usize, 4, 10, 16] {
        let mut fex = Fex::new(FexConfig::n_channels(Arch::MixedShift, n));
        let mut i = 0usize;
        let s = b.bench_with_items(&format!("push_sample @ {n}ch"), 1.0, "samples", || {
            black_box(fex.push_sample(black_box(audio[i % audio.len()])));
            i += 1;
        });
        println!(
            "  {n:>2} channels: {:>8.1} ns/sample ({:.0}x real time), model power {:.3} µW",
            s.mean_ns,
            1e9 / s.mean_ns / 8000.0,
            area::power_uw(Arch::MixedShift, n)
        );
    }

    println!("\nFig. 7 — datapath architectures (identical numerics, differing cost model):");
    for (arch, label) in [
        (Arch::Unified16, "baseline 16b-fraction"),
        (Arch::Mixed, "12b/8b mixed"),
        (Arch::MixedShift, "mixed + shift-sub"),
    ] {
        let mut fex = Fex::new(FexConfig::n_channels(arch, 10));
        let mut i = 0usize;
        b.bench_with_items(&format!("push_sample @ {label}"), 1.0, "samples", || {
            black_box(fex.push_sample(black_box(audio[i % audio.len()])));
            i += 1;
        });
    }
    let steps = area::fig7_steps();
    for (i, label) in ["baseline", "+mixed", "+shift"].iter().enumerate() {
        println!(
            "  {label:<10} area x{:.2}  power x{:.2}  (paper: 1/2.6/4.7x area, 1/2.4/5.7x power)",
            steps[i].1, steps[i].2
        );
    }

    println!("\nTable I — whole-utterance featurisation:");
    let mut fex = Fex::new(FexConfig::design_point());
    let s = b.bench_with_items("process 1s utterance @ 10ch", 8000.0, "samples", || {
        fex.reset();
        black_box(fex.process(black_box(&audio)));
    });
    println!(
        "  {:.2} ms per 1 s utterance -> {:.0}x real time",
        s.mean_ns / 1e6,
        1e9 / s.mean_ns
    );
    b.finish();
}

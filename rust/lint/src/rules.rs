//! The invariant catalog as named rules (DESIGN.md §13). Each rule is a
//! token check over cleaned code lines; scopes come from the manifest.

use crate::config::FileScope;
use std::collections::HashSet;

/// The seven checked invariants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rule {
    /// No heap allocation on the frame path.
    NoAllocHotPath,
    /// No locks/condvars on the frame path.
    NoLockHotPath,
    /// No panicking constructs on the frame path (debug_assert! is fine).
    NoPanicHotPath,
    /// Narrowing casts in fixed/ + accel/ route through fixed::sat helpers.
    NarrowingCastDiscipline,
    /// No unbounded mpsc channels anywhere.
    BoundedChannels,
    /// No wall-clock reads outside the observability allowlist.
    NoWallclock,
    /// The crate stays 0-unsafe.
    NoUnsafe,
}

impl Rule {
    /// All rules, in report order.
    pub const ALL: [Rule; 7] = [
        Rule::NoAllocHotPath,
        Rule::NoLockHotPath,
        Rule::NoPanicHotPath,
        Rule::NarrowingCastDiscipline,
        Rule::BoundedChannels,
        Rule::NoWallclock,
        Rule::NoUnsafe,
    ];

    /// Stable rule name — the key used in `lint:allow(name)` and the JSON
    /// report.
    pub fn name(self) -> &'static str {
        match self {
            Rule::NoAllocHotPath => "no-alloc-hot-path",
            Rule::NoLockHotPath => "no-lock-hot-path",
            Rule::NoPanicHotPath => "no-panic-hot-path",
            Rule::NarrowingCastDiscipline => "narrowing-cast-discipline",
            Rule::BoundedChannels => "bounded-channels",
            Rule::NoWallclock => "no-wallclock",
            Rule::NoUnsafe => "no-unsafe",
        }
    }

    /// Why the invariant holds — printed with every finding.
    pub fn rationale(self) -> &'static str {
        match self {
            Rule::NoAllocHotPath => {
                "the frame path is allocation-free: a 10ms audio frame must cost bounded work, like the chip's fixed datapath"
            }
            Rule::NoLockHotPath => {
                "the frame path is lock-free: frame stepping never blocks on a lock, contention lives in the coordinator"
            }
            Rule::NoPanicHotPath => {
                "the frame path is panic-free: invariant violations are debug_assert! + release clamp or typed errors, never aborts"
            }
            Rule::NarrowingCastDiscipline => {
                "narrowing casts wrap silently; Q-format narrowing must saturate through fixed::sat/round_shift like the chip's datapath"
            }
            Rule::BoundedChannels => {
                "every queue is bounded with typed backpressure; an unbounded channel hides memory growth under load"
            }
            Rule::NoWallclock => {
                "golden decision paths are pure functions of the samples; wall-clock reads belong to observability only"
            }
            Rule::NoUnsafe => "the crate is 0-unsafe and stays that way",
        }
    }

    /// Look up a rule by its stable name (for `lint:allow(...)` parsing).
    pub fn from_name(name: &str) -> Option<Rule> {
        Rule::ALL.iter().copied().find(|r| r.name() == name)
    }
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Substring match with an identifier boundary *before* the token (so
/// `assert!` never matches inside `debug_assert!`). Tokens starting with
/// `.` or other punctuation get plain substring semantics.
fn has_token(code: &str, tok: &str) -> bool {
    let first = tok.chars().next().unwrap_or(' ');
    let needs_boundary = is_ident_char(first);
    let mut start = 0usize;
    while let Some(p) = code[start..].find(tok) {
        let at = start + p;
        if !needs_boundary || at == 0 || !is_ident_char(code[..at].chars().next_back().unwrap()) {
            return true;
        }
        start = at + 1;
    }
    false
}

/// Word match with identifier boundaries on both sides (for keywords like
/// `unsafe`).
fn has_word(code: &str, word: &str) -> bool {
    let mut start = 0usize;
    while let Some(p) = code[start..].find(word) {
        let at = start + p;
        let before_ok = at == 0 || !is_ident_char(code[..at].chars().next_back().unwrap());
        let after = code[at + word.len()..].chars().next();
        let after_ok = after.is_none_or(|c| !is_ident_char(c));
        if before_ok && after_ok {
            return true;
        }
        start = at + 1;
    }
    false
}

/// Heap-allocating constructors. `.push(` is handled separately via the
/// Vec-identifier tracker: the ΔFIFO ring also has a `push` method and is
/// exactly the allocation-free structure the rule protects.
const ALLOC_TOKENS: &[&str] = &[
    "Vec::new(",
    "Vec::with_capacity(",
    "vec![",
    "VecDeque::new(",
    "VecDeque::with_capacity(",
    "Box::new(",
    "String::new(",
    "String::with_capacity(",
    "String::from(",
    "format!",
    ".collect(",
    ".collect::<",
    ".to_vec(",
    ".to_string(",
    ".to_owned(",
    ".push_str(",
    "HashMap::new(",
    "HashSet::new(",
    "BTreeMap::new(",
];

/// Growth-method calls flagged on identifiers the tracker proved are
/// Vec/VecDeque bindings.
const VEC_GROW_METHODS: &[&str] = &[
    ".push(",
    ".push_back(",
    ".push_front(",
    ".extend(",
    ".extend_from_slice(",
    ".append(",
    ".resize(",
];

const LOCK_TOKENS: &[&str] = &["Mutex", "RwLock", "Condvar", ".lock("];

const PANIC_TOKENS: &[&str] = &[
    ".unwrap(",
    ".expect(",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
    "assert!",
    "assert_eq!",
    "assert_ne!",
];

/// The three narrowing targets named by the invariant (Q-format lane
/// widths: weights u8/i8, states i16, accumulators i32).
const NARROWING_TOKENS: &[&str] = &["as i16", "as i32", "as u8"];

/// A cast on the same line as one of these is routed through the
/// saturating helpers and compliant.
const SAT_ROUTED_TOKENS: &[&str] = &[
    "sat(",
    "sat32(",
    "round_shift(",
    "floor_shift(",
    "mul_shift_sat(",
    ".clamp(",
    ".saturating_add(",
];

const CHANNEL_TOKENS: &[&str] = &["mpsc::channel(", "mpsc::channel::<"];

const WALLCLOCK_TOKENS: &[&str] = &["Instant::now(", "SystemTime"];

/// `as iN`/`as uN` followed by an identifier char is a different type
/// (e.g. `as i64`), not a narrowing target.
fn has_cast(code: &str, tok: &str) -> bool {
    let mut start = 0usize;
    while let Some(p) = code[start..].find(tok) {
        let at = start + p;
        let before_ok = at == 0 || !is_ident_char(code[..at].chars().next_back().unwrap());
        let after = code[at + tok.len()..].chars().next();
        let after_ok = after.is_none_or(|c| !is_ident_char(c));
        if before_ok && after_ok {
            return true;
        }
        start = at + 1;
    }
    false
}

/// Scan one cleaned line of non-test code. Returns at most one hit per
/// rule (findings are keyed `file:line:rule`). `vec_idents` is the file's
/// set of identifiers proven to be Vec bindings/params/fields.
pub fn check_line(code: &str, scope: FileScope, vec_idents: &HashSet<String>) -> Vec<Rule> {
    let mut hits = Vec::new();
    if scope.hot {
        if ALLOC_TOKENS.iter().any(|t| has_token(code, t))
            || vec_grow_call(code, vec_idents)
        {
            hits.push(Rule::NoAllocHotPath);
        }
        if LOCK_TOKENS.iter().any(|t| has_token(code, t)) {
            hits.push(Rule::NoLockHotPath);
        }
        if PANIC_TOKENS.iter().any(|t| has_token(code, t)) {
            hits.push(Rule::NoPanicHotPath);
        }
    }
    if scope.narrowing
        && NARROWING_TOKENS.iter().any(|t| has_cast(code, t))
        && !SAT_ROUTED_TOKENS.iter().any(|t| has_token(code, t))
    {
        hits.push(Rule::NarrowingCastDiscipline);
    }
    if CHANNEL_TOKENS.iter().any(|t| has_token(code, t)) {
        hits.push(Rule::BoundedChannels);
    }
    if scope.wallclock_banned && WALLCLOCK_TOKENS.iter().any(|t| has_token(code, t)) {
        hits.push(Rule::NoWallclock);
    }
    if has_word(code, "unsafe") {
        hits.push(Rule::NoUnsafe);
    }
    hits
}

/// Does this line call a growth method on a tracked Vec identifier?
fn vec_grow_call(code: &str, vec_idents: &HashSet<String>) -> bool {
    for method in VEC_GROW_METHODS {
        let mut start = 0usize;
        while let Some(p) = code[start..].find(method) {
            let at = start + p;
            // Read the identifier immediately before the `.method(`.
            let ident: String = code[..at]
                .chars()
                .rev()
                .take_while(|c| is_ident_char(*c))
                .collect::<Vec<_>>()
                .into_iter()
                .rev()
                .collect();
            if !ident.is_empty() && vec_idents.contains(&ident) {
                return true;
            }
            start = at + method.len();
        }
    }
    false
}

/// Collect identifiers proven to be Vec bindings on a cleaned line:
/// `let [mut] x: Vec<..>`, `x: &mut Vec<..>` (params), `x: Vec<..>`
/// (struct fields), and `x = Vec::new()/Vec::with_capacity(..)/vec![..]`.
pub fn collect_vec_idents(code: &str, idents: &mut HashSet<String>) {
    // `NAME : [&mut] Vec<` / `VecDeque<`
    for pat in [
        ": Vec<",
        ": &mut Vec<",
        ":Vec<",
        ": VecDeque<",
        ": &mut VecDeque<",
    ] {
        let mut start = 0usize;
        while let Some(p) = code[start..].find(pat) {
            let at = start + p;
            if let Some(name) = ident_before(code, at) {
                idents.insert(name);
            }
            start = at + pat.len();
        }
    }
    // `NAME = Vec::new(` / `= Vec::with_capacity(` / `= vec![` / VecDeque forms
    for pat in [
        "= Vec::new(",
        "= Vec::with_capacity(",
        "= vec![",
        "= VecDeque::new(",
        "= VecDeque::with_capacity(",
    ] {
        let mut start = 0usize;
        while let Some(p) = code[start..].find(pat) {
            let at = start + p;
            if let Some(name) = ident_before(code, at) {
                idents.insert(name);
            }
            start = at + pat.len();
        }
    }
}

/// The identifier ending just before position `at` (skipping trailing
/// whitespace), if any.
fn ident_before(code: &str, at: usize) -> Option<String> {
    let head = code[..at].trim_end();
    let name: String = head
        .chars()
        .rev()
        .take_while(|c| is_ident_char(*c))
        .collect::<Vec<_>>()
        .into_iter()
        .rev()
        .collect();
    if name.is_empty() || name.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        None
    } else {
        Some(name)
    }
}

//! `deltakws-lint` CLI.
//!
//! ```text
//! cargo run -p deltakws-lint                 # scan the repo, exit 1 on findings
//! cargo run -p deltakws-lint -- --json out.json --verbose
//! ```
//!
//! Exit codes: 0 clean, 1 unsuppressed findings, 2 usage/I/O error.

use deltakws_lint::{run, LintConfig};
use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "usage: deltakws-lint [--root DIR] [--config FILE] [--json FILE] [--verbose] [--list-rules]";

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut config_path: Option<PathBuf> = None;
    let mut json_path: Option<PathBuf> = None;
    let mut verbose = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => root = args.next().map(PathBuf::from),
            "--config" => config_path = args.next().map(PathBuf::from),
            "--json" => json_path = args.next().map(PathBuf::from),
            "--verbose" | "-v" => verbose = true,
            "--list-rules" => {
                for rule in deltakws_lint::Rule::ALL {
                    println!("{:<28} {}", rule.name(), rule.rationale());
                }
                return ExitCode::SUCCESS;
            }
            "--help" | "-h" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("deltakws-lint: unknown argument `{other}`\n{USAGE}");
                return ExitCode::from(2);
            }
        }
    }

    // Default root: the workspace root (two levels up from this crate),
    // so `cargo run -p deltakws-lint` works from anywhere in the repo.
    let root = root.unwrap_or_else(|| {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("..")
            .join("..")
    });

    let cfg = match config_path {
        Some(path) => match std::fs::read_to_string(&path) {
            Ok(text) => match LintConfig::parse(&text) {
                Ok(cfg) => cfg,
                Err(e) => {
                    eprintln!("deltakws-lint: {e}");
                    return ExitCode::from(2);
                }
            },
            Err(e) => {
                eprintln!("deltakws-lint: cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        },
        None => LintConfig::builtin(),
    };

    let report = match run(&root, &cfg) {
        Ok(report) => report,
        Err(e) => {
            eprintln!("deltakws-lint: scan failed: {e}");
            return ExitCode::from(2);
        }
    };

    print!("{}", report.to_text(verbose));
    if let Some(path) = json_path {
        if let Err(e) = std::fs::write(&path, report.to_json()) {
            eprintln!("deltakws-lint: cannot write {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }
    if report.unsuppressed().next().is_some() {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

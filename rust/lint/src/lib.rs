//! `deltakws-lint`: repo-native static analysis for the DeltaKWS twin.
//!
//! The chip's claims rest on *verified properties* — saturating Q-format
//! datapaths, clock-gated idle blocks, bounded FIFOs — not conventions.
//! This crate machine-checks the software analogs (DESIGN.md §13): an
//! allocation-/lock-/panic-free frame path, saturating narrowing casts,
//! bounded queues, wall-clock-free golden paths, and a 0-`unsafe` crate.
//!
//! It is a comment/string/`cfg(test)`-aware *token* scanner, not a type
//! checker: rules are conservative textual checks, and every deliberate
//! exception must carry an inline `// lint:allow(rule): <reason>` that the
//! report records. An allow without a reason does not suppress.
//!
//! Pure `std`, zero dependencies — it must build in the offline authoring
//! container and run as a blocking CI job in seconds.

pub mod config;
pub mod report;
pub mod rules;
pub mod scan;

pub use config::{FileScope, LintConfig};
pub use report::{Finding, Report, SCHEMA};
pub use rules::Rule;

use std::collections::HashSet;
use std::io;
use std::path::{Path, PathBuf};

/// Parse every `lint:allow(rule): reason` in a comment. The reason is the
/// text after `):` up to the next stacked `lint:allow(` or end of comment;
/// it may legitimately be empty (which the engine then rejects).
fn parse_allows(comment: &str) -> Vec<(String, String)> {
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(p) = rest.find("lint:allow(") {
        rest = &rest[p + "lint:allow(".len()..];
        let Some(close) = rest.find(')') else { break };
        let rule = rest[..close].trim().to_string();
        rest = &rest[close + 1..];
        let mut reason = String::new();
        if let Some(stripped) = rest.trim_start().strip_prefix(':') {
            let end = stripped.find("lint:allow(").unwrap_or(stripped.len());
            reason = stripped[..end].trim().to_string();
        }
        out.push((rule, reason));
    }
    out
}

/// Lint one source file. `rel_path` (repo-relative, forward slashes)
/// selects the rule scopes from the manifest; `source` is the file text.
/// Returns every hit — suppressed and not — in line order. This is the
/// entry point the selfcheck test drives with inline fixtures.
pub fn scan_source(rel_path: &str, source: &str, cfg: &LintConfig) -> Vec<Finding> {
    let scope = cfg.scope_for(rel_path);
    let lines = scan::clean_source(source);
    let mask = scan::test_mask(&lines);
    let raw_lines: Vec<&str> = source.lines().collect();

    // Pass 1: identifiers proven to be Vec bindings (non-test lines only —
    // a scratch Vec inside #[cfg(test)] must not taint shipping code).
    let mut vec_idents = HashSet::new();
    if scope.hot {
        for (i, line) in lines.iter().enumerate() {
            if !mask[i] {
                rules::collect_vec_idents(&line.code, &mut vec_idents);
            }
        }
    }

    // Pass 2: rule hits + suppression resolution. Allows apply to the line
    // they share (trailing comment) or, from comment-only lines, to the
    // next code line below a contiguous comment run (a blank line breaks
    // the run).
    let mut findings = Vec::new();
    let mut pending_allows: Vec<(String, String)> = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        let code_empty = line.code.trim().is_empty();
        let comment_empty = line.comment.trim().is_empty();
        if code_empty && comment_empty {
            pending_allows.clear(); // blank line ends the comment run
            continue;
        }
        if code_empty {
            pending_allows.extend(parse_allows(&line.comment));
            continue;
        }
        let mut allows = std::mem::take(&mut pending_allows);
        allows.extend(parse_allows(&line.comment));
        if mask[i] {
            continue; // test code: hot-path rules don't apply
        }
        for rule in rules::check_line(&line.code, scope, &vec_idents) {
            let matched = allows.iter().find(|(name, _)| name == rule.name());
            let mut rationale = rule.rationale().to_string();
            let suppressed = match matched {
                Some((_, reason)) if !reason.is_empty() => Some(reason.clone()),
                Some(_) => {
                    rationale.push_str(" (lint:allow without a reason — suppression rejected)");
                    None
                }
                None => None,
            };
            findings.push(Finding {
                file: rel_path.to_string(),
                line: i + 1,
                rule,
                snippet: raw_lines.get(i).map_or("", |s| s.trim()).to_string(),
                rationale,
                suppressed,
            });
        }
    }
    findings
}

/// Recursively collect `.rs` files under the manifest's scan roots, sorted
/// for deterministic report order. Returns repo-relative paths with
/// forward slashes.
pub fn collect_files(root: &Path, cfg: &LintConfig) -> io::Result<Vec<String>> {
    let mut files = Vec::new();
    for scan_root in &cfg.roots {
        let dir = root.join(scan_root);
        if dir.is_dir() {
            walk(&dir, &mut files)?;
        }
    }
    let mut rels: Vec<String> = files
        .iter()
        .filter_map(|p| {
            p.strip_prefix(root)
                .ok()
                .map(|r| r.components().map(|c| c.as_os_str().to_string_lossy()).collect::<Vec<_>>().join("/"))
        })
        .collect();
    rels.sort();
    Ok(rels)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Run the full scan from a repo root. Errors only on I/O failures.
pub fn run(root: &Path, cfg: &LintConfig) -> io::Result<Report> {
    let rels = collect_files(root, cfg)?;
    let mut report = Report::default();
    for rel in &rels {
        let source = std::fs::read_to_string(root.join(rel))?;
        report.findings.extend(scan_source(rel, &source, cfg));
        report.files_scanned += 1;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_parsing_extracts_rule_and_reason() {
        let allows = parse_allows(" lint:allow(no-unsafe): FFI signal registration");
        assert_eq!(allows.len(), 1);
        assert_eq!(allows[0].0, "no-unsafe");
        assert_eq!(allows[0].1, "FFI signal registration");
    }

    #[test]
    fn allow_without_reason_is_kept_but_empty() {
        let allows = parse_allows("lint:allow(no-panic-hot-path)");
        assert_eq!(allows.len(), 1);
        assert!(allows[0].1.is_empty());
    }

    #[test]
    fn stacked_allows_parse_independently() {
        let allows =
            parse_allows("lint:allow(no-alloc-hot-path): opt-in trace lint:allow(no-panic-hot-path): guarded");
        assert_eq!(allows.len(), 2);
        assert_eq!(allows[0].1, "opt-in trace");
        assert_eq!(allows[1].1, "guarded");
    }

    #[test]
    fn builtin_manifest_parses() {
        let cfg = LintConfig::builtin();
        assert!(cfg.scope_for("rust/src/accel/mod.rs").hot);
        assert!(!cfg.scope_for("rust/src/stream/metrics.rs").hot);
        assert!(!cfg.scope_for("rust/src/obs/mod.rs").wallclock_banned);
        assert!(cfg.scope_for("rust/src/coordinator/mod.rs").wallclock_banned);
        assert!(!cfg.scope_for("rust/benches/hotpath_bench.rs").hot);
    }
}

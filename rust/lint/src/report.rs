//! Finding/report types and the two serializations: human text for the
//! terminal, versioned JSON (`deltakws-lint/1`) for the trajectory
//! tooling (`tools/bench_report.py` ingests the counts into
//! `BENCH_<N>.json`).

use crate::rules::Rule;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Schema tag written into every JSON report.
pub const SCHEMA: &str = "deltakws-lint/1";

/// One rule hit at one source line.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Repo-relative path, forward slashes.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Which invariant fired.
    pub rule: Rule,
    /// The trimmed source line.
    pub snippet: String,
    /// Why this is a finding (rule rationale, plus suppression notes).
    pub rationale: String,
    /// `Some(reason)` when a `lint:allow(rule): reason` covers the line.
    pub suppressed: Option<String>,
}

/// A full scan result.
#[derive(Debug, Default)]
pub struct Report {
    /// Every hit, suppressed or not, in file/line order.
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

impl Report {
    /// Hits that still block (no valid suppression).
    pub fn unsuppressed(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.suppressed.is_none())
    }

    /// Hits covered by a reasoned `lint:allow`.
    pub fn suppressed(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.suppressed.is_some())
    }

    /// Per-rule `(unsuppressed, suppressed)` counts, keyed by rule name.
    pub fn per_rule_counts(&self) -> BTreeMap<&'static str, (usize, usize)> {
        let mut map: BTreeMap<&'static str, (usize, usize)> = BTreeMap::new();
        for rule in Rule::ALL {
            map.insert(rule.name(), (0, 0));
        }
        for f in &self.findings {
            let slot = map.entry(f.rule.name()).or_insert((0, 0));
            if f.suppressed.is_none() {
                slot.0 += 1;
            } else {
                slot.1 += 1;
            }
        }
        map
    }

    /// Human-readable report. `verbose` also lists the suppressions.
    pub fn to_text(&self, verbose: bool) -> String {
        let mut out = String::new();
        for f in self.unsuppressed() {
            let _ = writeln!(
                out,
                "{}:{}: [{}] {}\n    {}",
                f.file,
                f.line,
                f.rule.name(),
                f.rationale,
                f.snippet
            );
        }
        if verbose {
            for f in self.suppressed() {
                let _ = writeln!(
                    out,
                    "{}:{}: [{}] suppressed: {}",
                    f.file,
                    f.line,
                    f.rule.name(),
                    f.suppressed.as_deref().unwrap_or("")
                );
            }
        }
        let unsup = self.unsuppressed().count();
        let sup = self.suppressed().count();
        let _ = writeln!(
            out,
            "deltakws-lint: {} file(s) scanned, {} finding(s), {} reasoned suppression(s)",
            self.files_scanned, unsup, sup
        );
        out
    }

    /// Versioned JSON report (`deltakws-lint/1`).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        let _ = writeln!(out, "  \"schema\": \"{SCHEMA}\",");
        let _ = writeln!(out, "  \"files_scanned\": {},", self.files_scanned);
        out.push_str("  \"rules\": [");
        for (i, rule) in Rule::ALL.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let _ = write!(out, "\"{}\"", rule.name());
        }
        out.push_str("],\n");
        let unsup = self.unsuppressed().count();
        let sup = self.suppressed().count();
        out.push_str("  \"counts\": {\n");
        let _ = writeln!(out, "    \"findings\": {unsup},");
        let _ = writeln!(out, "    \"suppressed\": {sup},");
        out.push_str("    \"per_rule\": {\n");
        let per_rule = self.per_rule_counts();
        let n = per_rule.len();
        for (i, (name, (u, s))) in per_rule.into_iter().enumerate() {
            let comma = if i + 1 < n { "," } else { "" };
            let _ = writeln!(
                out,
                "      \"{name}\": {{\"findings\": {u}, \"suppressed\": {s}}}{comma}"
            );
        }
        out.push_str("    }\n  },\n");
        out.push_str("  \"findings\": [\n");
        let unsup_list: Vec<&Finding> = self.unsuppressed().collect();
        for (i, f) in unsup_list.iter().enumerate() {
            let comma = if i + 1 < unsup_list.len() { "," } else { "" };
            let _ = writeln!(
                out,
                "    {{\"file\": {}, \"line\": {}, \"rule\": {}, \"snippet\": {}, \"rationale\": {}}}{comma}",
                json_str(&f.file),
                f.line,
                json_str(f.rule.name()),
                json_str(&f.snippet),
                json_str(&f.rationale)
            );
        }
        out.push_str("  ],\n");
        out.push_str("  \"suppressions\": [\n");
        let sup_list: Vec<&Finding> = self.suppressed().collect();
        for (i, f) in sup_list.iter().enumerate() {
            let comma = if i + 1 < sup_list.len() { "," } else { "" };
            let _ = writeln!(
                out,
                "    {{\"file\": {}, \"line\": {}, \"rule\": {}, \"reason\": {}}}{comma}",
                json_str(&f.file),
                f.line,
                json_str(f.rule.name()),
                json_str(f.suppressed.as_deref().unwrap_or(""))
            );
        }
        out.push_str("  ]\n}\n");
        out
    }
}

/// Minimal JSON string encoder (quotes, backslashes, control chars).
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

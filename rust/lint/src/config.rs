//! Lint manifest: which files the scanner walks and which rule scopes
//! each file falls in. Parsed from `rust/lint/lint.conf` (an INI subset:
//! `[section]`, `key = comma, separated, values`, `#` comments). The
//! committed manifest is embedded at compile time so the binary's default
//! can never drift from the file on disk.

/// Parsed manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LintConfig {
    /// Directories to walk for `.rs` files, relative to the repo root.
    pub roots: Vec<String>,
    /// Hot-set path prefixes, relative to `rust/src/`.
    pub hot_include: Vec<String>,
    /// Exclusions carved out of the hot set (construction-time / post-hoc
    /// modules), relative to `rust/src/`.
    pub hot_exclude: Vec<String>,
    /// Modules under narrowing-cast discipline, relative to `rust/src/`.
    pub narrowing_include: Vec<String>,
    /// The only `rust/src/` locations where wall-clock reads are allowed.
    pub wallclock_allow: Vec<String>,
}

/// Rule scopes for one file, resolved from its repo-relative path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FileScope {
    /// Frame-path file: alloc/lock/panic rules apply.
    pub hot: bool,
    /// Narrowing-cast discipline applies.
    pub narrowing: bool,
    /// Wall-clock reads are banned here.
    pub wallclock_banned: bool,
}

impl LintConfig {
    /// The committed manifest, embedded at compile time.
    pub const MANIFEST: &'static str = include_str!("../lint.conf");

    /// Built-in default: the embedded manifest. Panics only if the
    /// committed `lint.conf` is syntactically invalid, which the selfcheck
    /// test guards against.
    pub fn builtin() -> Self {
        Self::parse(Self::MANIFEST).expect("embedded lint.conf parses")
    }

    /// Parse a manifest. Unknown sections/keys are rejected so typos in
    /// the config can't silently widen or narrow a rule's scope.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut cfg = LintConfig {
            roots: Vec::new(),
            hot_include: Vec::new(),
            hot_exclude: Vec::new(),
            narrowing_include: Vec::new(),
            wallclock_allow: Vec::new(),
        };
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                return Err(format!("lint.conf:{}: expected `key = values`", lineno + 1));
            };
            let values: Vec<String> = value
                .split(',')
                .map(|v| v.trim().to_string())
                .filter(|v| !v.is_empty())
                .collect();
            let slot = match (section.as_str(), key.trim()) {
                ("scan", "roots") => &mut cfg.roots,
                ("hot-path", "include") => &mut cfg.hot_include,
                ("hot-path", "exclude") => &mut cfg.hot_exclude,
                ("narrowing", "include") => &mut cfg.narrowing_include,
                ("wallclock", "allow") => &mut cfg.wallclock_allow,
                (s, k) => {
                    return Err(format!("lint.conf:{}: unknown key [{s}] {k}", lineno + 1));
                }
            };
            *slot = values;
        }
        if cfg.roots.is_empty() {
            return Err("lint.conf: [scan] roots must not be empty".into());
        }
        Ok(cfg)
    }

    /// Resolve the rule scopes for a repo-relative path (forward slashes).
    /// Hot-path, narrowing, and wallclock rules only ever apply under
    /// `rust/src/`; benches and examples are scanned for the global rules
    /// (`bounded-channels`, `no-unsafe`) only.
    pub fn scope_for(&self, rel_path: &str) -> FileScope {
        let src = rel_path.strip_prefix("rust/src/");
        let starts = |prefixes: &[String], s: &str| prefixes.iter().any(|p| s.starts_with(p.as_str()));
        let hot = src.is_some_and(|s| {
            starts(&self.hot_include, s) && !starts(&self.hot_exclude, s)
        });
        let narrowing = src.is_some_and(|s| starts(&self.narrowing_include, s));
        let wallclock_banned = src.is_some_and(|s| !starts(&self.wallclock_allow, s));
        FileScope { hot, narrowing, wallclock_banned }
    }
}

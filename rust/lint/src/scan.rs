//! Comment/string/`cfg(test)`-aware source cleaning.
//!
//! The rule engine works on *cleaned* text, line by line: comment bodies
//! and string/char-literal contents are blanked to spaces so token rules
//! never fire inside prose or literals, while comment text is kept
//! separately so `lint:allow(...)` suppressions can be read back from it.
//! Line numbers are preserved exactly (one `CleanLine` per physical line).

/// One physical source line after cleaning.
#[derive(Debug, Clone, Default)]
pub struct CleanLine {
    /// Code with comments and string/char-literal contents blanked out.
    /// The delimiting quotes survive so `format!("...")` still reads as
    /// `format!(" ")` — token rules anchored on the macro name keep firing.
    pub code: String,
    /// Concatenated comment text on this line, without the `//` / `/* */`
    /// markers. This is where `lint:allow(rule): reason` lives.
    pub comment: String,
}

enum Mode {
    Code,
    /// Nested block comments: `/* /* */ */` is one comment in Rust.
    Block(usize),
    /// Normal `"..."` or byte `b"..."` string (may span lines).
    Str,
    /// Raw string `r##"..."##` with N hashes.
    RawStr(usize),
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Detects `r"`, `r#"`, `br"`, ... at position `i`. Returns
/// `(hash_count, chars_consumed_through_opening_quote)`. Raw *identifiers*
/// (`r#match`) don't match because no quote follows the hashes.
fn raw_string_start(chars: &[char], i: usize) -> Option<(usize, usize)> {
    if i > 0 && is_ident_char(chars[i - 1]) {
        return None; // identifier ending in `b`/`r`, not a literal prefix
    }
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

/// Does the `"` at position `i` close a raw string with `hashes` hashes?
fn closes_raw(chars: &[char], i: usize, hashes: usize) -> bool {
    (1..=hashes).all(|k| chars.get(i + k) == Some(&'#'))
}

/// Length of a char literal starting at the `'` at position `i`, or `None`
/// if this is a lifetime (`'a`, `'static`). Handles `'x'`, `'\n'`, `'\''`
/// and `'\u{1F600}'`.
fn char_literal_len(chars: &[char], i: usize) -> Option<usize> {
    let c1 = *chars.get(i + 1)?;
    if c1 == '\\' {
        let mut j = i + 2;
        if chars.get(j) == Some(&'u') {
            while j < chars.len() && chars[j] != '}' {
                j += 1;
            }
        }
        j += 1;
        if chars.get(j) == Some(&'\'') {
            Some(j + 1 - i)
        } else {
            None
        }
    } else if c1 != '\'' && chars.get(i + 2) == Some(&'\'') {
        Some(3)
    } else {
        None
    }
}

/// Split `src` into cleaned lines. Total line count always equals the
/// physical line count of the input.
pub fn clean_source(src: &str) -> Vec<CleanLine> {
    let chars: Vec<char> = src.chars().collect();
    let mut out = Vec::new();
    let mut line = CleanLine::default();
    let mut mode = Mode::Code;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            out.push(std::mem::take(&mut line));
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                let next = chars.get(i + 1).copied().unwrap_or('\0');
                if c == '/' && next == '/' {
                    // Line comment (incl. `///` and `//!` doc forms): the
                    // rest of the line is comment text.
                    let mut j = i + 2;
                    while j < chars.len() && (chars[j] == '/' || chars[j] == '!') {
                        j += 1;
                    }
                    while j < chars.len() && chars[j] != '\n' {
                        line.comment.push(chars[j]);
                        j += 1;
                    }
                    line.code.push(' ');
                    i = j;
                } else if c == '/' && next == '*' {
                    mode = Mode::Block(1);
                    line.code.push(' ');
                    i += 2;
                } else if c == '"' {
                    line.code.push('"');
                    mode = Mode::Str;
                    i += 1;
                } else if let Some((hashes, consumed)) = raw_string_start(&chars, i) {
                    line.code.push('"');
                    mode = Mode::RawStr(hashes);
                    i += consumed;
                } else if c == '\'' {
                    if let Some(len) = char_literal_len(&chars, i) {
                        line.code.push('\'');
                        line.code.push(' ');
                        line.code.push('\'');
                        i += len;
                    } else {
                        line.code.push(c); // lifetime marker
                        i += 1;
                    }
                } else {
                    line.code.push(c);
                    i += 1;
                }
            }
            Mode::Block(depth) => {
                let next = chars.get(i + 1).copied().unwrap_or('\0');
                if c == '*' && next == '/' {
                    mode = if depth == 1 { Mode::Code } else { Mode::Block(depth - 1) };
                    i += 2;
                } else if c == '/' && next == '*' {
                    mode = Mode::Block(depth + 1);
                    i += 2;
                } else {
                    line.comment.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                let next = chars.get(i + 1).copied().unwrap_or('\0');
                if c == '\\' && next != '\n' {
                    line.code.push(' '); // skip the escaped char ("\"", "\\", ...)
                    i += 2;
                } else if c == '\\' {
                    line.code.push(' '); // trailing `\`: string continues next line
                    i += 1;
                } else if c == '"' {
                    line.code.push('"');
                    mode = Mode::Code;
                    i += 1;
                } else {
                    line.code.push(' ');
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if c == '"' && closes_raw(&chars, i, hashes) {
                    line.code.push('"');
                    mode = Mode::Code;
                    i += 1 + hashes;
                } else {
                    line.code.push(' ');
                    i += 1;
                }
            }
        }
    }
    out.push(line);
    out
}

/// Per-line "is test code" mask: `true` for lines inside a `#[cfg(test)]`
/// or `#[test]` item (the attribute line, the body, and the closing brace).
/// Test code is exempt from the hot-path rules — `unwrap` in a unit test
/// is idiomatic, not a finding.
pub fn test_mask(lines: &[CleanLine]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut depth: i32 = 0;
    // Saw the attribute; waiting for the item's `{` (or `;` for bodyless
    // forms like `#[cfg(test)] mod tests;`).
    let mut pending = false;
    // Brace depth *outside* the test item while inside one.
    let mut floor: Option<i32> = None;
    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;
        if floor.is_none()
            && (code.contains("#[test]")
                || code.contains("cfg(test)")
                || code.contains("cfg(all(test"))
        {
            pending = true;
        }
        let mut in_test = pending || floor.is_some();
        for ch in code.chars() {
            match ch {
                '{' => {
                    if pending {
                        floor = Some(depth);
                        pending = false;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if let Some(f) = floor {
                        if depth <= f {
                            floor = None;
                            in_test = true; // the closing-brace line itself
                        }
                    }
                }
                ';' => {
                    if pending && floor.is_none() {
                        pending = false;
                    }
                }
                _ => {}
            }
        }
        mask[idx] = in_test || pending || floor.is_some();
    }
    mask
}

//! API-compatible stub for the `xla` PJRT bindings.
//!
//! The real crate links libxla_extension (PJRT CPU client + HLO parsing),
//! which cannot be vendored in this offline environment. This stub exposes
//! the exact API surface `deltakws::runtime::pjrt` consumes so the `pjrt`
//! feature still *compiles*; every entry point that would need the real
//! runtime returns [`Error::Unavailable`] instead, and the backend factory
//! falls back to the pure-Rust native backend.
//!
//! Host-side [`Literal`] bookkeeping (shape/data/convert) is implemented for
//! real so unit tests of the conversion layer keep working.

use std::fmt;

/// Stub error: every PJRT-backed operation reports unavailability.
#[derive(Debug, Clone)]
pub enum Error {
    /// The PJRT runtime is not linked in this build.
    Unavailable(&'static str),
    /// Host-side usage error (shape mismatch etc.).
    Usage(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the real PJRT bindings (libxla_extension), \
                 which are not vendored in this build"
            ),
            Error::Usage(msg) => write!(f, "xla stub: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the host-side literal layer understands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
}

/// Marker trait for element types usable with [`Literal::vec1`]/[`Literal::to_vec`].
pub trait NativeType: Copy {
    const TY: PrimitiveType;
    fn to_f32(self) -> f32;
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    const TY: PrimitiveType = PrimitiveType::F32;
    fn to_f32(self) -> f32 {
        self
    }
    fn from_f32(v: f32) -> Self {
        v
    }
}

impl NativeType for i32 {
    const TY: PrimitiveType = PrimitiveType::S32;
    fn to_f32(self) -> f32 {
        self as f32
    }
    fn from_f32(v: f32) -> Self {
        v as i32
    }
}

/// Host-side array shape.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host-side literal: flat f32 storage + shape + nominal element type.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
    ty: PrimitiveType,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            data: data.iter().map(|v| v.to_f32()).collect(),
            dims: vec![data.len() as i64],
            ty: T::TY,
        }
    }

    /// Reshape (element count must match; rank-0 scalars use `&[]`).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let want = if dims.is_empty() { 1 } else { n };
        if want as usize != self.data.len() {
            return Err(Error::Usage(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec(), ty: self.ty })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn convert(&self, ty: PrimitiveType) -> Result<Literal> {
        Ok(Literal { data: self.data.clone(), dims: self.dims.clone(), ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Decompose a tuple literal. The stub never produces tuples (execution
    /// is unavailable), so this only errors.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple on an execution result"))
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed HLO module (stub: never constructible from text).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT device buffer (stub: never constructed).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub: never constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: AsRef<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (stub: creation fails, signalling callers to fall back).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn scalar_reshape() {
        let l = Literal::vec1(&[7.0f32]);
        let s = l.reshape(&[]).unwrap();
        assert!(s.array_shape().unwrap().dims().is_empty());
    }

    #[test]
    fn int_literals_convert() {
        let l = Literal::vec1(&[1i32, -2, 3]);
        let f = l.convert(PrimitiveType::F32).unwrap();
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![1.0, -2.0, 3.0]);
    }

    #[test]
    fn runtime_paths_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}

//! Serving API v2 integration: tickets, mailboxes, typed errors, builders.
//!
//! The acceptance surface of the redesign:
//! * builder validation — `ChipConfig::builder` / `Coordinator::builder` /
//!   `RunConfig::chip_config_checked` reject out-of-range knobs with
//!   `Error::InvalidConfig` (and the legacy `with_*` setters clamp, with a
//!   debug assertion);
//! * error paths — queue-full hands the request back intact and is
//!   retryable; post-shutdown submits report `Closed`; in-flight tickets
//!   resolve (response or `Closed`) instead of hanging;
//! * ticket semantics — `wait_timeout` returns the ticket inside
//!   `Timeout` so the wait can resume; batches resolve in submission
//!   order.

use std::time::Duration;

use deltakws::accel::gru::QuantParams;
use deltakws::chip::{ChipConfig, DELTA_TH_MAX_Q8};
use deltakws::config::RunConfig;
use deltakws::coordinator::{Coordinator, Request};
use deltakws::util::prng::Pcg;
use deltakws::{Error, SubmitError, WaitError};

fn rng_quant(seed: u64) -> QuantParams {
    let mut rng = Pcg::new(seed);
    let mut q = QuantParams::zeroed();
    q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
    q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q
}

fn short_request(stream: u64, seed: u64) -> Request {
    let mut rng = Pcg::new(seed);
    let label = (seed % 12) as usize;
    let audio = deltakws::audio::synth_utterance(label, &mut rng);
    Request {
        id: 0,
        stream,
        audio12: deltakws::audio::quantize_12b(&audio[..1024]),
        label: Some(label),
        trace: false,
        weights: None,
    }
}

// ---------------------------------------------------------------------------
// builder validation
// ---------------------------------------------------------------------------

#[test]
fn chip_builder_rejects_out_of_range_knobs() {
    for bad in [0usize, 17, 99] {
        let err = ChipConfig::builder().channels(bad).build().unwrap_err();
        assert!(
            matches!(err, Error::InvalidConfig { field: "channels", .. }),
            "channels={bad}: wrong error {err}"
        );
    }
    for bad in [-1i16, DELTA_TH_MAX_Q8 + 1, i16::MAX] {
        let err = ChipConfig::builder().delta_th_q8(bad).build().unwrap_err();
        assert!(
            matches!(err, Error::InvalidConfig { field: "delta_th_q8", .. }),
            "delta_th={bad}: wrong error {err}"
        );
    }
    // boundary values are valid
    for (ch, th) in [(1usize, 0i16), (16, DELTA_TH_MAX_Q8)] {
        let cfg = ChipConfig::builder().channels(ch).delta_th_q8(th).build().unwrap();
        assert_eq!(cfg.fex.num_active(), ch);
        assert_eq!(cfg.accel.delta_th_q8, th);
    }
}

#[test]
fn run_config_surfaces_invalid_chip_settings() {
    let mut cfg = RunConfig::default();
    assert!(cfg.chip_config_checked().is_ok());
    cfg.channels = 0;
    assert!(cfg.chip_config_checked().is_err(), "0-channel config accepted");
    cfg.channels = 10;
    cfg.delta_th_q8 = -5;
    assert!(cfg.chip_config_checked().is_err(), "negative Θ accepted");
}

#[test]
#[cfg_attr(debug_assertions, should_panic(expected = "channels"))]
fn legacy_channel_setter_clamps_or_asserts() {
    // debug builds: the debug assertion fires (should_panic above);
    // release builds: the value clamps into range instead of silently
    // configuring a chip with n > 16 "channels"
    let cfg = ChipConfig::design_point().with_channels(99);
    assert_eq!(cfg.fex.num_active(), 16);
    assert!(cfg.validate().is_ok());
}

#[test]
#[cfg_attr(debug_assertions, should_panic(expected = "delta_th_q8"))]
fn legacy_delta_setter_clamps_or_asserts() {
    let cfg = ChipConfig::design_point().with_delta_th(i16::MAX);
    assert_eq!(cfg.accel.delta_th_q8, DELTA_TH_MAX_Q8);
    assert!(cfg.validate().is_ok());
}

#[test]
fn coordinator_builder_validates_chip_config_too() {
    // an invalid chip config assembled by hand is caught at pool build
    let mut chip = ChipConfig::design_point();
    chip.accel.delta_th_q8 = -1;
    let err = Coordinator::builder(rng_quant(1), chip)
        .build()
        .err()
        .expect("invalid chip config must be rejected at pool build");
    assert!(matches!(err, Error::InvalidConfig { .. }));
}

// ---------------------------------------------------------------------------
// error paths
// ---------------------------------------------------------------------------

#[test]
fn queue_full_hands_the_request_back_intact() {
    let coord = Coordinator::builder(rng_quant(2), ChipConfig::design_point())
        .workers(1)
        .queue_depth(1)
        .build()
        .unwrap();
    coord.set_stalled(0, true);
    let original = short_request(3, 7);
    let (audio, label) = (original.audio12.clone(), original.label);
    let mut tickets = Vec::new();
    let mut req = original;
    let mut rejections = 0;
    // saturate: 1 in the worker's hands + 1 queued, then rejection
    loop {
        match coord.submit(req) {
            Ok(t) => {
                tickets.push(t);
                req = short_request(3, 7);
            }
            Err(e) => {
                assert!(e.is_queue_full());
                assert!(!e.is_closed());
                let back = e.into_request().expect("QueueFull hands the request back");
                assert_eq!(back.audio12, audio, "payload mutated in rejection");
                assert_eq!(back.label, label);
                rejections += 1;
                if rejections >= 3 {
                    break;
                }
                req = back; // a rejected request is directly resubmittable
            }
        }
        assert!(tickets.len() < 8, "queue of 1 never saturated");
    }
    assert!(coord.stats().rejected_full >= 3);
    coord.set_stalled(0, false);
    for t in tickets {
        t.wait_timeout(Duration::from_secs(300)).expect("accepted request lost");
    }
}

#[test]
fn post_shutdown_submit_reports_closed_and_tickets_resolve() {
    let coord = Coordinator::builder(rng_quant(3), ChipConfig::design_point())
        .workers(2)
        .queue_depth(4)
        .build()
        .unwrap();
    let client = coord.client();
    // a request in flight when the pool drops: the shutdown drain either
    // completes it (response claimable) or the mailbox closes — the wait
    // must resolve promptly either way, never hang
    let pending = client.submit(short_request(0, 11)).expect("live pool");
    drop(coord);
    assert!(client.is_closed());
    match pending.wait_timeout(Duration::from_secs(60)) {
        Ok(resp) => assert_eq!(resp.stream, 0),
        Err(WaitError::Closed) => {}
        Err(WaitError::Timeout(_)) => panic!("post-shutdown wait hung until timeout"),
    }
    // further submits: typed Closed, payload intact
    let original = short_request(1, 12);
    let audio = original.audio12.clone();
    match client.submit(original) {
        Err(SubmitError::Closed(back)) => assert_eq!(back.audio12, audio),
        Err(e) => panic!("dead pool must report Closed, got {e}"),
        Ok(_) => panic!("submit into a dropped pool succeeded"),
    }
}

// ---------------------------------------------------------------------------
// ticket semantics
// ---------------------------------------------------------------------------

#[test]
fn timeout_hands_the_ticket_back_and_the_wait_resumes() {
    let coord = Coordinator::builder(rng_quant(4), ChipConfig::design_point())
        .workers(1)
        .queue_depth(4)
        .build()
        .unwrap();
    coord.set_stalled(0, true);
    let ticket = coord.submit(short_request(0, 21)).expect("live pool");
    let id = ticket.id();
    // stalled worker: a short wait must time out and return the ticket
    let ticket = match ticket.wait_timeout(Duration::from_millis(30)) {
        Err(WaitError::Timeout(t)) => t,
        other => panic!("expected Timeout with the ticket back, got {other:?}"),
    };
    assert_eq!(ticket.id(), id, "a different ticket came back");
    coord.set_stalled(0, false);
    // the same ticket still claims the (same) response
    let resp = ticket.wait_timeout(Duration::from_secs(300)).expect("resumed wait failed");
    assert_eq!(resp.id, id);
}

#[test]
fn batch_waits_resolve_in_submission_order() {
    let coord = Coordinator::builder(rng_quant(5), ChipConfig::design_point())
        .workers(2)
        .queue_depth(4)
        .build()
        .unwrap();
    let reqs: Vec<Request> = (0..8).map(|i| short_request(i % 3, 30 + i)).collect();
    let batch = coord.submit_batch(reqs).expect("live pool");
    assert_eq!(batch.len(), 8);
    let ids = batch.ids();
    let responses = batch.wait_all(Duration::from_secs(300));
    assert_eq!(responses.len(), 8, "batch lost responses");
    let got: Vec<u64> = responses.iter().map(|r| r.id).collect();
    assert_eq!(got, ids, "responses out of submission order");
    // every response carries its own request's stream
    for (resp, i) in responses.iter().zip(0u64..) {
        assert_eq!(resp.stream, i % 3);
    }
}

//! Property-based tests over the crate invariants (util::check harness).
//!
//! The big ones: Δ-network ≡ dense network at Θ=0 for *any* weights and
//! input sequence (bit-exact on the integer datapath), encoder/reference
//! reconstruction, FIFO conservation, fixed-point laws, JSON roundtrip,
//! and coordinator request conservation under arbitrary arrival patterns.

use deltakws::accel::encoder::{encode, DeltaEvent};
use deltakws::accel::fifo::Fifo;
use deltakws::accel::gru::{QuantParams, C, H};
use deltakws::accel::{AccelConfig, DeltaRnnAccel};
use deltakws::baseline::DenseGruAccel;
use deltakws::chip::{ChipConfig, KwsChip};
use deltakws::energy::SramKind;
use deltakws::fixed;
use deltakws::util::check::forall;
use deltakws::util::json::{self, Json};
use deltakws::util::prng::Pcg;

fn arb_quant(rng: &mut Pcg) -> QuantParams {
    let mut q = QuantParams::zeroed();
    q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(256) as i8).wrapping_sub(0));
    q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q.b.iter_mut().for_each(|w| *w = (rng.below(512) as i16) - 256);
    q.w_fc.iter_mut().flatten().for_each(|w| *w = rng.below(256) as i8);
    q
}

fn arb_frame(rng: &mut Pcg) -> [i16; C] {
    let mut f = [0i16; C];
    for slot in f.iter_mut().take(14).skip(4) {
        *slot = rng.below(256) as i16;
    }
    f
}

#[test]
fn prop_delta_zero_threshold_equals_dense_bit_exact() {
    forall(20, |rng| {
        let q = arb_quant(rng);
        let steps = rng.below(12) + 2;
        let cfg = AccelConfig::design_point().with_delta_th(0);
        let mut delta = DeltaRnnAccel::new(q.clone(), cfg.clone(), SramKind::NearVth);
        let mut dense = DenseGruAccel::new(q, cfg.active_x, SramKind::NearVth);
        for _ in 0..steps {
            let f = arb_frame(rng);
            let rd = delta.step_frame(&f);
            let ld = dense.step_frame(&f);
            assert_eq!(rd.logits, ld, "Θ=0 Δ != dense");
        }
    });
}

#[test]
fn prop_sparsity_and_cost_monotone_in_threshold() {
    forall(10, |rng| {
        let q = arb_quant(rng);
        let frames: Vec<[i16; C]> = (0..20).map(|_| arb_frame(rng)).collect();
        let mut prev_reads = u64::MAX;
        for th in [0i16, 26, 51, 102, 204] {
            let cfg = AccelConfig::design_point().with_delta_th(th);
            let mut accel = DeltaRnnAccel::new(q.clone(), cfg, SramKind::NearVth);
            for f in &frames {
                accel.step_frame(f);
            }
            // x-side deltas are gated harder as th grows; total SRAM traffic
            // must never increase with threshold
            assert!(
                accel.sram.reads <= prev_reads,
                "SRAM reads increased with threshold at th={th}"
            );
            prev_reads = accel.sram.reads;
        }
    });
}

#[test]
fn prop_encoder_reconstruction() {
    // fired lanes: ref' = cur and emitted delta = cur - old_ref;
    // silent lanes: ref' = old_ref. The decoder can reconstruct cur for
    // every fired lane: old_ref + delta == cur.
    forall(200, |rng| {
        let n = rng.below(64) + 1;
        let cur: Vec<i16> = (0..n).map(|_| (rng.below(65536) as i32 - 32768) as i16).collect();
        let old_refs: Vec<i16> =
            (0..n).map(|_| (rng.below(65536) as i32 - 32768) as i16).collect();
        let th = rng.below(300) as i16;
        let mut refs = old_refs.clone();
        let mut out = Vec::new();
        encode(&cur, &mut refs, th, &mut out);
        for ev in &out {
            let lane = ev.lane as usize;
            assert_eq!(old_refs[lane] as i32 + ev.delta, cur[lane] as i32);
            assert_eq!(refs[lane], cur[lane]);
        }
        let fired: std::collections::HashSet<u16> = out.iter().map(|e| e.lane).collect();
        for lane in 0..n {
            if !fired.contains(&(lane as u16)) {
                assert_eq!(refs[lane], old_refs[lane], "silent lane moved its ref");
            }
        }
    });
}

#[test]
fn prop_fifo_conservation_and_order() {
    forall(200, |rng| {
        let cap = rng.below(16) + 1;
        let mut fifo: Fifo<u32> = Fifo::new(cap);
        let mut model: std::collections::VecDeque<u32> = Default::default();
        let mut next = 0u32;
        for _ in 0..rng.below(200) {
            if rng.uniform() < 0.55 {
                let v = next;
                next += 1;
                match fifo.push(v) {
                    Ok(()) => model.push_back(v),
                    Err(rejected) => {
                        assert_eq!(rejected, v);
                        assert_eq!(model.len(), cap, "rejected while not full");
                    }
                }
            } else {
                assert_eq!(fifo.pop(), model.pop_front());
            }
            assert_eq!(fifo.len(), model.len());
            assert!(fifo.len() <= cap);
        }
        // drain: order preserved
        while let Some(v) = fifo.pop() {
            assert_eq!(Some(v), model.pop_front());
        }
        assert!(model.is_empty());
    });
}

#[test]
fn prop_fixed_point_laws() {
    forall(500, |rng| {
        let bits = rng.below(30) as u32 + 4;
        // keep |v| < 2^50 so the f64 comparison below is exact
        let v = rng.next_u64() as i64 >> (rng.below(10) + 14);
        let s = fixed::sat(v, bits);
        assert!(fixed::fits(s, bits));
        if fixed::fits(v, bits) {
            assert_eq!(s, v, "sat changed an in-range value");
        }
        // round_shift halves-away and is within 1 of the float result
        let sh = rng.below(16) as u32;
        let r = fixed::round_shift(v, sh) as f64;
        let exact = v as f64 / (1u64 << sh) as f64;
        assert!((r - exact).abs() <= 0.5 + 1e-9, "round_shift err {r} vs {exact}");
    });
}

#[test]
fn prop_log2_linear_bounds() {
    forall(500, |rng| {
        let v = ((rng.next_u64() >> 1) >> rng.below(40)).max(1) as i64;
        let approx = fixed::log2_linear(v, 12) as f64 / 4096.0;
        let exact = (v as f64).log2();
        // log2 is concave, so the chord (linear mantissa interp) never
        // overshoots; quantisation of the fraction can add up to 1 LSB
        assert!(approx <= exact + 1.0 / 4096.0, "v={v}: interp above curve");
        assert!((approx - exact).abs() < 0.09, "v={v}: {approx} vs {exact}");
    });
}

#[test]
fn prop_json_roundtrip() {
    fn arb_json(rng: &mut Pcg, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform() < 0.5),
            2 => Json::Num((rng.below(2_000_001) as f64 - 1_000_000.0) / 64.0),
            3 => {
                let n = rng.below(12);
                Json::Str((0..n).map(|_| char::from(32 + rng.below(94) as u8)).collect())
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| arb_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), arb_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall(300, |rng| {
        let j = arb_json(rng, 3);
        let text = j.to_string();
        let parsed = json::parse(&text).unwrap_or_else(|e| panic!("reparse failed: {e}\n{text}"));
        assert_eq!(parsed, j, "roundtrip mismatch for {text}");
    });
}

#[test]
fn prop_delta_events_bounded_by_lanes() {
    forall(100, |rng| {
        let q = arb_quant(rng);
        let cfg = AccelConfig::design_point().with_delta_th(rng.below(128) as i16);
        let n_act = cfg.n_active();
        let mut accel = DeltaRnnAccel::new(q, cfg, SramKind::NearVth);
        for _ in 0..rng.below(10) + 1 {
            let r = accel.step_frame(&arb_frame(rng));
            assert!(r.fired <= n_act + H);
            // cycle floor and ceiling
            assert!(r.cycles >= deltakws::energy::calib::CYCLES_FIXED);
            let max_cycles = deltakws::energy::calib::CYCLES_FIXED
                + (n_act + H) as u64 * deltakws::energy::calib::CYCLES_PER_LANE;
            assert!(r.cycles <= max_cycles);
        }
    });
}

#[test]
fn prop_vad_gated_idle_segments_never_mutate_hidden_state() {
    // the streaming pipeline's functional-safety invariant: however
    // poll_frame and skip_frame interleave, a gated (VAD-idle) frame must
    // leave the ΔRNN state buffer and SRAM traffic bit-identical, while
    // still advancing the energy model's frame clock
    forall(12, |rng| {
        let q = arb_quant(rng);
        let th = rng.below(128) as i16;
        let mut chip =
            KwsChip::new(q, ChipConfig::design_point().with_delta_th(th));
        // random 12-bit audio, 8..24 frames worth
        let n_samples = 128 * (rng.below(17) + 8);
        let audio: Vec<i64> = (0..n_samples).map(|_| rng.below(4096) as i64 - 2048).collect();
        chip.push_samples(&audio).expect("audio fits the frame buffer");
        let mut gated_seen = 0u64;
        while chip.pending_frames() > 0 {
            if rng.uniform() < 0.5 {
                let before = chip.accel.state().clone();
                let reads = chip.accel.sram.reads;
                let cycles = chip.accel.activity.rnn_cycles;
                let frames = chip.accel.activity.frames;
                let f = chip.skip_frame().unwrap();
                assert!(f.gated && f.cycles == 0 && f.fired == 0);
                assert_eq!(*chip.accel.state(), before, "gated frame mutated ΔRNN state");
                assert_eq!(chip.accel.sram.reads, reads, "gated frame read SRAM");
                assert_eq!(chip.accel.activity.rnn_cycles, cycles, "gated frame cost cycles");
                assert_eq!(chip.accel.activity.frames, frames + 1, "frame clock stalled");
                gated_seen += 1;
            } else {
                let f = chip.poll_frame().unwrap();
                assert!(!f.gated);
            }
        }
        assert_eq!(chip.activity().gated_frames, gated_seen);
    });
}

#[test]
fn prop_quantize_dequantize_within_lsb() {
    use deltakws::fixed::QFormat;
    forall(500, |rng| {
        let bits = rng.below(14) as u32 + 4;
        let frac = rng.below(bits as usize) as u32;
        let q = QFormat::new(bits, frac);
        let v = rng.range_f64(q.min_value(), q.max_value());
        assert!(q.error(v) <= q.lsb() / 2.0 + 1e-12, "fmt Q{bits}.{frac} v={v}");
    });
}

#[test]
fn prop_encode_is_idempotent_when_nothing_changes() {
    forall(200, |rng| {
        let n = rng.below(32) + 1;
        let cur: Vec<i16> = (0..n).map(|_| rng.below(512) as i16 - 256).collect();
        let mut refs = vec![0i16; n];
        let mut out: Vec<DeltaEvent> = Vec::new();
        let th = rng.below(64) as i16;
        encode(&cur, &mut refs, th, &mut out);
        // second encode with the same input must fire nothing
        let mut out2 = Vec::new();
        let fired2 = encode(&cur, &mut refs, th, &mut out2);
        // lanes that fired are now at ref == cur; lanes that did not fire
        // still differ by < th, so nothing can fire
        assert_eq!(fired2, 0, "encode not idempotent (th={th})");
    });
}

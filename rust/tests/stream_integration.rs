//! Streaming-subsystem integration: the acceptance criteria of the
//! always-on pipeline.
//!
//! * streaming ≡ batch: feeding an utterance through `push_samples` /
//!   `poll_frame` in random-sized chunks reproduces `process_utterance`
//!   bit for bit — decisions, logits, cycle and feature traces — on 100
//!   utterances across every class;
//! * VAD gating is free of functional side effects (gated frames never
//!   touch the ΔRNN) and strictly cheaper on the energy model;
//! * coordinator stream sessions conserve audio and deliver detections
//!   in order, whichever workers end up running the session's chain.

use deltakws::accel::gru::QuantParams;
use deltakws::accel::{AccelConfig, DeltaRnnAccel};
use deltakws::energy::SramKind;
use deltakws::fex::MAX_CHANNELS;
use deltakws::audio::track::{synth_track, TrackConfig};
use deltakws::chip::{ChipConfig, DecisionAccum, KwsChip};
use deltakws::coordinator::{Coordinator, StreamEvent};
use deltakws::dataset::{Dataset, Split};
use deltakws::probe::TraceProbe;
use deltakws::stream::vad::VadConfig;
use deltakws::stream::{StreamConfig, StreamPipeline};
use deltakws::util::prng::Pcg;

fn rng_quant(seed: u64) -> QuantParams {
    let mut rng = Pcg::new(seed);
    let mut q = QuantParams::zeroed();
    q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
    q.b.iter_mut().for_each(|w| *w = (rng.below(512) as i16) - 256);
    q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q
}

#[test]
fn streaming_equals_batch_bit_exact_on_100_utterances() {
    let ds = Dataset::new(0xACCE);
    let mut batch = KwsChip::new(rng_quant(1), ChipConfig::design_point());
    let mut stream = KwsChip::new(rng_quant(1), ChipConfig::design_point());
    let mut chunk_rng = Pcg::new(0xC0FFEE);
    for i in 0..100usize {
        let utt = ds.utterance(Split::Test, i);
        let (want, want_trace) = batch.process_utterance_traced(&utt.audio12);

        stream.reset();
        let mut probe = TraceProbe::default();
        let mut acc = DecisionAccum::new(stream.config.warmup);
        let mut off = 0usize;
        while off < utt.audio12.len() {
            // random chunk sizes: 1..=977 samples, crossing frame
            // boundaries in every possible alignment over 100 utterances
            let n = (chunk_rng.below(977) + 1).min(utt.audio12.len() - off);
            stream
                .push_samples(&utt.audio12[off..off + n])
                .expect("chunk fits the frame buffer");
            off += n;
            while let Some(f) = stream.poll_frame_probed(&mut probe) {
                acc.push(&f);
            }
        }
        let got = acc.finish();

        // lean decisions agree field-for-field (Decision is Copy + Eq now)
        assert_eq!(got, want, "utt {i}: decision diverged");
        // and the TraceProbe reconstructs the batch traces bit for bit
        let trace = probe.take_trace();
        assert_eq!(trace.frame_cycles, want_trace.frame_cycles, "utt {i}: cycle trace diverged");
        assert_eq!(trace.frame_fired, want_trace.frame_fired, "utt {i}: fired trace diverged");
        assert_eq!(trace.feat_trace, want_trace.feat_trace, "utt {i}: feature trace diverged");
    }
}

#[test]
fn gated_frames_have_no_functional_side_effects() {
    // skip a 40-frame prefix through the VAD-gate path, then prove the
    // skipped frames left zero trace on the ΔRNN: a *fresh* accelerator
    // stepped directly with only the suffix features must reproduce every
    // suffix logit bit for bit
    let q = rng_quant(3);
    let cfg = TrackConfig { duration_s: 2, keywords: 1, fillers: 0, noise: (0.001, 0.002) };
    let (audio12, _) = synth_track(&cfg, 17);

    let mut gated = KwsChip::new(q.clone(), ChipConfig::design_point());
    gated.push_samples(&audio12).expect("track fits the frame buffer");
    let state0 = gated.accel.state().clone();
    for _ in 0..40 {
        gated.skip_frame().unwrap();
    }
    assert_eq!(*gated.accel.state(), state0, "skip_frame mutated the ΔRNN");
    let mut suffix = Vec::new();
    while let Some(f) = gated.poll_frame() {
        suffix.push(f);
    }
    assert!(!suffix.is_empty());
    assert_eq!(gated.activity().gated_frames, 40);

    let mut fresh = DeltaRnnAccel::new(q, AccelConfig::design_point(), SramKind::NearVth);
    for (t, f) in suffix.iter().enumerate() {
        let mut qf = [0i16; MAX_CHANNELS];
        for (c, &v) in f.feat.iter().enumerate() {
            qf[c] = (v >> 3) as i16;
        }
        let r = fresh.step_frame(&qf);
        assert_eq!(r.logits, f.logits, "suffix frame {t}: gated prefix leaked state");
    }
}

#[test]
fn vad_gating_is_strictly_cheaper_and_functionally_gated() {
    let cfg = TrackConfig { duration_s: 8, keywords: 2, fillers: 1, noise: (0.001, 0.002) };
    let (audio12, _) = synth_track(&cfg, 23);
    let run = |vad: VadConfig| {
        let mut p = StreamPipeline::new(
            rng_quant(5),
            StreamConfig::design_point().with_vad(vad),
        );
        for c in audio12.chunks(320) {
            p.push_audio(c).expect("chunk fits");
        }
        let a = p.chip.activity();
        (a.gated_frames, a.mac_ops, a.sram_word_reads, p.report().power.total_uw())
    };
    let (g_gated, g_macs, g_reads, g_power) = run(VadConfig::design_point());
    let (o_gated, o_macs, o_reads, o_power) = run(VadConfig::disabled());
    assert_eq!(o_gated, 0);
    assert!(g_gated > 0, "VAD never gated");
    assert!(g_macs < o_macs, "gating must elide MACs: {g_macs} !< {o_macs}");
    assert!(g_reads < o_reads, "gating must elide SRAM reads");
    assert!(g_power < o_power, "gating must cut average power");
}

#[test]
fn vad_cold_start_reopens_after_real_silence() {
    // a track that begins mid-keyword seeds the adaptive noise floor with
    // speech energy (there was never a quiet frame to learn from). The
    // pinned contract: whatever happens to that cold first keyword, once
    // real silence establishes a floor the gate must open again for the
    // next keyword instead of staying poisoned by the speech-level floor.
    let mut rng = Pcg::new(41);
    let utt = deltakws::audio::quantize_12b(&deltakws::audio::synth_utterance(5, &mut rng));
    let mut p = StreamPipeline::new(rng_quant(11), StreamConfig::design_point());

    // begin mid-keyword: drop the onset, start inside full speech
    p.push_audio(&utt[2048..]).expect("chunk fits");
    let cold = p.chip.activity();
    assert!(cold.frames > 0);

    // 3 s of true silence: the floor drops instantly to the real level
    let silence = vec![0i64; 3 * 8000];
    p.push_audio(&silence).expect("chunk fits");
    let after_silence = p.chip.activity();
    assert!(
        after_silence.gated_frames > cold.gated_frames,
        "sustained silence never gated the ΔRNN"
    );

    // a second keyword (with onset) must clock the ΔRNN again
    let mut rng2 = Pcg::new(42);
    let utt2 = deltakws::audio::quantize_12b(&deltakws::audio::synth_utterance(7, &mut rng2));
    p.push_audio(&utt2).expect("chunk fits");
    let end = p.chip.activity();
    let ungated_before = after_silence.frames - after_silence.gated_frames;
    let ungated_after = end.frames - end.gated_frames;
    assert!(
        ungated_after >= ungated_before + 5,
        "gate failed to reopen after a cold start: {} -> {} ungated frames",
        ungated_before,
        ungated_after
    );
}

#[test]
fn coordinator_sessions_conserve_frames_wherever_the_chain_runs() {
    // two sessions on a 3-worker pool, interleaved with batch requests:
    // every chunk of a stream must be processed (frame conservation) and
    // events must flow back asynchronously, regardless of which workers
    // the v3 scheduler lands each chunk chain on
    let coord = Coordinator::builder(rng_quant(7), ChipConfig::design_point())
        .workers(3)
        .queue_depth(8)
        .build()
        .expect("valid pool");
    let cfg = TrackConfig { duration_s: 4, keywords: 2, fillers: 0, noise: (0.001, 0.002) };
    let (audio12, _) = synth_track(&cfg, 31);
    let s1 = coord.open_stream(10).expect("under the high-water mark");
    let s2 = coord.open_stream(11).expect("under the high-water mark");
    for c in audio12.chunks(640) {
        s1.push_blocking(c.to_vec()).expect("pool alive");
        s2.push_blocking(c.to_vec()).expect("pool alive");
    }
    let frames_expected = (audio12.len() / deltakws::FRAME_SAMPLES) as u64;
    for sess in [s1, s2] {
        let events = sess.close();
        let closed = events.iter().find_map(|e| match e {
            StreamEvent::Closed { frames, gated_frames, .. } => Some((*frames, *gated_frames)),
            _ => None,
        });
        let (frames, gated) = closed.expect("no Closed marker");
        assert_eq!(frames, frames_expected, "session lost frames");
        assert!(gated < frames, "session gated everything");
    }
    let stats = coord.stats();
    let chunks: u64 = stats.per_worker.iter().map(|w| w.stream_chunks).sum();
    assert_eq!(chunks, 2 * audio12.chunks(640).count() as u64);
    assert!(stats.activity.frames >= 2 * frames_expected);
}

//! Probe-layer acceptance (PR 5 tentpole): the instrumented and
//! uninstrumented datapaths are the *same* datapath.
//!
//! * `NoProbe` vs `TraceProbe` over 100 seeded utterances: identical lean
//!   decisions (logits, class, counted frames, cycle totals) and identical
//!   [`ChipActivity`] — the probe cannot perturb the arithmetic, the
//!   cycle model, or the energy accounting;
//! * the `TraceProbe` reconstruction is internally consistent with the
//!   lean decision (trace sums == decision totals);
//! * `CountingProbe` hook cadence matches the activity counters on the
//!   full chip (not just the bare accelerator);
//! * the probed path also composes with VAD gating (skip_frame) without
//!   divergence.

use deltakws::accel::gru::QuantParams;
use deltakws::chip::{ChipConfig, DecisionAccum, KwsChip};
use deltakws::dataset::{Dataset, Split};
use deltakws::probe::{CountingProbe, TraceProbe};
use deltakws::stream::vad::VadConfig;
use deltakws::stream::{StreamConfig, StreamPipeline};
use deltakws::util::prng::Pcg;

fn rng_quant(seed: u64) -> QuantParams {
    let mut rng = Pcg::new(seed);
    let mut q = QuantParams::zeroed();
    q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
    q.b.iter_mut().for_each(|w| *w = (rng.below(512) as i16) - 256);
    q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q
}

#[test]
fn noprobe_and_traceprobe_are_bit_exact_on_100_utterances() {
    let ds = Dataset::new(0x5B0B);
    let mut lean_chip = KwsChip::new(rng_quant(1), ChipConfig::design_point());
    let mut traced_chip = KwsChip::new(rng_quant(1), ChipConfig::design_point());
    for i in 0..100usize {
        let utt = ds.utterance(Split::Test, i);
        let lean = lean_chip.process_utterance(&utt.audio12);
        let (traced, trace) = traced_chip.process_utterance_traced(&utt.audio12);
        // identical lean decisions: class, logits, counted frames, totals
        assert_eq!(lean, traced, "utt {i}: probe changed the decision");
        // the trace is consistent with the lean totals
        assert_eq!(trace.len(), traced.frames as usize, "utt {i}: trace length");
        assert_eq!(
            trace.frame_cycles.iter().sum::<u64>(),
            traced.total_cycles,
            "utt {i}: trace cycles don't sum to the decision total"
        );
        let fired: u64 = trace.frame_fired.iter().map(|&f| f as u64).sum();
        assert!(fired > 0, "utt {i}: nothing ever fired");
    }
    // and the aggregated chip activity (energy model input) is identical
    assert_eq!(
        lean_chip.activity(),
        traced_chip.activity(),
        "probe perturbed the activity counters"
    );
}

#[test]
fn counting_probe_cadence_matches_chip_activity() {
    let ds = Dataset::new(0xC0DE);
    let mut chip = KwsChip::new(rng_quant(2), ChipConfig::design_point());
    let mut probe = CountingProbe::default();
    for i in 0..8usize {
        let utt = ds.utterance(Split::Test, i);
        chip.process_utterance_probed(&utt.audio12, &mut probe);
    }
    let a = chip.activity();
    assert_eq!(probe.frames, a.frames);
    assert_eq!(probe.gated, a.gated_frames);
    assert_eq!(probe.fired_x, a.fired_x);
    assert_eq!(probe.fired_h, a.fired_h);
    // every fired lane streams one weight row; every ungated frame adds
    // the 64 FC rows — and the words they cover are exactly the SRAM reads
    assert_eq!(probe.sram_words, a.sram_word_reads);
}

#[test]
fn probed_path_composes_with_vad_gating() {
    // drive two chips through an identical poll/skip interleave, one with
    // a TraceProbe attached: decisions, activity and gated accounting all
    // agree, and the trace records the gated frames with zero cycles
    let audio: Vec<i64> = {
        let mut rng = Pcg::new(7);
        deltakws::audio::quantize_12b(&deltakws::audio::synth_utterance(9, &mut rng))
    };
    let mut lean = KwsChip::new(rng_quant(3), ChipConfig::design_point());
    let mut probed = KwsChip::new(rng_quant(3), ChipConfig::design_point());
    lean.push_samples(&audio).expect("utterance fits");
    probed.push_samples(&audio).expect("utterance fits");
    let mut probe = TraceProbe::default();
    let mut acc_lean = DecisionAccum::new(4);
    let mut acc_probed = DecisionAccum::new(4);
    let mut pattern = Pcg::new(99);
    while lean.pending_frames() > 0 {
        let skip = pattern.uniform() < 0.4;
        let (a, b) = if skip {
            (lean.skip_frame().unwrap(), probed.skip_frame_probed(&mut probe).unwrap())
        } else {
            (lean.poll_frame().unwrap(), probed.poll_frame_probed(&mut probe).unwrap())
        };
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.gated, b.gated);
        acc_lean.push(&a);
        acc_probed.push(&b);
    }
    let (da, db) = (acc_lean.finish(), acc_probed.finish());
    assert_eq!(da, db);
    assert!(da.gated_frames > 0, "interleave never gated");
    assert_eq!(lean.activity(), probed.activity());
    // gated frames appear in the trace with zero cycles and zero fired
    let trace = probe.take_trace();
    assert_eq!(trace.len(), da.frames as usize);
    let gated_in_trace =
        trace.frame_cycles.iter().zip(&trace.frame_fired).filter(|(&c, &f)| c == 0 && f == 0);
    assert!(gated_in_trace.count() >= da.gated_frames as usize);
}

#[test]
fn stream_pipeline_matches_probed_chip_drive() {
    // the StreamPipeline (production path, NoProbe) and a hand-driven
    // probed chip fed the same audio with the same VAD decisions agree on
    // every frame — the streaming layer adds no hidden datapath work
    let cfg = deltakws::audio::track::TrackConfig {
        duration_s: 3,
        keywords: 1,
        fillers: 0,
        noise: (0.001, 0.002),
    };
    let (audio12, _) = deltakws::audio::track::synth_track(&cfg, 5);
    let mut pipe = StreamPipeline::new(rng_quant(4), StreamConfig::design_point());
    for c in audio12.chunks(512) {
        pipe.push_audio(c).expect("chunk fits");
    }
    // replay: same chip + same VAD config, probed, driven by a fresh VAD
    // over the same features must reproduce the pipeline's activity
    let mut chip = KwsChip::new(rng_quant(4), ChipConfig::design_point());
    let mut vad = deltakws::stream::vad::Vad::new(VadConfig::design_point());
    let mut probe = TraceProbe::default();
    for c in audio12.chunks(512) {
        chip.push_samples(c).expect("chunk fits");
        while let Some(&feat) = chip.peek_frame() {
            if vad.step(&feat) {
                chip.poll_frame_probed(&mut probe).unwrap();
            } else {
                chip.skip_frame_probed(&mut probe).unwrap();
            }
        }
    }
    assert_eq!(chip.activity(), pipe.chip.activity(), "pipeline diverged from probed replay");
    assert_eq!(probe.trace.len() as u64, pipe.chip.activity().frames);
}

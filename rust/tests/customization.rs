//! Per-user customization integration (PR 9): few-shot enrollment, the
//! versioned weight registry, and the epoch-fenced mid-stream hot-swap.
//!
//! The acceptance surface of the customization tentpole:
//! * the epoch fence is *bit-exact*: after a mid-stream swap, every
//!   post-fence frame is bit-identical to a fresh accelerator on the new
//!   version seeded from the captured fence state — and no frame is
//!   dropped or duplicated across the fence;
//! * a coordinator stream survives the swap live: `Closed` accounts for
//!   every pushed frame, `WeightsSwapped` acknowledges the fence, and
//!   detections flip their `weights` tag at the fence;
//! * enrollment is deterministic: two runs from the same seed produce a
//!   byte-identical SRAM image and therefore the same content-hashed
//!   [`WeightVersion`];
//! * K ≤ 8 enrollment measurably improves held-out target-keyword
//!   accuracy for the synthetic speaker vs the base model;
//! * LRU pressure never evicts a pinned version (pool base, live
//!   sessions), and eviction/unknown-version failures surface through the
//!   typed error tree with the version payload preserved.

use deltakws::accel::gru::QuantParams;
use deltakws::chip::{ChipConfig, KwsChip};
use deltakws::coordinator::{Coordinator, StreamEvent};
use deltakws::custom::{few_shot, EnrollConfig, RegistryError, SpeakerVoice, WeightVersion};
use deltakws::runtime::NativeBackend;
use deltakws::util::prng::Pcg;
use deltakws::Error;

fn rng_quant(seed: u64) -> QuantParams {
    let mut rng = Pcg::new(seed);
    let mut q = QuantParams::zeroed();
    q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
    q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q
}

/// Drain every buffered frame, returning the weight-dependent outputs.
fn drain(chip: &mut KwsChip) -> Vec<([i64; deltakws::NUM_CLASSES], usize, u64)> {
    let mut out = Vec::new();
    while let Some(f) = chip.poll_frame() {
        out.push((f.logits, f.fired, f.cycles));
    }
    out
}

#[test]
fn mid_stream_swap_is_epoch_fenced_and_bit_exact() {
    let base = rng_quant(1);
    let next = rng_quant(2);
    let cfg = ChipConfig::design_point();
    let mut rng = Pcg::new(77);
    let audio = deltakws::audio::quantize_12b(&deltakws::audio::synth_utterance(11, &mut rng));
    let half = audio.len() / 2;

    // chip A: run the first half on the base weights, swap at the frame
    // boundary, run the second half on the new weights
    let mut a = KwsChip::new(base, cfg.clone());
    a.push_samples(&audio[..half]).expect("first half fits");
    let pre_fence = drain(&mut a);
    let fence_state = a.accel.state().clone();
    a.swap_weights(next.clone());
    a.push_samples(&audio[half..]).expect("second half fits");
    let post_a = drain(&mut a);

    // chip B: a fresh session on the new version, seeded with the fence
    // state. The same audio runs through its FEx first (feature
    // extraction is weight-independent, so the filter state matches),
    // then the captured recurrent state replaces whatever B computed.
    let mut b = KwsChip::new(next, cfg);
    b.push_samples(&audio[..half]).expect("first half fits");
    let discard = drain(&mut b);
    assert_eq!(discard.len(), pre_fence.len(), "frame framing diverged before the fence");
    b.accel.set_state(fence_state);
    b.push_samples(&audio[half..]).expect("second half fits");
    let post_b = drain(&mut b);

    // zero dropped or duplicated frames across the fence ...
    assert_eq!(
        pre_fence.len() + post_a.len(),
        deltakws::FRAMES_PER_DECISION,
        "frames lost or duplicated across the swap"
    );
    // ... and bit-identical post-fence outputs and final recurrent state
    assert_eq!(post_a, post_b, "post-fence frames diverged from the fresh session");
    assert_eq!(a.accel.state(), b.accel.state(), "final recurrent state diverged");
}

#[test]
fn coordinator_stream_survives_the_swap_with_full_accounting() {
    let coord = Coordinator::builder(rng_quant(3), ChipConfig::design_point())
        .workers(1)
        .build()
        .expect("valid pool");
    let v2 = coord.registry().insert(rng_quant(4), Some(coord.base_version()));
    let base_version = coord.base_version();

    let mut rng = Pcg::new(9);
    let audio = deltakws::audio::quantize_12b(&deltakws::audio::synth_utterance(5, &mut rng));
    let half = audio.len() / 2;

    let sess = coord.open_stream(0).expect("under the high-water mark");
    sess.push_blocking(audio[..half].to_vec()).expect("pool alive");
    coord.swap_weights(&sess, v2).expect("swap accepted");
    sess.push_blocking(audio[half..].to_vec()).expect("pool alive");
    let events = sess.close();

    let mut fence_frame = None;
    let mut closed_frames = None;
    for e in &events {
        match e {
            StreamEvent::WeightsSwapped { version, frame, .. } => {
                assert_eq!(*version, v2, "fence installed the wrong version");
                fence_frame = Some(*frame);
            }
            StreamEvent::Closed { frames, .. } => closed_frames = Some(*frames),
            StreamEvent::Detection { weights, .. } => {
                // the serving tag flips exactly at the fence
                let expect = if fence_frame.is_none() { base_version } else { v2 };
                assert_eq!(*weights, expect, "detection served by the wrong version");
            }
        }
    }
    let fence = fence_frame.expect("swap never acknowledged");
    let total = closed_frames.expect("no close event");
    assert_eq!(
        total,
        deltakws::FRAMES_PER_DECISION as u64,
        "frames dropped or duplicated across the live swap"
    );
    assert!(fence <= total, "fence frame beyond the stream");

    let stats = coord.stats();
    assert_eq!(stats.weight_swaps, 1, "swap not counted");
    assert!(stats.resident_versions >= 2);
    assert_eq!(coord.registry().pins(v2), 0, "session pin leaked after close");
}

#[test]
fn enrolling_twice_from_the_same_seed_is_byte_identical() {
    let backend = NativeBackend::new();
    let base = rng_quant(5);
    let mut cfg = EnrollConfig::design_point(9, 10);
    cfg.steps = 6; // determinism is step-count independent; keep it quick
    let a = few_shot(&backend, &base, &cfg).expect("enrollment");
    let b = few_shot(&backend, &base, &cfg).expect("enrollment");
    assert_eq!(
        deltakws::accel::gru::to_sram_image(&a.params),
        deltakws::accel::gru::to_sram_image(&b.params),
        "same seed, different SRAM image"
    );
    assert_eq!(
        WeightVersion::of(&a.params),
        WeightVersion::of(&b.params),
        "content addressing broke"
    );
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.final_loss, b.final_loss);
}

#[test]
fn enrollment_improves_heldout_target_accuracy() {
    let backend = NativeBackend::new();
    let base = rng_quant(6);
    let cfg = EnrollConfig::design_point(9, 11);
    let enrolled = few_shot(&backend, &base, &cfg).expect("enrollment");

    let chip_cfg = ChipConfig::design_point();
    let voice = SpeakerVoice::new(9);
    let held = voice.holdout(11, 16);
    let hits = |p: &QuantParams| {
        let mut chip = KwsChip::new(p.clone(), chip_cfg.clone());
        held.iter().filter(|u| chip.process_utterance(&u.audio12).class == 11).count()
    };
    let (before, after) = (hits(&base), hits(&enrolled.params));
    assert!(
        after > before,
        "enrollment did not improve held-out accuracy: {before}/16 -> {after}/16"
    );
    assert!(
        enrolled.final_loss.is_finite() && enrolled.final_loss >= 0.0,
        "bad final loss {}",
        enrolled.final_loss
    );
}

#[test]
fn coordinator_enroll_registers_lineage_and_is_idempotent() {
    let coord = Coordinator::builder(rng_quant(7), ChipConfig::design_point())
        .workers(1)
        .build()
        .expect("valid pool");
    let mut cfg = EnrollConfig::design_point(4, 9);
    cfg.steps = 4;
    let first = coord.enroll(None, cfg.clone()).expect("enrollment");
    assert_eq!(first.parent, coord.base_version());
    assert_eq!(
        coord.registry().lineage(first.version),
        vec![first.version, coord.base_version()],
        "lineage broken"
    );
    // deterministic: enrolling again lands on the very same version id
    let second = coord.enroll(None, cfg).expect("enrollment");
    assert_eq!(second.version, first.version, "same seed minted a new version");
    let stats = coord.stats();
    assert_eq!(stats.enroll_latency.count(), 2, "enrollment latency not recorded");
    assert!(stats.resident_versions >= 2);

    // unknown parent: typed Error::Registry with the payload preserved
    let bogus = WeightVersion::of(&rng_quant(404));
    match coord.enroll(Some(bogus), EnrollConfig::design_point(4, 9)) {
        Err(e) => match e.downcast_ref::<Error>() {
            Some(Error::Registry(r)) => {
                assert!(matches!(r, RegistryError::UnknownVersion(_)));
                assert_eq!(r.version(), bogus, "version payload lost");
            }
            other => panic!("expected Error::Registry, got {other:?}"),
        },
        Ok(_) => panic!("unknown parent accepted"),
    }
}

#[test]
fn lru_pressure_never_evicts_pinned_versions() {
    let coord = Coordinator::builder(rng_quant(8), ChipConfig::design_point())
        .workers(1)
        .registry_capacity(2)
        .build()
        .expect("valid pool");
    let reg = coord.registry();
    let v2 = reg.insert(rng_quant(20), Some(coord.base_version()));
    let sess = coord.open_stream_with_weights(0, None, v2).expect("v2 resident");
    assert!(reg.pins(v2) >= 1, "open_stream_with_weights must pin");

    // churn far past capacity: only unpinned versions may be evicted
    let churn: Vec<WeightVersion> = (0..6).map(|i| reg.insert(rng_quant(100 + i), None)).collect();
    assert!(reg.contains(coord.base_version()), "pool base evicted");
    assert!(reg.contains(v2), "live session's pinned version evicted");
    assert!(reg.get(v2).is_ok());

    // the oldest churn version is gone — Evicted, with the id preserved
    let evicted = churn[0];
    assert!(!reg.contains(evicted), "LRU never evicted under pressure");
    let err = reg.get(evicted).expect_err("evicted version still resident");
    assert!(matches!(err, RegistryError::Evicted(_)), "wrong error: {err}");
    assert_eq!(err.version(), evicted, "version payload lost");

    // ... and through the serving surface as the typed Error tree
    match coord.swap_weights(&sess, evicted) {
        Err(Error::Registry(e)) => assert_eq!(e.version(), evicted),
        other => panic!("expected Error::Registry(Evicted), got {other:?}"),
    }
    let bogus = WeightVersion::of(&rng_quant(500));
    match coord.swap_weights(&sess, bogus) {
        Err(Error::Registry(RegistryError::UnknownVersion(v))) => assert_eq!(v, bogus),
        other => panic!("expected UnknownVersion, got {other:?}"),
    }

    // re-registering an evicted version resurrects it (content hash and
    // lineage unchanged)
    let back = reg.insert(rng_quant(100), None);
    assert_eq!(back, evicted, "resurrection changed the content hash");
    assert!(reg.get(evicted).is_ok());

    sess.close();
    assert_eq!(reg.pins(v2), 0, "session pin leaked after close");
}

//! Flight-recorder + trace-propagation integration (PR 7).
//!
//! The acceptance surface of the observability tentpole:
//! * every submission gets a unique, nonzero [`TraceId`], returned on the
//!   [`Response`] and stamped on the recorder's events — a dump and the
//!   response that triggered it correlate by id alone;
//! * an [`AnomalyRule`] freezes a post-mortem [`FlightDump`] whose
//!   trace-correlated timeline covers the request's whole life
//!   (Submit → Dequeue → FrameBatch → Decision);
//! * the recorder is an *observer*: a pool with the ring enabled produces
//!   bit-identical decisions (class, logits, counted frames, chip cycles)
//!   to a pool without one;
//! * stream sessions carry their trace on every [`StreamEvent`];
//! * [`Coordinator::metrics`] exposes the recorder section and sequences
//!   its snapshots.

use deltakws::accel::gru::QuantParams;
use deltakws::audio::track::{synth_track, TrackConfig};
use deltakws::chip::ChipConfig;
use deltakws::coordinator::{Coordinator, Request, StreamEvent};
use deltakws::obs::recorder::{AnomalyRule, EventKind, RecorderConfig};
use deltakws::util::prng::Pcg;

fn rng_quant(seed: u64) -> QuantParams {
    let mut rng = Pcg::new(seed);
    let mut q = QuantParams::zeroed();
    q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
    q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q
}

fn request(id: u64, stream: u64, seed: u64) -> Request {
    let mut rng = Pcg::new(seed);
    let label = (seed % 12) as usize;
    let audio = deltakws::audio::synth_utterance(label, &mut rng);
    Request {
        id,
        stream,
        audio12: deltakws::audio::quantize_12b(&audio),
        label: Some(label),
        trace: false,
        weights: None,
    }
}

#[test]
fn responses_carry_unique_nonzero_trace_ids() {
    let coord = Coordinator::builder(rng_quant(1), ChipConfig::design_point())
        .workers(2)
        .build()
        .expect("valid pool");
    let mut seen = std::collections::HashSet::new();
    for i in 0..12u64 {
        let resp = coord
            .submit(request(i, i % 3, 100 + i))
            .expect("pool accepts")
            .wait()
            .expect("pool alive");
        assert!(!resp.trace_id.is_none(), "req {i}: trace id missing");
        assert!(seen.insert(resp.trace_id.0), "req {i}: trace id {} reused", resp.trace_id);
    }
}

#[test]
fn anomaly_rule_freezes_a_trace_correlated_dump() {
    // LatencyAboveUs { us: 0 } fires on any completed decision (a full
    // utterance decode costs well over a microsecond), so one submission
    // yields exactly one frozen dump whose trigger is that decision
    let coord = Coordinator::builder(rng_quant(2), ChipConfig::design_point())
        .workers(1)
        .recorder(RecorderConfig::default().dump_on(AnomalyRule::LatencyAboveUs { us: 0 }))
        .build()
        .expect("valid pool");
    let resp = coord
        .submit(request(7, 0, 42))
        .expect("pool accepts")
        .wait()
        .expect("pool alive");

    let dumps = coord.flight_dumps();
    assert_eq!(dumps.len(), 1, "one decision, one frozen dump");
    let dump = &dumps[0];
    assert!(
        matches!(dump.rule, AnomalyRule::LatencyAboveUs { us: 0 }),
        "wrong rule on the dump: {:?}",
        dump.rule
    );
    assert!(
        matches!(dump.trigger.kind, EventKind::Decision { .. }),
        "trigger is not the decision: {:?}",
        dump.trigger.kind
    );
    assert_eq!(dump.trigger.trace, resp.trace_id, "trigger not correlated to the response");

    // the trace-correlated timeline covers the request's whole life
    let timeline = dump.events_for(resp.trace_id);
    let has = |pred: &dyn Fn(&EventKind) -> bool| timeline.iter().any(|e| pred(&e.kind));
    assert!(has(&|k| matches!(k, EventKind::Submit)), "no Submit in {timeline:?}");
    assert!(has(&|k| matches!(k, EventKind::Dequeue { .. })), "no Dequeue in {timeline:?}");
    assert!(
        has(&|k| matches!(k, EventKind::FrameBatch { frames, .. } if *frames > 0)),
        "no FrameBatch in {timeline:?}"
    );
    assert!(has(&|k| matches!(k, EventKind::Decision { .. })), "no Decision in {timeline:?}");

    // timestamps are monotonic within the frozen ring
    for w in dump.events.windows(2) {
        assert!(w[0].at_us <= w[1].at_us, "timeline not monotonic: {w:?}");
    }
    // drained once — a second take sees nothing
    assert!(coord.flight_dumps().is_empty(), "dumps not drained");
}

#[test]
fn recorder_pool_is_bit_identical_to_lean_pool() {
    let run = |with_recorder: bool| {
        let mut builder =
            Coordinator::builder(rng_quant(3), ChipConfig::design_point()).workers(1);
        if with_recorder {
            builder = builder
                .recorder(RecorderConfig::default().dump_on(AnomalyRule::LatencyAboveUs { us: 0 }));
        }
        let coord = builder.build().expect("valid pool");
        let mut out = Vec::new();
        for i in 0..8u64 {
            // sequential submits on one worker: identical job order, so the
            // chip twin sees the identical utterance sequence in both pools
            let resp = coord
                .submit(request(i, 0, 500 + i))
                .expect("pool accepts")
                .wait()
                .expect("pool alive");
            out.push((resp.class, resp.logits, resp.counted_frames, resp.chip_cycles));
        }
        out
    };
    assert_eq!(run(true), run(false), "flight recorder perturbed the datapath");
}

#[test]
fn stream_events_carry_the_session_trace() {
    let coord = Coordinator::builder(rng_quant(4), ChipConfig::design_point())
        .workers(2)
        .recorder(RecorderConfig::default())
        .build()
        .expect("valid pool");
    let cfg = TrackConfig { duration_s: 3, keywords: 1, fillers: 0, noise: (0.001, 0.002) };
    let (audio12, _) = synth_track(&cfg, 77);
    let sess = coord.open_stream(5).expect("under the high-water mark");
    let session_trace = sess.trace_id();
    assert!(!session_trace.is_none(), "session trace missing");
    for c in audio12.chunks(640) {
        sess.push_blocking(c.to_vec()).expect("pool alive");
    }
    let events = sess.close();
    assert!(!events.is_empty(), "no events from the session");
    for e in &events {
        match e {
            StreamEvent::Detection { trace, .. }
            | StreamEvent::WeightsSwapped { trace, .. }
            | StreamEvent::Closed { trace, .. } => {
                assert_eq!(*trace, session_trace, "event trace diverged: {e:?}");
            }
        }
    }
}

#[test]
fn metrics_expose_the_recorder_section_and_sequence() {
    let coord = Coordinator::builder(rng_quant(5), ChipConfig::design_point())
        .workers(1)
        .recorder(RecorderConfig::default())
        .build()
        .expect("valid pool");
    coord.submit(request(1, 0, 9)).expect("pool accepts").wait().expect("pool alive");

    let first = coord.metrics();
    assert_eq!(first.seq, 1);
    assert!(first.rates.is_none(), "no rates window on the first fold");
    let rec = first.recorder.expect("recorder-enabled pool must expose the section");
    assert!(rec.events > 0, "submit/dequeue/decision never recorded");
    assert_eq!(first.stats.completed, 1);

    let second = coord.metrics();
    assert_eq!(second.seq, 2, "snapshot sequence must advance");
    assert!(second.rates.is_some(), "second fold carries a rates window");
}

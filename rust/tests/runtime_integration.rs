//! Integration: the execution-backend abstraction ↔ the Rust twins.
//!
//! The default build exercises the pure-Rust [`NativeBackend`] against the
//! in-crate f64 ΔGRU oracle (`accel::gru::float_delta_step`) — the same
//! cross-check the PJRT artifacts go through. With `--features pjrt` and
//! AOT artifacts present, the original artifact-level checks run too (the
//! `pjrt_artifacts` module below); they skip gracefully otherwise.

use deltakws::accel::gru;
use deltakws::runtime::{backend_for, Backend, NativeBackend, Tensor, TrainState};
use deltakws::train::float_params_from_tensors;
use deltakws::util::prng::Pcg;

/// Random full-size parameter tensors (canonical order/shapes).
fn random_params(seed: u64, scale: f32) -> Vec<Tensor> {
    let mut rng = Pcg::new(seed);
    let shapes: [(usize, usize); 5] = [(16, 192), (64, 192), (1, 192), (64, 12), (1, 12)];
    let mut tensors = Vec::new();
    for (r, c) in shapes {
        let data: Vec<f32> =
            (0..r * c).map(|_| (rng.range_f64(-1.0, 1.0) as f32) * scale).collect();
        let shape = if r == 1 { vec![c] } else { vec![r, c] };
        tensors.push(Tensor::new(shape, data));
    }
    tensors
}

/// Random smooth feature stream [T=62, C=16] in [0, 1).
fn smooth_feats(seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    let mut feats = vec![0.0f32; 62 * 16];
    let mut cur = [0.3f32; 16];
    for t in 0..62 {
        for c in 0..16 {
            cur[c] = (cur[c] + (rng.uniform() as f32 - 0.5) * 0.2).clamp(0.0, 0.99);
            feats[t * 16 + c] = cur[c];
        }
    }
    feats
}

#[test]
fn default_backend_is_usable_without_artifacts() {
    // the whole point of the backend abstraction: no artifacts, no PJRT —
    // the factory must still hand back something that can run the model
    let backend = backend_for("artifacts").expect("backend");
    let m = backend.manifest();
    assert_eq!(m.frames, 62);
    assert_eq!(m.channels, 16);
    assert_eq!(m.hidden, 64);
    assert_eq!(m.classes, 12);

    // a PJRT backend (feature + artifacts + real bindings) is lowered at a
    // fixed batch; only drive B=1 when the backend accepts it
    if backend.supports_batch(1) {
        let params = random_params(1, 0.1);
        let feats = Tensor::new(vec![1, 62, 16], smooth_feats(2));
        let out = backend.forward(&params, &feats, 0.1).expect("forward");
        assert_eq!(out.logits.shape, vec![1, 12]);
        assert_eq!(out.sparsity.shape, vec![1]);
    }
}

#[test]
fn native_forward_matches_f64_reference() {
    // the backend and the f64 oracle implement the same math; agreement is
    // bounded by f32 accumulation only
    let backend = NativeBackend::new();
    let params = random_params(7, 0.15);
    let p = float_params_from_tensors(&params);
    let feats = smooth_feats(8);

    for delta_th in [0.0f32, 0.1, 0.3] {
        let out = backend
            .forward(&params, &Tensor::new(vec![1, 62, 16], feats.clone()), delta_th)
            .expect("forward");
        let sparsity = out.sparsity.data[0];
        assert!((0.0..=1.0).contains(&sparsity), "sparsity {sparsity}");

        // f64 reference (mirror of the python oracle)
        let mut st = gru::FloatState::new(16);
        let mut acc = [0.0f64; 12];
        let mut counted = 0;
        for t in 0..62 {
            let x: Vec<f64> = (0..16).map(|c| feats[t * 16 + c] as f64).collect();
            let (h, _) = gru::float_delta_step(&p, &mut st, &x, delta_th as f64);
            if t >= 4 {
                for k in 0..12 {
                    let mut l = p.b_fc[k] as f64;
                    for j in 0..64 {
                        l += h[j] * p.w_fc[j][k] as f64;
                    }
                    acc[k] += l;
                }
                counted += 1;
            }
        }
        for k in 0..12 {
            acc[k] /= counted as f64;
            let got = out.logits.data[k] as f64;
            assert!(
                (got - acc[k]).abs() < 2e-3,
                "th={delta_th} logit[{k}]: backend {got} vs rust ref {}",
                acc[k]
            );
        }
    }
}

#[test]
fn forward_sparsity_monotone_in_threshold() {
    let backend = NativeBackend::new();
    let params = random_params(9, 0.1);
    let mut rng = Pcg::new(10);
    let feats: Vec<f32> = (0..62 * 16).map(|_| rng.uniform() as f32 * 0.8).collect();
    let mut prev = -1.0f32;
    for th in [0.0f32, 0.05, 0.1, 0.2, 0.4] {
        let out = backend
            .forward(&params, &Tensor::new(vec![1, 62, 16], feats.clone()), th)
            .expect("forward");
        let sp = out.sparsity.data[0];
        assert!(sp >= prev - 1e-6, "sparsity not monotone: {sp} after {prev} at th={th}");
        prev = sp;
    }
    assert!(prev > 0.5, "high threshold should be mostly sparse, got {prev}");
}

#[test]
fn batched_forward_matches_single() {
    let backend = NativeBackend::new();
    let params = random_params(11, 0.12);
    let mut rng = Pcg::new(12);
    let feats_b: Vec<f32> = (0..4 * 62 * 16).map(|_| rng.uniform() as f32 * 0.7).collect();

    let out_b = backend
        .forward(&params, &Tensor::new(vec![4, 62, 16], feats_b.clone()), 0.1)
        .expect("run batched");
    assert_eq!(out_b.logits.shape, vec![4, 12]);

    for b in [0usize, 2, 3] {
        let single = feats_b[b * 62 * 16..(b + 1) * 62 * 16].to_vec();
        let out_s = backend
            .forward(&params, &Tensor::new(vec![1, 62, 16], single), 0.1)
            .expect("run single");
        for k in 0..12 {
            let lb = out_b.logits.data[b * 12 + k];
            let ls = out_s.logits.data[k];
            assert!((lb - ls).abs() < 1e-6, "b={b} k={k}: {lb} vs {ls}");
        }
    }
}

#[test]
fn train_state_matches_backend_geometry() {
    let backend = NativeBackend::new();
    let st = TrainState::init(backend.manifest(), 42);
    assert_eq!(st.params.len(), 5);
    for ((name, shape), t) in backend.manifest().param_shapes.iter().zip(&st.params) {
        assert_eq!(&t.shape, shape, "tensor {name}");
    }
    // forward accepts the initialised parameters directly
    let feats = Tensor::new(vec![1, 62, 16], smooth_feats(3));
    let out = backend.forward(&st.params, &feats, 0.0).expect("forward");
    assert!(out.logits.data.iter().all(|v| v.is_finite()));
}

// ---------------------------------------------------------------------------
// PJRT artifact cross-checks (feature-gated; skip without `make artifacts`)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_artifacts {
    use super::*;
    use deltakws::fex::{Fex, FexConfig};
    use deltakws::runtime::{Runtime, Value};

    fn runtime() -> Option<Runtime> {
        let dir = Runtime::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        match Runtime::new(dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping: PJRT unavailable ({e:#})");
                None
            }
        }
    }

    /// Unquantised Rust float FEx (design coefficients, f64 pipeline) — the
    /// apples-to-apples comparator for the JAX float artifact.
    fn rust_float_fex(audio: &[f64]) -> Vec<[f64; 16]> {
        use deltakws::fex::biquad::FloatBiquad;
        use deltakws::fex::design::design_filterbank;
        let bank = design_filterbank();
        let frames = audio.len() / 128;
        let mut out = vec![[0.0f64; 16]; frames];
        for (c, ch) in bank.iter().enumerate() {
            let mut s0 = FloatBiquad::new(ch.sos[0]);
            let mut s1 = FloatBiquad::new(ch.sos[1]);
            let mut env = 0.0f64;
            for (i, &x) in audio.iter().enumerate() {
                let y = s1.step(s0.step(x));
                env += (y.abs() - env) / 32.0;
                if (i + 1) % 128 == 0 {
                    let t = (i + 1) / 128 - 1;
                    if t < frames {
                        out[t][c] = ((1.0 + env * 4096.0).log2() / 12.0).clamp(0.0, 1.0);
                    }
                }
            }
        }
        out
    }

    #[test]
    fn fex_artifact_matches_rust_float_pipeline() {
        let Some(rt) = runtime() else { return };
        let exe = rt.load("fex_ref.hlo.txt").expect("load fex_ref");
        let mut rng = Pcg::new(5);
        let wave = deltakws::audio::synth_utterance(11, &mut rng);
        let audio12 = deltakws::audio::quantize_12b(&wave);
        let audio_f: Vec<f64> = audio12.iter().map(|&v| v as f64 / 2048.0).collect();
        let n = rt.manifest.audio_samples;

        let out = exe
            .run(&[Tensor::new(vec![n], audio_f.iter().map(|&v| v as f32).take(n).collect())
                .into()])
            .expect("run fex_ref");
        let jax_feats = &out[0]; // flat [62*16], row-major by construction
        assert_eq!(jax_feats.len(), 62 * 16);

        let rust_feats = rust_float_fex(&audio_f[..n]);
        let mut max_err = 0.0f64;
        for (t, frame) in rust_feats.iter().enumerate() {
            for c in 0..16 {
                let e = (frame[c] - jax_feats.data[t * 16 + c] as f64).abs();
                max_err = max_err.max(e);
            }
        }
        assert!(max_err < 5e-3, "JAX vs Rust float FEx: max err {max_err}");
    }

    #[test]
    fn fex_artifact_correlates_with_fixed_point_twin() {
        let Some(rt) = runtime() else { return };
        let exe = rt.load("fex_ref.hlo.txt").expect("load fex_ref");
        let mut rng = Pcg::new(5);
        let wave = deltakws::audio::synth_utterance(11, &mut rng);
        let audio12 = deltakws::audio::quantize_12b(&wave);
        let audio_f: Vec<f32> = audio12.iter().map(|&v| v as f32 / 2048.0).collect();
        let n = rt.manifest.audio_samples;
        let out = exe
            .run(&[Tensor::new(vec![n], audio_f[..n].to_vec()).into()])
            .expect("run fex_ref");
        let float_feats = &out[0]; // flat [62*16]

        let mut fex = Fex::new(FexConfig::all_channels(deltakws::fex::biquad::Arch::MixedShift));
        let frames = fex.process(&audio12[..n]);
        assert_eq!(frames.len(), 62);

        let mut total_err = 0.0;
        let mut strong_channels = 0;
        for c in 0..16 {
            let xs: Vec<f64> = frames.iter().map(|f| f[c] as f64 / 4095.0).collect();
            let ys: Vec<f64> = (0..62).map(|t| float_feats.data[t * 16 + c] as f64).collect();
            total_err += xs.iter().zip(&ys).map(|(a, b)| (a - b).abs()).sum::<f64>();
            let mx = xs.iter().sum::<f64>() / 62.0;
            let my = ys.iter().sum::<f64>() / 62.0;
            let cov: f64 = xs.iter().zip(&ys).map(|(a, b)| (a - mx) * (b - my)).sum();
            let vx: f64 = xs.iter().map(|a| (a - mx) * (a - mx)).sum();
            let vy: f64 = ys.iter().map(|b| (b - my) * (b - my)).sum();
            if vy > 1e-6 {
                let corr = cov / (vx.sqrt() * vy.sqrt()).max(1e-12);
                if corr > 0.9 {
                    strong_channels += 1;
                }
            }
        }
        let mean_err = total_err / (62.0 * 16.0);
        assert!(mean_err < 0.2, "mean |fixed - float| = {mean_err}");
        assert!(strong_channels >= 10, "only {strong_channels}/16 channels track the float FEx");
    }

    #[test]
    fn kws_fwd_artifact_matches_rust_float_reference() {
        let Some(rt) = runtime() else { return };
        let exe = rt.load("kws_fwd.hlo.txt").expect("load kws_fwd");
        let tensors = random_params(7, 0.15);
        let p = float_params_from_tensors(&tensors);
        let feats = smooth_feats(8);

        for delta_th in [0.0f32, 0.1, 0.3] {
            let mut inputs: Vec<Value> = tensors.iter().map(|t| Value::from(t.clone())).collect();
            inputs.push(Tensor::new(vec![62, 16], feats.clone()).into());
            inputs.push(Tensor::scalar(delta_th).into());
            let out = exe.run(&inputs).expect("run kws_fwd");
            let logits = &out[0];
            let sparsity = out[1].data[0];
            assert_eq!(logits.shape, vec![12]);
            assert!((0.0..=1.0).contains(&sparsity), "sparsity {sparsity}");

            let mut st = gru::FloatState::new(16);
            let mut acc = [0.0f64; 12];
            let mut counted = 0;
            for t in 0..62 {
                let x: Vec<f64> = (0..16).map(|c| feats[t * 16 + c] as f64).collect();
                let (h, _) = gru::float_delta_step(&p, &mut st, &x, delta_th as f64);
                if t >= 4 {
                    for k in 0..12 {
                        let mut l = p.b_fc[k] as f64;
                        for j in 0..64 {
                            l += h[j] * p.w_fc[j][k] as f64;
                        }
                        acc[k] += l;
                    }
                    counted += 1;
                }
            }
            for k in 0..12 {
                acc[k] /= counted as f64;
                let got = logits.data[k] as f64;
                assert!(
                    (got - acc[k]).abs() < 2e-3,
                    "th={delta_th} logit[{k}]: artifact {got} vs rust ref {}",
                    acc[k]
                );
            }
        }
    }

    #[test]
    fn batched_fwd_matches_single() {
        let Some(rt) = runtime() else { return };
        let single = rt.load("kws_fwd.hlo.txt").expect("load single");
        let batched = rt.load("kws_fwd_b16.hlo.txt").expect("load batched");
        let tensors = random_params(11, 0.12);
        let mut rng = Pcg::new(12);
        let feats_b: Vec<f32> = (0..16 * 62 * 16).map(|_| rng.uniform() as f32 * 0.7).collect();

        let mut inputs: Vec<Value> = tensors.iter().map(|t| Value::from(t.clone())).collect();
        inputs.push(Tensor::new(vec![16, 62, 16], feats_b.clone()).into());
        inputs.push(Tensor::scalar(0.1f32).into());
        let out_b = batched.run(&inputs).expect("run batched");
        assert_eq!(out_b[0].shape, vec![16, 12]);

        for b in [0usize, 7, 15] {
            let mut inputs: Vec<Value> = tensors.iter().map(|t| Value::from(t.clone())).collect();
            inputs.push(
                Tensor::new(vec![62, 16], feats_b[b * 62 * 16..(b + 1) * 62 * 16].to_vec())
                    .into(),
            );
            inputs.push(Tensor::scalar(0.1f32).into());
            let out_s = single.run(&inputs).expect("run single");
            for k in 0..12 {
                let lb = out_b[0].data[b * 12 + k];
                let ls = out_s[0].data[k];
                assert!((lb - ls).abs() < 1e-4, "b={b} k={k}: {lb} vs {ls}");
            }
        }
    }
}

//! SIMD/batched acceptance (PR 6 tentpole): the lane-packed fast kernels
//! and the batched-chip stepper are the *same* datapath as the scalar
//! oracle — bit for bit, including order-dependent saturation.
//!
//! * randomized `step_frame` equivalence: scalar vs fast datapath over
//!   random `QuantParams` (all weight fractions), Θ at zero / the design
//!   point / beyond full scale, asserting per-frame results, final state,
//!   activity counters and SRAM traffic;
//! * saturation-heavy extremes: all-±127 weight rows driven with
//!   full-scale alternating inputs, asserting the NLU input clamp
//!   actually engaged while the datapaths stayed identical;
//! * ΔFIFO interleavings: depth-1 vs deep rings on the fast datapath
//!   (the scalar pair is pinned by the accel unit tests);
//! * batched vs solo: `step_frames_batched` on a SIMD host against
//!   scalar solo accelerators — scalar == SIMD == batched in one place;
//! * the chip-level acceptance sweep: 100 seeded utterances through a
//!   scalar chip, a SIMD chip, and the batched-chip path (FEx on-chip,
//!   ΔRNN via `BatchSession` groups), asserting every `Decision` and the
//!   aggregate `ChipActivity` are identical.

use deltakws::accel::batch::BatchSession;
use deltakws::accel::gru::{QuantParams, C};
use deltakws::accel::{AccelConfig, DeltaRnnAccel};
use deltakws::chip::{ChipConfig, DecisionAccum, FrameOut, KwsChip};
use deltakws::dataset::{Dataset, Split};
use deltakws::energy::{ChipActivity, SramKind};
use deltakws::util::check::forall;
use deltakws::util::prng::Pcg;
use deltakws::MAX_CHANNELS;

/// Fully randomized model: weights over the whole int8 range, biases over
/// the whole int16 range, every supported weight fraction.
fn rng_quant_rand(rng: &mut Pcg) -> QuantParams {
    let mut q = QuantParams::zeroed();
    q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(256) as i64 - 128) as i8);
    q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(256) as i64 - 128) as i8);
    q.b.iter_mut().for_each(|w| *w = (rng.below(65536) as i64 - 32768) as i16);
    q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(256) as i64 - 128) as i8);
    q.b_fc.iter_mut().for_each(|w| *w = (rng.below(65536) as i64 - 32768) as i16);
    q.w_frac = 6 + rng.below(4) as u32;
    q
}

/// Moderate trained-looking model (the chip-level sweep).
fn rng_quant(seed: u64) -> QuantParams {
    let mut rng = Pcg::new(seed);
    let mut q = QuantParams::zeroed();
    q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
    q.b.iter_mut().for_each(|w| *w = (rng.below(512) as i16) - 256);
    q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q
}

/// Random feature-frame stream on the chip's Q8.8 activation grid.
fn stream(rng: &mut Pcg, frames: usize, p_move: f64, step: i16) -> Vec<[i16; C]> {
    let mut cur = [60i16; C];
    (0..frames)
        .map(|_| {
            for slot in cur.iter_mut().take(14).skip(4) {
                if rng.uniform() < p_move {
                    let d = (rng.below(2 * step as u64 + 1) as i16) - step;
                    *slot = (*slot + d).clamp(0, 511);
                }
            }
            cur
        })
        .collect()
}

fn pair(q: &QuantParams, cfg: &AccelConfig) -> (DeltaRnnAccel, DeltaRnnAccel) {
    (
        DeltaRnnAccel::new(q.clone(), cfg.clone().with_simd(false), SramKind::NearVth),
        DeltaRnnAccel::new(q.clone(), cfg.clone().with_simd(true), SramKind::NearVth),
    )
}

/// Step both datapaths through the same frames, asserting bit-exact
/// per-frame results and identical final state/telemetry.
fn assert_lockstep(
    scalar: &mut DeltaRnnAccel,
    simd: &mut DeltaRnnAccel,
    frames: &[[i16; C]],
    tag: &str,
) {
    for (t, f) in frames.iter().enumerate() {
        let a = scalar.step_frame(f);
        let b = simd.step_frame(f);
        assert_eq!(a.logits, b.logits, "{tag}: logits diverged at frame {t}");
        assert_eq!(a.fired, b.fired, "{tag}: fired diverged at frame {t}");
        assert_eq!(a.cycles, b.cycles, "{tag}: cycles diverged at frame {t}");
    }
    assert_eq!(scalar.state(), simd.state(), "{tag}: final state diverged");
    assert_eq!(scalar.activity, simd.activity, "{tag}: activity diverged");
    assert_eq!(scalar.sram.reads, simd.sram.reads, "{tag}: SRAM reads diverged");
    assert_eq!(
        scalar.sram.bank_reads, simd.sram.bank_reads,
        "{tag}: per-bank SRAM traffic diverged"
    );
}

#[test]
fn randomized_models_step_frame_bit_exact() {
    forall(24, |rng| {
        let q = rng_quant_rand(rng);
        // Θ = 0 (everything fires), the design point, and beyond the
        // activation full scale (nothing ever fires)
        let th = [0i16, 51, 1024][rng.below(3) as usize];
        let cfg = AccelConfig::design_point().with_delta_th(th);
        let (mut scalar, mut simd) = pair(&q, &cfg);
        let frames = stream(rng, 40, 0.4, 60);
        assert_lockstep(&mut scalar, &mut simd, &frames, &format!("th={th}"));
    });
}

#[test]
fn saturation_heavy_extreme_weights_bit_exact() {
    // all-±127 rows + full-scale alternating inputs: every event lands the
    // largest representable product and the gate pre-activations blow past
    // the NLU's Q4.12 input clamp in both directions
    let mut q = QuantParams::zeroed();
    for (i, row) in q.w_x.iter_mut().enumerate() {
        row.iter_mut().for_each(|w| *w = if i % 2 == 0 { 127 } else { -128 });
    }
    for (j, row) in q.w_h.iter_mut().enumerate() {
        row.iter_mut().for_each(|w| *w = if j % 2 == 0 { 127 } else { -128 });
    }
    q.b.iter_mut().enumerate().for_each(|(g, b)| *b = if g % 2 == 0 { 32767 } else { -32768 });
    q.w_fc.iter_mut().flatten().for_each(|w| *w = 127);
    let m_frac = q.m_frac();
    let cfg = AccelConfig::design_point().with_delta_th(0);
    let (mut scalar, mut simd) = pair(&q, &cfg);
    // swing the full i16 range so every delta is ~2^16 (the accel-level
    // input is not clamped to the chip's 9-bit feature grid)
    let mut clamp_hit = (false, false);
    for t in 0..60 {
        let v: i16 = if t % 2 == 0 { 32767 } else { -32768 };
        let x = [v; C];
        let a = scalar.step_frame(&x);
        let b = simd.step_frame(&x);
        assert_eq!(a.logits, b.logits, "frame {t}");
        assert_eq!((a.fired, a.cycles), (b.fired, b.cycles), "frame {t}");
        // NLU input clamp engages once |m| >> nlu_shift exceeds Q4.12
        let rail = 8i64 << m_frac;
        for &m in scalar.state().m_r.iter() {
            clamp_hit.0 |= m as i64 >= rail;
            clamp_hit.1 |= m as i64 <= -rail;
        }
    }
    assert_eq!(scalar.state(), simd.state());
    assert_eq!(scalar.activity, simd.activity);
    assert!(clamp_hit.0 && clamp_hit.1, "NLU clamp never engaged on both rails: {clamp_hit:?}");
}

#[test]
fn fifo_interleavings_bit_exact_on_fast_path() {
    // depth-1 vs deep ΔFIFO rings on the *fast* datapath: the drain-order
    // invariance the scalar accel tests pin must survive vectorization
    forall(8, |rng| {
        let q = rng_quant_rand(rng);
        let mut tiny_cfg = AccelConfig::design_point().with_simd(true);
        tiny_cfg.fifo_depth = 1;
        let mut deep_cfg = AccelConfig::design_point().with_simd(true);
        deep_cfg.fifo_depth = 64;
        let mut tiny = DeltaRnnAccel::new(q.clone(), tiny_cfg, SramKind::NearVth);
        let mut deep = DeltaRnnAccel::new(q, deep_cfg, SramKind::NearVth);
        for (t, f) in stream(rng, 30, 0.5, 80).iter().enumerate() {
            let a = tiny.step_frame(f);
            let b = deep.step_frame(f);
            assert_eq!(a.logits, b.logits, "frame {t}");
            assert_eq!(a.cycles, b.cycles, "frame {t}");
        }
        assert_eq!(tiny.state(), deep.state());
    });
}

#[test]
fn batched_host_matches_scalar_solos() {
    // scalar == SIMD == batched in one assertion chain: the batched host
    // runs the fast kernels, the solo references run the scalar oracle
    forall(6, |rng| {
        let q = rng_quant_rand(rng);
        let cfg = AccelConfig::design_point();
        let n = 1 + rng.below(5) as usize;
        let streams: Vec<Vec<[i16; C]>> =
            (0..n).map(|_| stream(rng, 25, 0.4, 60)).collect();
        let mut host =
            DeltaRnnAccel::new(q.clone(), cfg.clone().with_simd(true), SramKind::NearVth);
        let mut solos: Vec<DeltaRnnAccel> = (0..n)
            .map(|_| DeltaRnnAccel::new(q.clone(), cfg.clone().with_simd(false), SramKind::NearVth))
            .collect();
        let mut sessions = vec![BatchSession::new(); n];
        for t in 0..25 {
            for (sess, st) in sessions.iter_mut().zip(streams.iter()) {
                sess.stage(st[t]);
            }
            let stats = host.step_frames_batched(&mut sessions);
            assert_eq!(stats.stepped, n);
            assert!(stats.physical_word_reads <= stats.logical_word_reads);
            for (s, sess) in sessions.iter().enumerate() {
                let solo = solos[s].step_frame(&streams[s][t]);
                let got = sess.last.expect("stepped");
                assert_eq!(got.logits, solo.logits, "t={t} s={s}");
                assert_eq!((got.fired, got.cycles), (solo.fired, solo.cycles), "t={t} s={s}");
            }
        }
        for (s, sess) in sessions.iter().enumerate() {
            assert_eq!(sess.state(), solos[s].state(), "session {s}");
            assert_eq!(sess.activity, solos[s].activity, "session {s}");
        }
    });
}

#[test]
fn hundred_utterances_scalar_simd_batched_chip_equivalence() {
    const GROUP: usize = 4;
    let ds = Dataset::new(0x51D6);
    let q = rng_quant(1);
    let mut scalar_cfg = ChipConfig::design_point();
    scalar_cfg.accel.use_simd = false;
    let mut simd_cfg = ChipConfig::design_point();
    simd_cfg.accel.use_simd = true;
    let mut scalar_chip = KwsChip::new(q.clone(), scalar_cfg);
    let mut simd_chip = KwsChip::new(q.clone(), simd_cfg.clone());
    // FEx front end + batch host for the batched-chip path
    let mut batch_chip = KwsChip::new(q, simd_cfg);
    let mut sessions = vec![BatchSession::new(); GROUP];

    for group in 0..(100 / GROUP) {
        // per-utterance frames through the batch chip's FEx
        let mut frames: Vec<Vec<[i16; MAX_CHANNELS]>> = Vec::with_capacity(GROUP);
        let mut decisions = Vec::with_capacity(GROUP);
        for g in 0..GROUP {
            let i = group * GROUP + g;
            let utt = ds.utterance(Split::Test, i);
            let d_scalar = scalar_chip.process_utterance(&utt.audio12);
            let d_simd = simd_chip.process_utterance(&utt.audio12);
            assert_eq!(d_scalar, d_simd, "utt {i}: SIMD decision diverged");
            decisions.push(d_scalar);
            batch_chip.reset();
            let mut fr = Vec::new();
            for piece in utt.audio12.chunks(deltakws::chip::SAFE_CHUNK_SAMPLES) {
                batch_chip.push_samples(piece).expect("chunk fits");
                while let Some(qf) = batch_chip.pop_frame_activations() {
                    fr.push(qf);
                }
            }
            frames.push(fr);
        }
        // lockstep ΔRNN over the group (counters survive reset_state)
        for sess in sessions.iter_mut() {
            sess.reset_state();
        }
        let mut accums: Vec<DecisionAccum> =
            (0..GROUP).map(|_| DecisionAccum::new(batch_chip.config.warmup)).collect();
        let max_t = frames.iter().map(|f| f.len()).max().unwrap_or(0);
        for t in 0..max_t {
            for (sess, fr) in sessions.iter_mut().zip(frames.iter()) {
                if let Some(&qf) = fr.get(t) {
                    sess.stage(qf);
                }
            }
            batch_chip.accel.step_frames_batched(&mut sessions);
            for ((sess, fr), acc) in sessions.iter().zip(frames.iter()).zip(accums.iter_mut()) {
                if t >= fr.len() {
                    continue;
                }
                let r = sess.last.expect("staged session stepped");
                acc.push(&FrameOut {
                    index: t as u64,
                    feat: [0i64; MAX_CHANNELS],
                    logits: r.logits,
                    fired: r.fired,
                    cycles: r.cycles,
                    gated: false,
                });
            }
        }
        for (g, acc) in accums.iter().enumerate() {
            let i = group * GROUP + g;
            assert_eq!(acc.finish(), decisions[g], "utt {i}: batched decision diverged");
        }
    }

    // aggregate telemetry: scalar == SIMD, and the batched split
    // (on-chip FEx + per-session RNN accounting) re-assembles to the same
    // ChipActivity as a solo chip
    let scalar_act = scalar_chip.activity();
    assert_eq!(scalar_act, simd_chip.activity(), "SIMD chip activity diverged");
    let mut batched_act: ChipActivity = batch_chip.activity();
    for sess in &sessions {
        batched_act.merge(&sess.activity);
    }
    assert_eq!(scalar_act, batched_act, "batched activity accounting diverged");
    assert!(scalar_act.frames >= 100 * 62, "sweep too short: {}", scalar_act.frames);
}

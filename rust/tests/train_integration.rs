//! Integration: the training path on the default execution backend — the
//! train step executes, the loss decreases, runs are deterministic under
//! pinned seeds, and the export chain (float -> int8 image -> accelerator)
//! holds together. No artifacts or PJRT required.

use deltakws::dataset::{Dataset, Split};
use deltakws::fex::FexConfig;
use deltakws::runtime::{Backend, NativeBackend};
use deltakws::train::{float_params_from_tensors, Trainer};

/// These suites pin down the *native* backend's training behaviour, so they
/// construct it directly — running them under `--features pjrt` with
/// artifacts present must not silently switch the backend under test
/// (the PJRT path has its own artifact-gated suite).
fn trainer(seed: u64, batch: usize) -> Trainer {
    let backend = Box::new(NativeBackend::new());
    let ds = Dataset::with_fex(seed, FexConfig::all_channels(deltakws::fex::biquad::Arch::MixedShift));
    Trainer::new(backend, ds, batch, 0.1).expect("trainer")
}

#[test]
fn train_step_reduces_loss() {
    let mut trainer = trainer(1, 8);
    let mut state = trainer.init_state(1);

    // repeat the SAME batch in the dense (Θ=0) curriculum phase: loss must
    // fall fast if gradients flow (STE-thresholded training from scratch
    // stalls by design — that's why fit() uses the curriculum)
    let mut losses = Vec::new();
    for _ in 0..6 {
        let loss = trainer
            .step_at(&mut state, 0, 0.0, deltakws::train::BASE_LR)
            .expect("step");
        assert!(loss.is_finite());
        losses.push(loss);
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.95),
        "no learning on a repeated batch: {losses:?}"
    );
    assert_eq!(state.step, 6.0);
    assert_eq!(trainer.log.len(), 6);
}

#[test]
fn training_is_deterministic_under_pinned_seeds() {
    // same seed -> bit-identical losses and parameters (Pcg-seeded data +
    // a deterministic backend step); different seed -> different trajectory
    let run = |seed: u64| {
        let mut trainer = trainer(seed, 8);
        let mut state = trainer.init_state(seed);
        let mut losses = Vec::new();
        for s in 0..3 {
            losses.push(trainer.step_at(&mut state, s, 0.0, 1e-3).expect("step"));
        }
        (losses, state.params[0].data.clone())
    };
    let (l1, p1) = run(5);
    let (l2, p2) = run(5);
    assert_eq!(l1, l2, "loss trajectory not deterministic");
    assert_eq!(p1, p2, "parameters not deterministic");
    let (l3, _) = run(6);
    assert_ne!(l1, l3, "different seeds must differ");
}

#[test]
fn evaluate_and_export_chain() {
    let mut trainer = trainer(2, 8);
    let mut state = trainer.init_state(2);
    for s in 0..3 {
        trainer.step(&mut state, s).expect("step");
    }

    // float eval runs and is bounded
    let (acc, sp) = trainer.evaluate(&state, Split::Test, 16, 0.1).expect("eval");
    assert!((0.0..=1.0).contains(&acc));
    assert!((0.0..=1.0).contains(&sp));

    // export -> quantise -> SRAM image -> accelerator classifies
    let q = trainer.export(&state);
    let fp = float_params_from_tensors(&state.params);
    assert!(fp.quant_clip_fraction() < 0.2, "early training weights should mostly fit Q1.6");
    let mut accel = deltakws::accel::DeltaRnnAccel::new(
        q,
        deltakws::accel::AccelConfig::design_point(),
        deltakws::energy::SramKind::NearVth,
    );
    let feats = trainer.dataset.feature_batch(Split::Test, 0, 1);
    let (class, logits) = accel.classify(&feats[0].feats, 4);
    assert!(class < 12);
    assert!(logits.iter().any(|&l| l != 0));
}

#[test]
fn quantized_chip_agrees_with_float_model_on_trained_weights() {
    // After a few steps, the chip twin and the float forward should agree
    // on most predictions (quantisation is mild for small weights).
    let backend = Box::new(NativeBackend::new());
    let ds = Dataset::with_fex(3, FexConfig::design_point());
    let mut trainer = Trainer::new(backend, ds, 8, 0.1).expect("trainer");
    let mut state = trainer.init_state(3);
    for s in 0..4 {
        trainer.step(&mut state, s).expect("step");
    }
    let q = trainer.export(&state);

    let (feats, _labels) = trainer.batch_tensors(Split::Test, 64);
    let backend2 = NativeBackend::new();
    let out = backend2.forward(&state.params, &feats, 0.0).expect("forward");

    // dense on both sides (Θ=0): quantisation is the only gap
    let mut chip = deltakws::accel::DeltaRnnAccel::new(
        q,
        deltakws::accel::AccelConfig::design_point().with_delta_th(0),
        deltakws::energy::SramKind::NearVth,
    );
    let seqs = trainer.dataset.feature_batch(Split::Test, 64, 8);
    let mut agree = 0;
    for (b, seq) in seqs.iter().enumerate() {
        let row = &out.logits.data[b * 12..(b + 1) * 12];
        let float_pred = (0..12).max_by(|&i, &j| row[i].partial_cmp(&row[j]).unwrap()).unwrap();
        let (chip_pred, _) = chip.classify(&seq.feats, 4);
        if chip_pred == float_pred {
            agree += 1;
        }
    }
    assert!(agree >= 4, "chip/float prediction agreement too low: {agree}/8");
}

#[test]
fn curriculum_schedules_are_well_formed() {
    let trainer = trainer(4, 8);
    let total = 100;
    // dense first, target threshold at the end, monotone non-decreasing
    assert_eq!(trainer.schedule_th(0, total), 0.0);
    assert_eq!(trainer.schedule_th(total - 1, total), trainer.delta_th);
    let mut prev = -1.0f32;
    for s in 0..total {
        let th = trainer.schedule_th(s, total);
        assert!(th >= prev - 1e-6, "Θ schedule not monotone at {s}");
        assert!(th <= trainer.delta_th + 1e-6);
        prev = th;
    }
    // LR drops when the threshold activates
    assert_eq!(trainer.schedule_lr(0, total), deltakws::train::BASE_LR);
    assert_eq!(trainer.schedule_lr(total - 1, total), deltakws::train::FINETUNE_LR);
}

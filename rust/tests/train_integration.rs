//! Integration: the PJRT training path — train_step executes, the loss
//! decreases, and the export chain (float -> int8 image -> accelerator)
//! holds together. Skips gracefully without artifacts.

use deltakws::dataset::{Dataset, Split};
use deltakws::fex::FexConfig;
use deltakws::runtime::Runtime;
use deltakws::train::{float_params_from_tensors, TrainState, Trainer};

fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

#[test]
fn train_step_reduces_loss() {
    let Some(rt) = runtime() else { return };
    let ds = Dataset::with_fex(1, FexConfig::all_channels(deltakws::fex::biquad::Arch::MixedShift));
    let mut trainer = Trainer::new(&rt, ds, 16, 0.1).expect("trainer");
    let mut state = TrainState::init(&rt, 1);

    // repeat the SAME batch in the dense (Θ=0) curriculum phase: loss must
    // fall fast if gradients flow (STE-thresholded training from scratch
    // stalls by design — that's why fit() uses the curriculum)
    let mut losses = Vec::new();
    for _ in 0..8 {
        let loss = trainer
            .step_at(&mut state, 0, 0.0, deltakws::train::BASE_LR)
            .expect("step");
        assert!(loss.is_finite());
        losses.push(loss);
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.95),
        "no learning on a repeated batch: {losses:?}"
    );
    assert_eq!(state.step, 8.0);
}

#[test]
fn evaluate_and_export_chain() {
    let Some(rt) = runtime() else { return };
    let ds = Dataset::with_fex(2, FexConfig::all_channels(deltakws::fex::biquad::Arch::MixedShift));
    let mut trainer = Trainer::new(&rt, ds, 16, 0.1).expect("trainer");
    let mut state = TrainState::init(&rt, 2);
    for s in 0..4 {
        trainer.step(&mut state, s).expect("step");
    }

    // float eval runs and is bounded
    let (acc, sp) = trainer.evaluate(&state, Split::Test, 32, 0.1).expect("eval");
    assert!((0.0..=1.0).contains(&acc));
    assert!((0.0..=1.0).contains(&sp));

    // export -> quantise -> SRAM image -> accelerator classifies
    let q = trainer.export(&state);
    let fp = float_params_from_tensors(&state.params);
    assert!(fp.quant_clip_fraction() < 0.2, "early training weights should mostly fit Q1.6");
    let mut accel = deltakws::accel::DeltaRnnAccel::new(
        q,
        deltakws::accel::AccelConfig::design_point(),
        deltakws::energy::SramKind::NearVth,
    );
    let feats = trainer.dataset.feature_batch(Split::Test, 0, 1);
    let (class, logits) = accel.classify(&feats[0].feats, 4);
    assert!(class < 12);
    assert!(logits.iter().any(|&l| l != 0));
}

#[test]
fn quantized_chip_agrees_with_float_model_on_trained_weights() {
    // After a few steps, the chip twin and the float forward should agree
    // on most predictions (quantisation is mild for small weights).
    let Some(rt) = runtime() else { return };
    let ds = Dataset::with_fex(3, FexConfig::design_point());
    let mut trainer = Trainer::new(&rt, ds, 16, 0.1).expect("trainer");
    let mut state = TrainState::init(&rt, 3);
    for s in 0..6 {
        trainer.step(&mut state, s).expect("step");
    }
    let q = trainer.export(&state);
    let fwd = rt.load("kws_fwd_b16.hlo.txt").expect("load fwd");

    let (feats, _labels) = trainer.batch_tensors(Split::Test, 64);
    let mut inputs: Vec<deltakws::runtime::Value> =
        state.params.iter().map(|t| deltakws::runtime::Value::from(t.clone())).collect();
    inputs.push(feats.clone().into());
    inputs.push(deltakws::runtime::Tensor::scalar(0.2f32).into());
    let out = fwd.run(&inputs).expect("run");

    let mut chip = deltakws::accel::DeltaRnnAccel::new(
        q,
        deltakws::accel::AccelConfig::design_point().with_delta_th(51),
        deltakws::energy::SramKind::NearVth,
    );
    let seqs = trainer.dataset.feature_batch(Split::Test, 64, 16);
    let mut agree = 0;
    for (b, seq) in seqs.iter().enumerate() {
        let row = &out[0].data[b * 12..(b + 1) * 12];
        let float_pred = (0..12).max_by(|&i, &j| row[i].partial_cmp(&row[j]).unwrap()).unwrap();
        let (chip_pred, _) = chip.classify(&seq.feats, 4);
        if chip_pred == float_pred {
            agree += 1;
        }
    }
    assert!(agree >= 10, "chip/float prediction agreement too low: {agree}/16");
}

//! Self-check for `deltakws-lint` (DESIGN.md §13): the analyzer holds the
//! live tree clean, every rule demonstrably fires on a minimal fixture,
//! the suppression protocol behaves (reasoned allows suppress, reasonless
//! allows are rejected), and the JSON report parses against its schema.
//!
//! This is the test that keeps the lint honest in both directions: a rule
//! that silently stopped firing fails the fixture half, and a regression
//! that re-introduces a hot-path allocation fails the live-tree half.

use deltakws::util::json;
use deltakws_lint::{scan_source, LintConfig, Report, Rule, SCHEMA};
use std::path::Path;

fn cfg() -> LintConfig {
    LintConfig::builtin()
}

/// Repo root: the deltakws crate lives at `<root>/rust`.
fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("crate sits under the repo root")
}

// ---------------------------------------------------------------------------
// Live tree
// ---------------------------------------------------------------------------

#[test]
fn live_tree_has_zero_unsuppressed_findings() {
    let report = deltakws_lint::run(repo_root(), &cfg()).expect("scan the live tree");
    assert!(report.files_scanned > 50, "scan roots missing? only {} files", report.files_scanned);
    let offenders: Vec<String> = report
        .unsuppressed()
        .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule.name(), f.snippet))
        .collect();
    assert!(
        offenders.is_empty(),
        "unsuppressed lint findings in the live tree:\n{}",
        offenders.join("\n")
    );
}

#[test]
fn live_tree_suppressions_all_carry_reasons() {
    let report = deltakws_lint::run(repo_root(), &cfg()).expect("scan the live tree");
    // the engine only records a suppression when the reason is non-empty;
    // this guards the *report* invariant the CI job and bench tooling rely on
    for f in report.suppressed() {
        let reason = f.suppressed.as_deref().unwrap_or("");
        assert!(
            !reason.trim().is_empty(),
            "{}:{} [{}] suppressed without a reason",
            f.file,
            f.line,
            f.rule.name()
        );
    }
    assert!(report.suppressed().count() > 0, "the audited tree documents its exceptions");
}

// ---------------------------------------------------------------------------
// Per-rule fixtures: every rule fires on a minimal inline source
// ---------------------------------------------------------------------------

fn rules_hit(path: &str, src: &str) -> Vec<Rule> {
    scan_source(path, src, &cfg()).into_iter().map(|f| f.rule).collect()
}

#[test]
fn no_alloc_hot_path_fires_on_constructor_and_tracked_push() {
    let src = "fn f() {\n    let mut buf = Vec::with_capacity(4);\n    buf.push(1);\n}\n";
    let findings = scan_source("rust/src/accel/fixture.rs", src, &cfg());
    let lines: Vec<usize> = findings
        .iter()
        .filter(|f| f.rule == Rule::NoAllocHotPath)
        .map(|f| f.line)
        .collect();
    assert_eq!(lines, vec![2, 3], "constructor line and tracked .push( line both fire");
}

#[test]
fn no_alloc_does_not_flag_untracked_push() {
    // the ΔFIFO ring also has `.push(` — only identifiers proven to be
    // Vec/VecDeque bindings are flagged
    let src = "fn f(ring: &mut Fifo) {\n    let _ = ring.push(ev);\n}\n";
    assert!(rules_hit("rust/src/accel/fixture.rs", src).is_empty());
}

#[test]
fn no_lock_hot_path_fires_on_mutex() {
    let src = "fn f() {\n    let m = std::sync::Mutex::new(0u32);\n    let _g = m.lock();\n}\n";
    let hits = rules_hit("rust/src/fex/fixture.rs", src);
    assert!(hits.contains(&Rule::NoLockHotPath), "hits: {hits:?}");
}

#[test]
fn no_panic_hot_path_fires_on_unwrap_but_not_debug_assert() {
    let src = "fn f(x: Option<u8>) -> u8 {\n    debug_assert!(x.is_some());\n    x.unwrap()\n}\n";
    let findings = scan_source("rust/src/chip/fixture.rs", src, &cfg());
    let lines: Vec<usize> = findings
        .iter()
        .filter(|f| f.rule == Rule::NoPanicHotPath)
        .map(|f| f.line)
        .collect();
    assert_eq!(lines, vec![3], "debug_assert! passes, .unwrap( fires");
}

#[test]
fn narrowing_cast_fires_bare_but_passes_sat_routed() {
    let bare = "fn f(acc: i64) -> i16 {\n    acc as i16\n}\n";
    assert!(rules_hit("rust/src/fixed/fixture.rs", bare)
        .contains(&Rule::NarrowingCastDiscipline));
    let routed = "fn f(acc: i64) -> i16 {\n    sat(acc, 16) as i16\n}\n";
    assert!(
        !rules_hit("rust/src/fixed/fixture.rs", routed)
            .contains(&Rule::NarrowingCastDiscipline),
        "a cast routed through fixed::sat on the same line is compliant"
    );
    // widening casts are not narrowing targets
    let widen = "fn f(x: i16) -> i64 {\n    x as i64\n}\n";
    assert!(rules_hit("rust/src/accel/fixture.rs", widen).is_empty());
}

#[test]
fn narrowing_rule_is_scoped_to_fixed_and_accel() {
    let bare = "fn f(acc: i64) -> i16 {\n    acc as i16\n}\n";
    assert!(
        rules_hit("rust/src/obs/fixture.rs", bare).is_empty(),
        "outside fixed/ + accel/ the cast rule does not apply"
    );
}

#[test]
fn bounded_channels_fires_everywhere() {
    let src = "fn f() {\n    let (tx, rx) = std::sync::mpsc::channel::<u32>();\n}\n";
    // even in a module with no hot-path restrictions at all
    let hits = rules_hit("rust/src/obs/fixture.rs", src);
    assert!(hits.contains(&Rule::BoundedChannels), "hits: {hits:?}");
    let bounded = "fn f() {\n    let (tx, rx) = std::sync::mpsc::sync_channel::<u32>(8);\n}\n";
    assert!(!rules_hit("rust/src/obs/fixture.rs", bounded)
        .contains(&Rule::BoundedChannels));
}

#[test]
fn no_wallclock_fires_outside_the_allowlist_only() {
    let src = "fn f() {\n    let t = std::time::Instant::now();\n}\n";
    assert!(rules_hit("rust/src/stream/fixture.rs", src).contains(&Rule::NoWallclock));
    assert!(
        !rules_hit("rust/src/obs/fixture.rs", src).contains(&Rule::NoWallclock),
        "obs/ owns the wall clock"
    );
    assert!(!rules_hit("rust/src/coordinator/soak.rs", src).contains(&Rule::NoWallclock));
}

#[test]
fn sched_module_is_hot_and_the_steal_path_exemption_suppresses() {
    // coordinator/sched.rs joined the hot set with the v3 scheduler: a
    // bare lock there fires like it would in accel/ — the work-stealing
    // run queue answers to the frame-path rules
    let bare = "fn f() {\n    let q = std::sync::Mutex::new(0u32);\n    let _g = q.lock();\n}\n";
    let hits = rules_hit("rust/src/coordinator/sched.rs", bare);
    assert!(hits.contains(&Rule::NoLockHotPath), "sched.rs fell out of the hot set: {hits:?}");
    // the rest of coordinator/ stays control plane: the same source is
    // clean one directory level up
    assert!(
        !rules_hit("rust/src/coordinator/mod.rs", bare).contains(&Rule::NoLockHotPath),
        "hot scope leaked past sched.rs into the coordinator control plane"
    );
    // and the documented exemption shape — a reasoned allow on the
    // mutex-guarded steal deque — suppresses without hiding the finding
    let exempt = concat!(
        "fn steal(&self, victim: usize) -> Option<u32> {\n",
        "    // lint:allow(no-lock-hot-path): the mutex-guarded deque IS the std-only steal mechanism (DESIGN.md \u{a7}15)\n",
        "    self.locals[victim].lock().ok()?.pop_back()\n",
        "}\n",
    );
    let findings = scan_source("rust/src/coordinator/sched.rs", exempt, &cfg());
    let locks: Vec<_> =
        findings.iter().filter(|f| f.rule == Rule::NoLockHotPath).collect();
    assert_eq!(locks.len(), 1, "the steal-path lock is still recorded as a finding");
    assert_eq!(
        locks[0].suppressed.as_deref(),
        Some("the mutex-guarded deque IS the std-only steal mechanism (DESIGN.md \u{a7}15)"),
        "the reasoned steal-path allow must suppress with its reason recorded"
    );
}

#[test]
fn no_unsafe_fires_on_the_keyword() {
    let src = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
    assert!(rules_hit("rust/src/util/fixture.rs", src).contains(&Rule::NoUnsafe));
    // identifiers containing the word are not the keyword
    let ident = "fn f() {\n    let unsafe_looking_name = 1;\n    let _ = unsafe_looking_name;\n}\n";
    assert!(rules_hit("rust/src/util/fixture.rs", ident).is_empty());
}

// ---------------------------------------------------------------------------
// Comment/string/test-code awareness
// ---------------------------------------------------------------------------

#[test]
fn comments_strings_and_test_code_do_not_fire() {
    let src = concat!(
        "// Vec::new() in a comment is fine; so is .unwrap()\n",
        "/* block comment: Mutex, Instant::now() */\n",
        "fn f() -> &'static str {\n",
        "    \"Vec::new() inside a string literal\"\n",
        "}\n",
        "#[cfg(test)]\n",
        "mod tests {\n",
        "    #[test]\n",
        "    fn t() {\n",
        "        let v = vec![1, 2, 3];\n",
        "        assert_eq!(v.len(), 3);\n",
        "        let _ = v.iter().max().unwrap();\n",
        "    }\n",
        "}\n",
    );
    let hits = rules_hit("rust/src/accel/fixture.rs", src);
    assert!(hits.is_empty(), "hits: {hits:?}");
}

// ---------------------------------------------------------------------------
// Suppression protocol
// ---------------------------------------------------------------------------

#[test]
fn reasoned_allow_suppresses_trailing_and_line_above() {
    let src = concat!(
        "fn f() {\n",
        "    let a = Vec::new(); // lint:allow(no-alloc-hot-path): construction-time scratch\n",
        "    // lint:allow(no-alloc-hot-path): one-time table build\n",
        "    let b = Vec::with_capacity(8);\n",
        "}\n",
    );
    let findings = scan_source("rust/src/accel/fixture.rs", src, &cfg());
    assert_eq!(findings.len(), 2);
    for f in &findings {
        assert!(f.suppressed.is_some(), "{}:{} not suppressed", f.file, f.line);
    }
    assert_eq!(findings[0].suppressed.as_deref(), Some("construction-time scratch"));
    assert_eq!(findings[1].suppressed.as_deref(), Some("one-time table build"));
}

#[test]
fn reasonless_allow_is_rejected() {
    let src = "fn f() {\n    let a = Vec::new(); // lint:allow(no-alloc-hot-path)\n}\n";
    let findings = scan_source("rust/src/accel/fixture.rs", src, &cfg());
    assert_eq!(findings.len(), 1);
    assert!(findings[0].suppressed.is_none(), "an allow without a reason must not suppress");
    assert!(
        findings[0].rationale.contains("without a reason"),
        "the rejection is called out in the rationale: {}",
        findings[0].rationale
    );
}

#[test]
fn blank_line_breaks_the_allow_run() {
    let src = concat!(
        "fn f() {\n",
        "    // lint:allow(no-alloc-hot-path): stale comment\n",
        "\n",
        "    let a = Vec::new();\n",
        "}\n",
    );
    let findings = scan_source("rust/src/accel/fixture.rs", src, &cfg());
    assert_eq!(findings.len(), 1);
    assert!(findings[0].suppressed.is_none(), "an allow separated by a blank line must not apply");
}

#[test]
fn allow_for_the_wrong_rule_does_not_suppress() {
    let src = "fn f() {\n    let a = Vec::new(); // lint:allow(no-panic-hot-path): wrong rule named\n}\n";
    let findings = scan_source("rust/src/accel/fixture.rs", src, &cfg());
    assert_eq!(findings.len(), 1);
    assert!(findings[0].suppressed.is_none());
}

// ---------------------------------------------------------------------------
// JSON report schema
// ---------------------------------------------------------------------------

#[test]
fn json_report_parses_and_matches_the_schema() {
    let src = concat!(
        "fn f() {\n",
        "    let a = Vec::new();\n",
        "    let b = Vec::with_capacity(4); // lint:allow(no-alloc-hot-path): fixture\n",
        "}\n",
    );
    let report = Report {
        findings: scan_source("rust/src/accel/fixture.rs", src, &cfg()),
        files_scanned: 1,
    };

    let parsed = json::parse(&report.to_json()).expect("report JSON parses");
    assert_eq!(parsed.at(&["schema"]).and_then(|j| j.as_str()), Some(SCHEMA));
    assert_eq!(parsed.at(&["files_scanned"]).and_then(|j| j.as_usize()), Some(1));
    assert_eq!(
        parsed.at(&["rules"]).and_then(|j| j.as_arr()).map(|a| a.len()),
        Some(Rule::ALL.len()),
        "all rules are listed"
    );
    assert_eq!(parsed.at(&["counts", "findings"]).and_then(|j| j.as_usize()), Some(1));
    assert_eq!(parsed.at(&["counts", "suppressed"]).and_then(|j| j.as_usize()), Some(1));
    assert_eq!(
        parsed
            .at(&["counts", "per_rule", "no-alloc-hot-path", "findings"])
            .and_then(|j| j.as_usize()),
        Some(1)
    );
    let findings = parsed.at(&["findings"]).and_then(|j| j.as_arr()).expect("findings array");
    assert_eq!(findings.len(), 1);
    assert_eq!(
        findings[0].get("rule").and_then(|j| j.as_str()),
        Some("no-alloc-hot-path")
    );
    assert_eq!(findings[0].get("line").and_then(|j| j.as_usize()), Some(2));
    let sups = parsed.at(&["suppressions"]).and_then(|j| j.as_arr()).expect("suppressions array");
    assert_eq!(sups.len(), 1);
    assert_eq!(sups[0].get("reason").and_then(|j| j.as_str()), Some("fixture"));
}

#[test]
fn live_tree_json_report_parses() {
    let report = deltakws_lint::run(repo_root(), &cfg()).expect("scan the live tree");
    let parsed = json::parse(&report.to_json()).expect("live JSON parses");
    assert_eq!(parsed.at(&["schema"]).and_then(|j| j.as_str()), Some(SCHEMA));
    assert_eq!(parsed.at(&["counts", "findings"]).and_then(|j| j.as_usize()), Some(0));
}

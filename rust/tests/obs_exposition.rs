//! Golden tests for the metrics exposition schema (PR 7, re-pinned for
//! the `deltakws-metrics/3` scheduler schema in PR 10).
//!
//! The Prometheus-style text and the JSON document emitted by
//! [`MetricsSnapshot`] are a **stable schema** other tooling scrapes
//! (`tools/bench_report.py --validate-metrics`, the CI soak smoke step,
//! any dashboard pointed at the `main serve` dumps). These tests pin:
//!
//! * the full ordered `# TYPE` line sequence of the text form,
//! * every integer-valued sample line byte-exact against a synthetic
//!   [`Stats`] built from hand-computable counters,
//! * the float gauges by parsed value (all chosen exactly representable:
//!   accuracy 6/8, sparsity 1 − 100/400, duty cycle 1 − 155/620),
//! * the JSON key sets at every level, the `le` bucket sequence, and a
//!   parse → compare roundtrip through the crate's own JSON parser.
//!
//! Any change that breaks these tests is a schema break: bump
//! [`METRICS_SCHEMA`], update `tools/bench_report.py`, then re-pin here.

use deltakws::coordinator::{Stats, WorkerStats};
use deltakws::energy::ChipActivity;
use deltakws::obs::recorder::RecorderStats;
use deltakws::obs::{MetricsRegistry, MetricsSnapshot, LATENCY_LE_US, METRICS_SCHEMA};
use deltakws::util::hist::LogHistogram;
use deltakws::util::json::{parse, Json};

/// Synthetic pool stats with every derived quantity exactly computable:
/// latency samples 100/300/5000 µs split cleanly across the `le` bounds,
/// and each float gauge is a dyadic-free but exactly-representable ratio.
fn synthetic_stats() -> Stats {
    let mut latency = LogHistogram::new();
    latency.record(100);
    latency.record(300);
    latency.record(5_000);
    let mut chunk_latency = LogHistogram::new();
    chunk_latency.record(50);
    let mut sched_latency = LogHistogram::new();
    sched_latency.record(80);
    let mut enroll_latency = LogHistogram::new();
    enroll_latency.record(200_000);
    Stats {
        completed: 10,
        correct: 6,
        labelled: 8,
        rejected_full: 2,
        rejected_closed: 1,
        steals: 4,
        park_transitions: 9,
        sessions_parked: 7,
        sessions_runnable: 2,
        shed_overloaded: 3,
        latency,
        chunk_latency,
        sched_latency,
        activity: ChipActivity {
            frames: 620,
            gated_frames: 155,
            mac_ops: 1_000,
            sram_word_reads: 2_000,
            rnn_cycles: 3_000,
            fired_lanes: 100,
            total_lanes: 400,
            fired_x: 60,
            total_x: 240,
            fired_h: 40,
            total_h: 160,
            fex_visits: 500,
        },
        fused_batches: 1,
        stream_events_dropped: 4,
        session_bytes: 512,
        weight_swaps: 5,
        resident_versions: 2,
        enroll_latency,
        per_worker: vec![
            WorkerStats { completed: 7, steals: 1, stream_chunks: 5 },
            WorkerStats { completed: 3, steals: 3, stream_chunks: 9 },
        ],
        captured_us: 1_000_000,
    }
}

fn has_line(text: &str, line: &str) -> bool {
    text.lines().any(|l| l == line)
}

/// Value of the unique sample line starting with `prefix` followed by a
/// space (labels included in the prefix when present).
fn prom_value(text: &str, prefix: &str) -> f64 {
    let want = format!("{prefix} ");
    let mut hits = text.lines().filter(|l| l.starts_with(&want));
    let line = hits.next().unwrap_or_else(|| panic!("no sample line for {prefix}"));
    assert!(hits.next().is_none(), "ambiguous sample line for {prefix}");
    line[want.len()..].parse().unwrap_or_else(|_| panic!("unparseable value in {line:?}"))
}

#[test]
fn prometheus_type_lines_are_pinned() {
    let text = MetricsSnapshot::from_stats(&synthetic_stats()).to_prometheus();
    let types: Vec<&str> = text.lines().filter(|l| l.starts_with("# TYPE ")).collect();
    let expected = [
        "# TYPE deltakws_metrics_seq gauge",
        "# TYPE deltakws_metrics_captured_us gauge",
        "# TYPE deltakws_completed_total counter",
        "# TYPE deltakws_labelled_total counter",
        "# TYPE deltakws_correct_total counter",
        "# TYPE deltakws_accuracy gauge",
        "# TYPE deltakws_rejected_total counter",
        "# TYPE deltakws_steals_total counter",
        "# TYPE deltakws_park_transitions_total counter",
        "# TYPE deltakws_shed_overloaded_total counter",
        "# TYPE deltakws_sessions_parked gauge",
        "# TYPE deltakws_sessions_runnable gauge",
        "# TYPE deltakws_fused_batches_total counter",
        "# TYPE deltakws_stream_events_dropped_total counter",
        "# TYPE deltakws_session_bytes gauge",
        "# TYPE deltakws_weight_swaps_total counter",
        "# TYPE deltakws_resident_weight_versions gauge",
        "# TYPE deltakws_chip_frames_total counter",
        "# TYPE deltakws_chip_gated_frames_total counter",
        "# TYPE deltakws_chip_mac_ops_total counter",
        "# TYPE deltakws_chip_sram_word_reads_total counter",
        "# TYPE deltakws_chip_rnn_cycles_total counter",
        "# TYPE deltakws_chip_fired_lanes_total counter",
        "# TYPE deltakws_chip_scanned_lanes_total counter",
        "# TYPE deltakws_chip_fex_visits_total counter",
        "# TYPE deltakws_chip_sparsity gauge",
        "# TYPE deltakws_chip_duty_cycle gauge",
        "# TYPE deltakws_worker_completed_total counter",
        "# TYPE deltakws_worker_steals_total counter",
        "# TYPE deltakws_worker_stream_chunks_total counter",
        "# TYPE deltakws_latency_us histogram",
        "# TYPE deltakws_chunk_latency_us histogram",
        "# TYPE deltakws_sched_latency_us histogram",
        "# TYPE deltakws_enroll_latency_us histogram",
    ];
    assert_eq!(types, expected, "TYPE line set/order drifted — schema break");
}

#[test]
fn prometheus_integer_samples_are_exact() {
    let text = MetricsSnapshot::from_stats(&synthetic_stats()).to_prometheus();
    for line in [
        "deltakws_metrics_seq 0",
        "deltakws_metrics_captured_us 1000000",
        "deltakws_completed_total 10",
        "deltakws_labelled_total 8",
        "deltakws_correct_total 6",
        "deltakws_rejected_total{cause=\"queue_full\"} 2",
        "deltakws_rejected_total{cause=\"closed\"} 1",
        "deltakws_steals_total 4",
        "deltakws_park_transitions_total 9",
        "deltakws_shed_overloaded_total 3",
        "deltakws_sessions_parked 7",
        "deltakws_sessions_runnable 2",
        "deltakws_fused_batches_total 1",
        "deltakws_stream_events_dropped_total 4",
        "deltakws_session_bytes 512",
        "deltakws_weight_swaps_total 5",
        "deltakws_resident_weight_versions 2",
        "deltakws_chip_frames_total 620",
        "deltakws_chip_gated_frames_total 155",
        "deltakws_chip_mac_ops_total 1000",
        "deltakws_chip_sram_word_reads_total 2000",
        "deltakws_chip_rnn_cycles_total 3000",
        "deltakws_chip_fired_lanes_total 100",
        "deltakws_chip_scanned_lanes_total 400",
        "deltakws_chip_fex_visits_total 500",
        "deltakws_worker_completed_total{worker=\"0\"} 7",
        "deltakws_worker_completed_total{worker=\"1\"} 3",
        "deltakws_worker_steals_total{worker=\"0\"} 1",
        "deltakws_worker_steals_total{worker=\"1\"} 3",
        "deltakws_worker_stream_chunks_total{worker=\"0\"} 5",
        "deltakws_worker_stream_chunks_total{worker=\"1\"} 9",
    ] {
        assert!(has_line(&text, line), "missing exact sample line {line:?} in:\n{text}");
    }
}

#[test]
fn prometheus_float_gauges_parse_to_exact_ratios() {
    let text = MetricsSnapshot::from_stats(&synthetic_stats()).to_prometheus();
    assert_eq!(prom_value(&text, "deltakws_accuracy"), 0.75, "6/8 labelled correct");
    assert_eq!(prom_value(&text, "deltakws_chip_sparsity"), 0.75, "1 - 100/400 lanes fired");
    assert_eq!(prom_value(&text, "deltakws_chip_duty_cycle"), 0.75, "1 - 155/620 gated");
}

#[test]
fn prometheus_histograms_cumulate_exactly() {
    let text = MetricsSnapshot::from_stats(&synthetic_stats()).to_prometheus();
    // samples 100/300/5000: 100 < 128; 300 < 512; 5000 < 8192 — and every
    // `le` is an exact LogHistogram bucket boundary, so the cumulative
    // counts are exact (strictly-below semantics, see LATENCY_LE_US docs)
    for (le, want) in LATENCY_LE_US.iter().zip([1u64, 2, 2, 3, 3, 3, 3, 3]) {
        let line = format!("deltakws_latency_us_bucket{{le=\"{le}\"}} {want}");
        assert!(has_line(&text, &line), "missing {line:?}");
    }
    assert!(has_line(&text, "deltakws_latency_us_bucket{le=\"+Inf\"} 3"));
    assert!(has_line(&text, "deltakws_latency_us_sum 5400"));
    assert!(has_line(&text, "deltakws_latency_us_count 3"));
    for le in LATENCY_LE_US {
        let line = format!("deltakws_chunk_latency_us_bucket{{le=\"{le}\"}} 1");
        assert!(has_line(&text, &line), "missing {line:?}");
    }
    assert!(has_line(&text, "deltakws_chunk_latency_us_bucket{le=\"+Inf\"} 1"));
    assert!(has_line(&text, "deltakws_chunk_latency_us_sum 50"));
    assert!(has_line(&text, "deltakws_chunk_latency_us_count 1"));
    // scheduling-latency sample 80 µs: below the first bound already
    assert!(has_line(&text, "deltakws_sched_latency_us_bucket{le=\"128\"} 1"));
    assert!(has_line(&text, "deltakws_sched_latency_us_bucket{le=\"+Inf\"} 1"));
    assert!(has_line(&text, "deltakws_sched_latency_us_sum 80"));
    assert!(has_line(&text, "deltakws_sched_latency_us_count 1"));
    // enrollment sample 200_000 µs: above 131072, below 524288
    assert!(has_line(&text, "deltakws_enroll_latency_us_bucket{le=\"131072\"} 0"));
    assert!(has_line(&text, "deltakws_enroll_latency_us_bucket{le=\"524288\"} 1"));
    assert!(has_line(&text, "deltakws_enroll_latency_us_bucket{le=\"+Inf\"} 1"));
    assert!(has_line(&text, "deltakws_enroll_latency_us_sum 200000"));
    assert!(has_line(&text, "deltakws_enroll_latency_us_count 1"));
}

fn key_set(j: &Json) -> Vec<String> {
    match j {
        Json::Obj(m) => m.keys().cloned().collect(),
        other => panic!("expected object, got {other}"),
    }
}

#[test]
fn json_key_sets_are_pinned() {
    let doc = MetricsSnapshot::from_stats(&synthetic_stats()).to_json();
    // BTreeMap keys come back sorted — pin the sorted sets
    assert_eq!(
        key_set(&doc),
        [
            "activity",
            "captured_us",
            "chunk_latency_us",
            "counters",
            "enroll_latency_us",
            "gauges",
            "latency_us",
            "per_worker",
            "rates",
            "recorder",
            "sched_latency_us",
            "schema",
            "seq",
        ]
    );
    assert_eq!(
        key_set(doc.get("counters").unwrap()),
        [
            "completed",
            "correct",
            "fused_batches",
            "labelled",
            "park_transitions",
            "rejected_closed",
            "rejected_full",
            "shed_overloaded",
            "steals",
            "stream_events_dropped",
            "weight_swaps",
        ]
    );
    assert_eq!(
        key_set(doc.get("gauges").unwrap()),
        [
            "accuracy",
            "resident_weight_versions",
            "session_bytes",
            "sessions_parked",
            "sessions_runnable",
            "telemetry_bytes",
        ]
    );
    assert_eq!(
        key_set(doc.get("activity").unwrap()),
        [
            "duty_cycle",
            "fex_visits",
            "fired_h",
            "fired_lanes",
            "fired_x",
            "frames",
            "gated_frames",
            "mac_ops",
            "rnn_cycles",
            "sparsity",
            "sram_word_reads",
            "total_h",
            "total_lanes",
            "total_x",
        ]
    );
    for hist in ["latency_us", "chunk_latency_us", "sched_latency_us", "enroll_latency_us"] {
        assert_eq!(
            key_set(doc.get(hist).unwrap()),
            ["buckets", "count", "mean", "p50", "p90", "p99", "sum"],
            "{hist} shape drifted"
        );
    }
    let workers = doc.get("per_worker").unwrap().as_arr().unwrap();
    assert_eq!(workers.len(), 2);
    for w in workers {
        assert_eq!(key_set(w), ["completed", "steals", "stream_chunks", "worker"]);
    }
}

#[test]
fn json_values_and_le_sequence_are_exact() {
    let doc = MetricsSnapshot::from_stats(&synthetic_stats()).to_json();
    assert_eq!(doc.get("schema").unwrap().as_str(), Some(METRICS_SCHEMA));
    assert_eq!(doc.at(&["counters", "completed"]).unwrap().as_f64(), Some(10.0));
    assert_eq!(doc.at(&["counters", "weight_swaps"]).unwrap().as_f64(), Some(5.0));
    assert_eq!(doc.at(&["counters", "steals"]).unwrap().as_f64(), Some(4.0));
    assert_eq!(doc.at(&["counters", "park_transitions"]).unwrap().as_f64(), Some(9.0));
    assert_eq!(doc.at(&["counters", "shed_overloaded"]).unwrap().as_f64(), Some(3.0));
    assert_eq!(doc.at(&["gauges", "sessions_parked"]).unwrap().as_f64(), Some(7.0));
    assert_eq!(doc.at(&["gauges", "sessions_runnable"]).unwrap().as_f64(), Some(2.0));
    assert_eq!(
        doc.at(&["gauges", "resident_weight_versions"]).unwrap().as_f64(),
        Some(2.0)
    );
    assert_eq!(doc.at(&["gauges", "accuracy"]).unwrap().as_f64(), Some(0.75));
    assert_eq!(doc.at(&["activity", "sparsity"]).unwrap().as_f64(), Some(0.75));
    assert_eq!(doc.at(&["activity", "duty_cycle"]).unwrap().as_f64(), Some(0.75));
    // document shape is constant: absent sections serialize as null
    assert_eq!(doc.get("recorder"), Some(&Json::Null));
    assert_eq!(doc.get("rates"), Some(&Json::Null));

    let buckets = doc.at(&["latency_us", "buckets"]).unwrap().as_arr().unwrap();
    assert_eq!(buckets.len(), LATENCY_LE_US.len() + 1, "8 bounds + the +Inf bucket");
    for (b, le) in buckets.iter().zip(LATENCY_LE_US) {
        assert_eq!(b.get("le").unwrap().as_f64(), Some(le as f64));
    }
    assert_eq!(buckets.last().unwrap().get("le"), Some(&Json::Null), "+Inf is le:null");
    let counts: Vec<u64> =
        buckets.iter().map(|b| b.get("count").unwrap().as_f64().unwrap() as u64).collect();
    assert_eq!(counts, [1, 2, 2, 3, 3, 3, 3, 3, 3]);

    // percentile goldens pin the round-half-up rank rule through the
    // exposition: p50 of {100, 300, 5000} is the 2nd order statistic's
    // bucket midpoint ([296, 303] → 299); p90/p99 clamp to the 3rd
    // ([4992, 5119] → 5055)
    assert_eq!(doc.at(&["latency_us", "mean"]).unwrap().as_f64(), Some(1800.0));
    assert_eq!(doc.at(&["latency_us", "p50"]).unwrap().as_f64(), Some(299.0));
    assert_eq!(doc.at(&["latency_us", "p90"]).unwrap().as_f64(), Some(5055.0));
    assert_eq!(doc.at(&["latency_us", "p99"]).unwrap().as_f64(), Some(5055.0));
    assert_eq!(doc.at(&["chunk_latency_us", "p50"]).unwrap().as_f64(), Some(50.0));
}

#[test]
fn json_roundtrips_through_the_crate_parser() {
    let doc = MetricsSnapshot::from_stats(&synthetic_stats()).to_json();
    let reparsed = parse(&doc.to_string()).expect("exposition emits valid JSON");
    assert_eq!(reparsed, doc);
}

#[test]
fn registry_fold_exposes_recorder_and_rates_sections() {
    let mut reg = MetricsRegistry::new();
    let first = reg.fold(synthetic_stats(), None);
    assert_eq!(first.seq, 1);

    let mut later = synthetic_stats();
    later.captured_us = 3_000_000;
    later.completed = 50;
    later.rejected_full = 4;
    later.steals = 12;
    later.activity.frames = 3_100;
    later.per_worker[0].stream_chunks = 21; // 14 → 30 total chunks
    let rec = RecorderStats { events: 7, dumps_taken: 2, dumps_dropped: 1, dumps_held: 1 };
    let snap = reg.fold(later, Some(rec));
    assert_eq!(snap.seq, 2);

    let text = snap.to_prometheus();
    assert!(has_line(&text, "deltakws_metrics_seq 2"));
    assert!(has_line(&text, "deltakws_recorder_events_total 7"));
    assert!(has_line(&text, "deltakws_flight_dumps_total 2"));
    assert!(has_line(&text, "deltakws_flight_dumps_dropped_total 1"));
    assert!(has_line(&text, "deltakws_flight_dumps_held 1"));
    assert!(has_line(&text, "deltakws_rate_window_us 2000000"));
    // 40 more decisions over a 2 s window
    assert_eq!(prom_value(&text, "deltakws_decisions_per_sec"), 20.0);
    // Δrejected_full 2 + Δrejected_closed 0 + Δdropped 0 over 2 s
    assert_eq!(prom_value(&text, "deltakws_drops_per_sec"), 1.0);
    // Δchunks (21 + 9) − (5 + 9) = 16 over 2 s
    assert_eq!(prom_value(&text, "deltakws_stream_chunks_per_sec"), 8.0);
    assert_eq!(prom_value(&text, "deltakws_chip_frames_per_sec"), 1240.0);
    // Δsteals 12 − 4 = 8 over 2 s
    assert_eq!(prom_value(&text, "deltakws_steals_per_sec"), 4.0);

    let doc = snap.to_json();
    assert_eq!(doc.at(&["recorder", "events"]).unwrap().as_f64(), Some(7.0));
    assert_eq!(doc.at(&["rates", "elapsed_us"]).unwrap().as_f64(), Some(2_000_000.0));
    assert_eq!(doc.at(&["rates", "decisions_per_sec"]).unwrap().as_f64(), Some(20.0));
    assert_eq!(doc.at(&["rates", "steals_per_sec"]).unwrap().as_f64(), Some(4.0));
}

//! Golden-vector regression tests: checked-in expected outputs for the
//! bit-accurate integer datapaths, plus the Δ ≡ dense bit-exactness
//! invariant at Θ = 0.
//!
//! The expected vectors were computed by an *independent* integer-exact
//! reimplementation (`tools/gen_goldens.py`) — not recorded from this crate
//! — so they catch both regressions and shared-misconception bugs in the
//! fixed-point primitives. The stimulus is PCG-derived integer noise (not
//! the f64 formant synthesiser) precisely so the golden path contains no
//! floating-point op whose last ulp could differ across toolchains.

use deltakws::accel::encoder::{encode, DeltaEvent};
use deltakws::accel::gru::{QuantParams, C};
use deltakws::accel::{AccelConfig, DeltaRnnAccel};
use deltakws::audio::track::{schedule, TrackConfig};
use deltakws::baseline::DenseGruAccel;
use deltakws::dataset::{Dataset, Split};
use deltakws::energy::SramKind;
use deltakws::fex::biquad::Cascade;
use deltakws::fex::design::QuantBiquad;
use deltakws::fex::postproc::{log_compress, Envelope};
use deltakws::fixed::QFormat;
use deltakws::stream::detector::{Detector, DetectorConfig};
use deltakws::util::prng::Pcg;

// ---------------------------------------------------------------------------
// 1. FEx channel pipeline: biquad cascade -> envelope -> log compression
// ---------------------------------------------------------------------------

/// 62 frames of one FEx channel over a fixed 1 s noise utterance
/// (regenerate with `python3 tools/gen_goldens.py`).
const FEX_GOLDEN: [i64; 62] = [
    2862, 2865, 2857, 2653, 2817, 2634, 2542, 2951, 2905, 2808,
    3028, 2900, 2917, 2604, 2785, 2817, 2814, 2739, 2713, 2931,
    2598, 2605, 2744, 2814, 2774, 2692, 2866, 2809, 2786, 2547,
    2751, 2725, 2625, 2788, 2638, 2764, 2735, 2702, 2760, 2886,
    2787, 2884, 2962, 2735, 2593, 2786, 3067, 2684, 2788, 2547,
    2401, 3087, 2735, 2787, 2591, 2700, 2654, 2792, 2774, 2781,
    2731, 2873,
];

#[test]
fn fex_channel_pipeline_matches_golden() {
    // hand-picked quantised coefficients (Q0.11 b, Q1.6 a), strictly
    // stable: |a1| = 91/64 < 1 + a2 = 1 + 53/64, a2 < 1
    let q = QuantBiquad {
        b0: 150,
        a1: -91,
        a2: 53,
        qb: QFormat::new(12, 11),
        qa: QFormat::new(8, 6),
    };
    let mut cascade = Cascade::new([q, q]);
    let mut env = Envelope::default();
    let mut rng = Pcg::new(0xFE0);
    let mut feats = Vec::with_capacity(62);
    for n in 0..8000usize {
        // deterministic 12-bit noise "utterance" (top 12 bits of the PCG)
        let x12 = (rng.next_u32() >> 20) as i64 - 2048;
        let x = x12 << 4; // Q1.11 -> Q1.15 signal path
        let y = cascade.step(x);
        env.step(y);
        if (n + 1) % 128 == 0 {
            feats.push(log_compress(env.acc));
        }
    }
    assert_eq!(feats.len(), FEX_GOLDEN.len());
    for (t, (&got, &want)) in feats.iter().zip(FEX_GOLDEN.iter()).enumerate() {
        assert_eq!(got, want, "FEx golden diverged at frame {t}: {got} != {want}");
    }
}

// ---------------------------------------------------------------------------
// 2. ΔEncoder: event stream over a fixed feature sequence
// ---------------------------------------------------------------------------

const ENC_FIRED_TOTAL: usize = 590;
const ENC_HASH: u64 = 0xa27bd74ec743c15b;
const ENC_FIRST_EVENTS: [(u16, i32); 8] =
    [(1, 327), (2, 325), (3, 476), (4, 327), (5, 78), (6, 362), (7, 395), (8, 444)];

#[test]
fn delta_encoder_matches_golden() {
    let mut rng = Pcg::new(0xDE17A);
    let mut refs = [0i16; 16];
    let th = 20i16;
    let mut fired_total = 0usize;
    let mut hash = 0u64;
    let mut all_events: Vec<DeltaEvent> = Vec::new();
    for _ in 0..40 {
        let cur: Vec<i16> = (0..16).map(|_| (rng.next_u32() % 512) as i16).collect();
        let mut out = Vec::new();
        fired_total += encode(&cur, &mut refs, th, &mut out);
        for ev in &out {
            hash = hash
                .wrapping_mul(1000003)
                .wrapping_add(ev.lane as u64 * 100000 + (ev.delta as i64 + 70000) as u64);
        }
        all_events.extend(out);
    }
    assert_eq!(fired_total, ENC_FIRED_TOTAL, "fired-lane count drifted");
    for (i, &(lane, delta)) in ENC_FIRST_EVENTS.iter().enumerate() {
        assert_eq!(all_events[i].lane, lane, "event {i} lane");
        assert_eq!(all_events[i].delta, delta, "event {i} delta");
    }
    assert_eq!(hash, ENC_HASH, "event stream hash drifted");
}

// ---------------------------------------------------------------------------
// 3. Δ-network ≡ dense network at Θ = 0, bit-exact, on real feature streams
// ---------------------------------------------------------------------------

fn rng_quant(seed: u64) -> QuantParams {
    let mut rng = Pcg::new(seed);
    let mut q = QuantParams::zeroed();
    q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
    q.b.iter_mut().for_each(|w| *w = (rng.below(512) as i16) - 256);
    q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q
}

#[test]
fn delta_at_zero_threshold_is_bit_exact_dense_on_synth_utterances() {
    // the chip's central functional claim, checked end-to-end on the real
    // FEx feature stream (not just random frames): with Θ = 0 the ΔRNN's
    // per-frame integer logits equal the dense accelerator's, bit for bit
    for seed in [1u64, 7, 42] {
        let ds = Dataset::new(seed);
        let q = rng_quant(seed ^ 0x5eed);
        let cfg = AccelConfig::design_point().with_delta_th(0);
        let mut delta = DeltaRnnAccel::new(q.clone(), cfg.clone(), SramKind::NearVth);
        let mut dense = DenseGruAccel::new(q, cfg.active_x, SramKind::NearVth);
        let utt = ds.utterance(Split::Test, seed as usize);
        let feats = ds.feature_batch(Split::Test, seed as usize, 1);
        assert_eq!(utt.label, feats[0].label);
        for (t, frame) in feats[0].feats.iter().enumerate() {
            let rd = delta.step_frame(frame);
            let ld = dense.step_frame(frame);
            assert_eq!(
                rd.logits, ld,
                "seed {seed}: Θ=0 Δ != dense at frame {t} (bit-exactness broken)"
            );
        }
        // and the Δ path did real event elision bookkeeping meanwhile
        assert_eq!(delta.activity.total_x, 62 * 10);
    }
}

// ---------------------------------------------------------------------------
// 4. Long-form track schedule: the streaming workload's ground truth
// ---------------------------------------------------------------------------

/// Keyword/filler placement for the 60 s design-point track at seed
/// 0x517EAD (regenerate with `python3 tools/gen_goldens.py`). The schedule
/// draws are integer-only precisely so this independent oracle exists.
const TRACK_GOLDEN: [(usize, usize); 26] = [
    (11, 3941),
    (10, 25169),
    (10, 46016),
    (1, 64863),
    (7, 80624),
    (7, 92824),
    (8, 117100),
    (1, 138798),
    (5, 147964),
    (10, 169660),
    (9, 185830),
    (1, 204642),
    (8, 225362),
    (7, 244883),
    (10, 260401),
    (1, 285733),
    (7, 298114),
    (9, 324171),
    (4, 335211),
    (1, 359331),
    (8, 372218),
    (8, 397487),
    (9, 410376),
    (1, 434887),
    (10, 448630),
    (8, 469810),
];

#[test]
fn track_schedule_matches_golden() {
    let cfg = TrackConfig { duration_s: 60, keywords: 20, fillers: 6, noise: (0.001, 0.003) };
    let sched = schedule(&cfg, 0x517EAD);
    assert_eq!(sched.len(), TRACK_GOLDEN.len(), "schedule length drifted");
    for (t, (e, &(class, onset))) in sched.iter().zip(TRACK_GOLDEN.iter()).enumerate() {
        assert_eq!(
            (e.class, e.onset),
            (class, onset),
            "track schedule diverged at entry {t}"
        );
        assert_eq!(e.len, 8000);
    }
}

// ---------------------------------------------------------------------------
// 5. Wakeword detector state machine: expected detections for a fixed
//    logit stream (two keyword bursts + one VAD-gated gap)
// ---------------------------------------------------------------------------

/// (class, confirm frame, onset frame, margin) — the detector's integer
/// state machine is mirrored in `tools/gen_goldens.py`; each burst fires
/// once at onset + window-fill + hysteresis and once more after the
/// refractory window, pinning smoothing, debounce and flush behaviour.
const DETECTOR_GOLDEN: [(usize, u64, u64, i64); 4] = [
    (5, 44, 42, 246190),
    (5, 72, 70, 398549),
    (9, 124, 122, 243486),
    (9, 152, 150, 398188),
];

#[test]
fn detector_state_machine_matches_golden() {
    let cfg = DetectorConfig {
        window: 8,
        margin_q: 120_000,
        on_frames: 3,
        refractory_frames: 25,
    };
    let mut det = Detector::new(cfg);
    let mut rng = Pcg::new(0xDE7EC7);
    let mut events = Vec::new();
    for t in 0..200u64 {
        let mut logits = [0i64; deltakws::NUM_CLASSES];
        for l in logits.iter_mut() {
            *l = rng.below(2000) as i64;
        }
        if (40..80).contains(&t) {
            logits[5] += 50_000;
        }
        if (120..160).contains(&t) {
            logits[9] += 50_000;
        }
        let gated = (90..100).contains(&t);
        if let Some(e) = det.step(t, &logits, gated) {
            events.push((e.class, e.frame, e.onset_frame, e.margin));
        }
    }
    assert_eq!(events.len(), DETECTOR_GOLDEN.len(), "event count drifted: {events:?}");
    for (i, (got, want)) in events.iter().zip(DETECTOR_GOLDEN.iter()).enumerate() {
        assert_eq!(got, want, "detector golden diverged at event {i}");
    }
}

#[test]
fn delta_at_zero_threshold_sparsity_only_from_unchanged_lanes() {
    // at Θ=0 a lane is silent iff its value literally did not change; on
    // the design-point feature stream some lanes do hold still, so fired
    // counts must be <= total but > 0 — pin the exact counts via the
    // encoder-level hash above, and the invariant here
    let ds = Dataset::new(3);
    let q = rng_quant(99);
    let mut delta =
        DeltaRnnAccel::new(q, AccelConfig::design_point().with_delta_th(0), SramKind::NearVth);
    let feats = ds.feature_batch(Split::Test, 3, 1);
    let mut prev: Option<[i16; C]> = None;
    for frame in &feats[0].feats {
        let r = delta.step_frame(frame);
        if let Some(p) = prev {
            // input lanes that changed since the previous frame must be
            // covered by fired events (hidden side adds more)
            let changed =
                (4..14).filter(|&i| p[i] != frame[i]).count();
            assert!(r.fired >= changed, "fired {} < changed inputs {changed}", r.fired);
        }
        prev = Some(*frame);
    }
}

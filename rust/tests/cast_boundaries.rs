//! Boundary regressions for the narrowing-cast audit (DESIGN.md §13).
//!
//! The `narrowing-cast-discipline` lint rule requires every narrowing
//! `as i16` / `as i32` / `as u8` in `fixed/` and `accel/` to route through
//! the saturating helpers (`fixed::sat`, `round_shift`, `mul_shift_sat`,
//! `sat32`) or carry a written justification. These tests pin the exact
//! boundary behaviour those helpers guarantee — the wrap-arounds a raw
//! `as` cast would silently commit are spelled out next to the clamped
//! result the datapath actually requires, so a future "simplification"
//! back to a bare cast fails loudly here instead of corrupting logits.

use deltakws::accel::mac::{mac_row, ACC_BITS};
use deltakws::accel::simd::{mac_row_fast, sat32};
use deltakws::fixed::{add_sat, max_val, min_val, mul_shift_sat, round_shift, sat};

#[test]
fn sat_clamps_where_raw_i16_cast_wraps() {
    // one past i16::MAX: the raw cast wraps to the most negative value —
    // in a feature pipeline that is a full-scale sign flip
    assert_eq!(32_768i64 as i16, -32_768);
    assert_eq!(sat(32_768, 16), 32_767);
    assert_eq!(-32_769i64 as i16, 32_767);
    assert_eq!(sat(-32_769, 16), -32_768);
    // identity strictly inside the word
    for v in [-32_768i64, -1, 0, 1, 32_767] {
        assert_eq!(sat(v, 16), v);
    }
}

#[test]
fn sat_clamps_where_raw_i8_cast_wraps() {
    assert_eq!(128i64 as i8, -128);
    assert_eq!(sat(128, 8), 127);
    assert_eq!(-129i64 as i8, 127);
    assert_eq!(sat(-129, 8), -128);
}

#[test]
fn sat32_pins_the_accumulator_boundary() {
    let hi = i32::MAX as i64;
    let lo = i32::MIN as i64;
    // exactly representable values pass through untouched
    assert_eq!(sat32(hi), i32::MAX);
    assert_eq!(sat32(lo), i32::MIN);
    // one past the rail clamps; the raw cast would wrap to the far rail
    assert_eq!((hi + 1) as i32, i32::MIN);
    assert_eq!(sat32(hi + 1), i32::MAX);
    assert_eq!((lo - 1) as i32, i32::MAX);
    assert_eq!(sat32(lo - 1), i32::MIN);
    // and it agrees with the width-parametric primitive it shadows
    for v in [lo - 7, lo, -1, 0, 1, hi, hi + 7] {
        assert_eq!(sat32(v) as i64, sat(v, 32));
    }
}

#[test]
fn mac_row_saturates_instead_of_wrapping_at_the_rails() {
    // accumulator one product below the positive rail: the next MAC must
    // pin at the rail, not wrap negative
    let w = [127i8, -128, 0];
    let mut acc = [i32::MAX - 100, i32::MIN + 100, 5];
    let mut acc_fast = acc;
    let delta = 1_000; // products: 127_000 / -128_000 / 0 — all overflow the headroom
    mac_row(delta, &w, &mut acc);
    mac_row_fast(delta, &w, &mut acc_fast);
    assert_eq!(acc, [i32::MAX, i32::MIN, 5]);
    // the vectorized kernel is bit-exact with the scalar oracle at the rails
    assert_eq!(acc, acc_fast);
}

#[test]
fn mac_row_scalar_and_fast_agree_across_the_full_product_range() {
    // extreme delta (Q8.8 full scale) x extreme weights, accumulators
    // seeded near both rails and at zero
    let w = [i8::MIN, -1, 0, 1, i8::MAX];
    for delta in [i16::MIN as i32, -257, 0, 257, i16::MAX as i32] {
        let mut a = [i32::MIN + 3, -1, 0, 1, i32::MAX - 3];
        let mut b = a;
        mac_row(delta, &w, &mut a);
        mac_row_fast(delta, &w, &mut b);
        assert_eq!(a, b, "delta={delta}");
        for v in a {
            assert!(
                (min_val(ACC_BITS)..=max_val(ACC_BITS)).contains(&(v as i64)),
                "accumulator escaped the {ACC_BITS}-bit word: {v}"
            );
        }
    }
}

#[test]
fn mul_shift_sat_clamps_the_post_shift_product() {
    // Q1.6 x Q1.6 full-scale square, renormalised by 6: overflows a
    // 16-bit word and must pin at the rail
    let full = max_val(16);
    assert_eq!(mul_shift_sat(full, full, 6, 16), max_val(16));
    assert_eq!(mul_shift_sat(full, -full, 6, 16), min_val(16));
    // small products are exact (rounded, not truncated)
    assert_eq!(mul_shift_sat(3, 5, 0, 16), 15);
    assert_eq!(mul_shift_sat(3, 1, 1, 16), 2); // 1.5 rounds away from zero
}

#[test]
fn add_sat_clamps_the_carry_out() {
    assert_eq!(add_sat(max_val(16), 1, 16), max_val(16));
    assert_eq!(add_sat(min_val(16), -1, 16), min_val(16));
    assert_eq!(add_sat(100, -300, 16), -200);
}

#[test]
fn round_shift_is_total_near_i64_min() {
    // regression for the widened-magnitude negative branch: the naive
    // `-((-v + half) >> sh)` overflows here and wraps in release builds
    assert_eq!(round_shift(i64::MIN, 1), i64::MIN / 2);
    // -(2^63 - 1)/2 = -(2^62 - 0.5) rounds away from zero to -(2^62)
    assert_eq!(round_shift(i64::MIN + 1, 1), i64::MIN / 2);
    assert_eq!(round_shift(i64::MAX, 1), i64::MAX / 2 + 1);
    // rounding is half-away-from-zero in both directions
    assert_eq!(round_shift(3, 1), 2);
    assert_eq!(round_shift(-3, 1), -2);
    assert_eq!(round_shift(5, 2), 1);
    assert_eq!(round_shift(-5, 2), -1);
}

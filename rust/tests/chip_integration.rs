//! Integration: the full chip twin + coordinator under realistic load and
//! injected failures.

use std::time::Duration;

use deltakws::accel::gru::QuantParams;
use deltakws::chip::{ChipConfig, KwsChip};
use deltakws::coordinator::{Coordinator, Request};
use deltakws::dataset::{Dataset, Split};
use deltakws::util::prng::Pcg;

fn rng_quant(seed: u64) -> QuantParams {
    let mut rng = Pcg::new(seed);
    let mut q = QuantParams::zeroed();
    q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
    q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q
}

#[test]
fn end_to_end_dataset_run_with_energy_report() {
    let ds = Dataset::new(3);
    let mut chip = KwsChip::new(rng_quant(3), ChipConfig::design_point());
    for i in 0..8 {
        let utt = ds.utterance(Split::Test, i);
        let d = chip.process_utterance(&utt.audio12);
        assert_eq!(d.frames, 62);
    }
    let rep = chip.report();
    // sanity envelope around the calibrated design regime
    assert!(rep.power.total_uw() > 3.0 && rep.power.total_uw() < 10.0, "{:?}", rep.power);
    assert!(rep.latency_ms > 1.0 && rep.latency_ms < 17.0, "latency {}", rep.latency_ms);
    assert!(rep.energy_per_decision_nj > 5.0 && rep.energy_per_decision_nj < 130.0);
    assert!(rep.sparsity > 0.0 && rep.sparsity < 1.0);
}

#[test]
fn delta_th_tradeoff_shape_holds_on_real_audio() {
    // the Fig. 12 *shape*: latency and energy decrease monotonically with
    // Δ_TH on real (synthetic-GSCD) audio through the full pipeline
    let ds = Dataset::new(4);
    let utts: Vec<_> = (0..6).map(|i| ds.utterance(Split::Test, i)).collect();
    let mut prev_energy = f64::MAX;
    let mut prev_latency = f64::MAX;
    for th in [0i16, 26, 51, 102] {
        let mut chip = KwsChip::new(rng_quant(4), ChipConfig::design_point().with_delta_th(th));
        for u in &utts {
            chip.process_utterance(&u.audio12);
        }
        let rep = chip.report();
        assert!(
            rep.energy_per_decision_nj <= prev_energy * 1.001,
            "energy rose at th={th}: {} after {prev_energy}",
            rep.energy_per_decision_nj
        );
        assert!(rep.latency_ms <= prev_latency * 1.001, "latency rose at th={th}");
        prev_energy = rep.energy_per_decision_nj;
        prev_latency = rep.latency_ms;
    }
    // and the span must be material (paper: 3.4x energy, 2.4x latency)
    // (prev_* now hold the th=102 values)
    let mut chip0 = KwsChip::new(rng_quant(4), ChipConfig::design_point().with_delta_th(0));
    for u in &utts {
        chip0.process_utterance(&u.audio12);
    }
    let rep0 = chip0.report();
    assert!(rep0.energy_per_decision_nj / prev_energy > 1.5, "energy span too small");
}

#[test]
fn coordinator_under_load_conserves_requests() {
    let coord = Coordinator::builder(rng_quant(5), ChipConfig::design_point())
        .workers(3)
        .queue_depth(4)
        .build()
        .expect("valid pool");
    let ds = Dataset::new(5);
    let n = 18;
    let mut tickets = Vec::new();
    for i in 0..n {
        let utt = ds.utterance(Split::Test, i);
        let mut req = Request {
            id: 0,
            stream: (i % 5) as u64,
            audio12: utt.audio12,
            label: Some(utt.label),
            trace: false,
            weights: None,
        };
        loop {
            match coord.submit(req) {
                Ok(t) => {
                    tickets.push(t);
                    break;
                }
                Err(e) => {
                    assert!(e.is_queue_full(), "live pool reported Closed");
                    req = e.into_request();
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
        }
    }
    assert_eq!(tickets.len(), n);
    // conservation, per ticket: each resolves exactly its own request id
    for t in tickets {
        let id = t.id();
        let r = t.wait_timeout(Duration::from_secs(300)).expect("lost response");
        assert_eq!(r.id, id, "ticket resolved to a foreign response");
    }
}

#[test]
fn coordinator_survives_worker_stall_mid_run() {
    let coord = Coordinator::builder(rng_quant(6), ChipConfig::design_point())
        .workers(2)
        .queue_depth(8)
        .build()
        .expect("valid pool");
    let ds = Dataset::new(6);
    let mut tickets = Vec::new();
    // phase 1: normal
    for i in 0..4 {
        let utt = ds.utterance(Split::Test, i);
        let t = coord
            .submit(Request {
                id: 0,
                stream: i as u64,
                audio12: utt.audio12,
                label: None,
                trace: false,
                weights: None,
            })
            .unwrap();
        tickets.push(t);
    }
    // phase 2: stall worker 0, keep submitting (must spill or queue)
    coord.set_stalled(0, true);
    for i in 4..10 {
        let utt = ds.utterance(Split::Test, i);
        if let Ok(t) = coord
            .submit(Request {
                id: 0,
                stream: i as u64,
                audio12: utt.audio12,
                label: None,
                trace: false,
                weights: None,
            })
        {
            tickets.push(t);
        }
    }
    // phase 3: recover — every accepted request must still complete
    std::thread::sleep(Duration::from_millis(50));
    coord.set_stalled(0, false);
    for t in tickets {
        t.wait_timeout(Duration::from_secs(300)).expect("request lost across a stall");
    }
}

#[test]
fn malformed_audio_is_tolerated() {
    // short, empty and clipped inputs must not panic the chip
    let mut chip = KwsChip::new(rng_quant(7), ChipConfig::design_point());
    let d = chip.process_utterance(&[]);
    assert_eq!(d.frames, 0);
    assert!(!d.has_evidence());
    let d = chip.process_utterance(&vec![2047i64; 100]); // sub-frame
    assert_eq!(d.frames, 0);
    let d = chip.process_utterance(&vec![-2048i64; 8000]); // full-scale DC
    assert_eq!(d.frames, 62);
}

#[test]
fn sram_bank_utilisation_is_balanced_over_model_image() {
    // the weight image spans banks 0..=8; reads during inference should
    // touch several banks (no single-bank hotspot)
    let mut chip = KwsChip::new(rng_quant(8), ChipConfig::design_point().with_delta_th(0));
    let ds = Dataset::new(8);
    let utt = ds.utterance(Split::Test, 0);
    chip.process_utterance(&utt.audio12);
    let touched = chip.accel.sram.bank_reads.iter().filter(|&&r| r > 0).count();
    assert!(touched >= 6, "only {touched} banks touched");
}

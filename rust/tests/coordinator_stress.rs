//! Stress: N producer threads × M streams through one `Coordinator`.
//!
//! Asserts the serving contract under concurrency and injected failures:
//! request conservation (every accepted ticket resolves exactly its own
//! request id, and accepted + rejected == attempts), per-client mailbox
//! isolation (no cross-producer response theft), per-stream `stream_seq`
//! ordering (the v3 chain serializes a stream's requests no matter which
//! workers serve them), typed backpressure (bounded `QueueFull`
//! rejections with the request handed back, no loss) under a stalled
//! worker, and session churn (open/push/park/wake/swap/close interleaved
//! from concurrent clients). Audio is pre-rendered so the submission
//! phase itself is tight.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use deltakws::accel::gru::QuantParams;
use deltakws::chip::ChipConfig;
use deltakws::coordinator::{Coordinator, Request, Response, StreamEvent};
use deltakws::util::prng::Pcg;
use deltakws::SubmitError;

fn rng_quant(seed: u64) -> QuantParams {
    let mut rng = Pcg::new(seed);
    let mut q = QuantParams::zeroed();
    q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
    q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q
}

fn pool(seed: u64, workers: usize, queue_depth: usize) -> Coordinator {
    Coordinator::builder(rng_quant(seed), ChipConfig::design_point())
        .workers(workers)
        .queue_depth(queue_depth)
        .build()
        .expect("valid stress pool")
}

/// Short (sub-second) utterance: enough frames to exercise the chip while
/// keeping the stress run fast. The chip handles any length.
fn short_request(stream: u64, seed: u64) -> Request {
    let mut rng = Pcg::new(seed);
    let label = (seed % 12) as usize;
    let audio = deltakws::audio::synth_utterance(label, &mut rng);
    Request {
        id: 0,
        stream,
        audio12: deltakws::audio::quantize_12b(&audio[..1024]),
        label: Some(label),
        trace: false,
        weights: None,
    }
}

#[test]
fn stress_concurrent_producers_conserve_requests() {
    const THREADS: usize = 4;
    const STREAMS_PER_THREAD: usize = 2;
    const REQS_PER_STREAM: usize = 4;
    const TOTAL: usize = THREADS * STREAMS_PER_THREAD * REQS_PER_STREAM;

    let coord = pool(1, 3, 4);
    let attempts = AtomicUsize::new(0);
    let accepted = AtomicUsize::new(0);

    // pre-render audio outside the timed/concurrent section
    let mut work: Vec<Vec<Request>> = Vec::new();
    for t in 0..THREADS {
        let mut reqs = Vec::new();
        for s in 0..STREAMS_PER_THREAD {
            let stream = (t * STREAMS_PER_THREAD + s) as u64;
            for r in 0..REQS_PER_STREAM {
                reqs.push(short_request(stream, (stream * 100 + r as u64) + 1));
            }
        }
        work.push(reqs);
    }

    let mut responses: Vec<Response> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for reqs in work {
            let client = coord.client();
            let attempts = &attempts;
            let accepted = &accepted;
            handles.push(scope.spawn(move || {
                let mut tickets = Vec::new();
                for mut req in reqs {
                    // retry on typed backpressure, bail if the pool dies
                    loop {
                        attempts.fetch_add(1, Ordering::Relaxed);
                        match client.submit(req) {
                            Ok(t) => {
                                accepted.fetch_add(1, Ordering::Relaxed);
                                tickets.push(t);
                                break;
                            }
                            Err(e) => {
                                assert!(e.is_queue_full(), "pool died mid-run");
                                req = e.into_request().expect("QueueFull keeps the request");
                                std::thread::sleep(Duration::from_millis(2));
                            }
                        }
                    }
                }
                // every ticket resolves exactly its own request id — the
                // per-client mailbox cannot hand over foreign responses
                tickets
                    .into_iter()
                    .map(|t| {
                        let id = t.id();
                        let r = t
                            .wait_timeout(Duration::from_secs(300))
                            .expect("response lost");
                        assert_eq!(r.id, id, "cross-ticket response leak");
                        r
                    })
                    .collect::<Vec<Response>>()
            }));
        }
        for h in handles {
            responses.extend(h.join().expect("producer thread panicked"));
        }
    });

    let accepted = accepted.load(Ordering::Relaxed);
    assert_eq!(accepted, TOTAL, "every request must eventually be accepted");
    assert_eq!(responses.len(), accepted, "responses lost");

    // conservation: accepted ids are unique and complete exactly once
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), accepted, "duplicate or missing response ids");

    // attempts == accepted + rejected_full (each failed submit counts
    // once; a live pool under saturation never reports Closed)
    let stats = coord.stats();
    assert_eq!(stats.completed, accepted as u64);
    assert_eq!(stats.rejected_closed, 0, "live pool produced Closed rejections");
    assert_eq!(
        attempts.load(Ordering::Relaxed) as u64,
        accepted as u64 + stats.rejected_full,
        "attempt accounting broken: {} attempts, {} accepted, {} rejected_full",
        attempts.load(Ordering::Relaxed),
        accepted,
        stats.rejected_full
    );

    // per-stream ordering: each stream here has a single submitting
    // thread, so its requests enter the chain in ascending-id order and
    // the v3 chain must serve them in that order — dense `stream_seq`,
    // ids ascending along it, regardless of which workers ran the chain
    let mut by_stream: HashMap<u64, Vec<(u64, u64)>> = HashMap::new();
    for r in &responses {
        by_stream.entry(r.stream).or_default().push((r.stream_seq, r.id));
    }
    for (stream, seq) in by_stream.iter_mut() {
        seq.sort();
        let dense = seq.iter().enumerate().all(|(i, &(s, _))| s == i as u64);
        assert!(dense, "stream {stream} has gaps in stream_seq: {seq:?}");
        let ordered = seq.windows(2).all(|w| w[0].1 < w[1].1);
        assert!(ordered, "stream {stream} served out of submission order: {seq:?}");
    }
}

#[test]
fn stress_multi_client_ticket_isolation() {
    // N threads, each with its *own* Client (own mailbox), submitting
    // interleaved requests that share streams (and therefore workers)
    // across clients: every ticket must resolve to its own request id
    // with zero cross-talk — the property the v1 global collect() FIFO
    // could not provide
    const CLIENTS: usize = 4;
    const REQS: usize = 6;
    let coord = pool(4, 3, 8);
    std::thread::scope(|scope| {
        for c in 0..CLIENTS {
            let client = coord.client();
            scope.spawn(move || {
                let mut tickets = Vec::new();
                for r in 0..REQS {
                    // deliberately collide streams across clients so all
                    // clients' requests mix on the same worker queues
                    let stream = ((c + r) % 3) as u64;
                    let mut req = short_request(stream, (c * 100 + r) as u64 + 1);
                    loop {
                        match client.submit(req) {
                            Ok(t) => {
                                tickets.push(t);
                                break;
                            }
                            Err(SubmitError::QueueFull(back)) => {
                                req = back;
                                std::thread::sleep(Duration::from_millis(1));
                            }
                            Err(e) => panic!("pool died mid-run: {e}"),
                        }
                    }
                }
                for t in tickets {
                    let id = t.id();
                    let stream = t.stream();
                    let resp = t
                        .wait_timeout(Duration::from_secs(300))
                        .expect("ticket starved: response stolen or lost");
                    assert_eq!(resp.id, id, "cross-client response leak");
                    assert_eq!(resp.stream, stream, "response for a foreign stream");
                }
            });
        }
    });
    let stats = coord.stats();
    assert_eq!(stats.completed, (CLIENTS * REQS) as u64);
}

#[test]
fn stress_backpressure_under_stalled_worker() {
    // one of two workers stalls mid-run: the healthy worker keeps pulling
    // from the shared pool, and saturation sheds with clean typed
    // rejections — every accepted request completes after recovery
    let coord = pool(2, 2, 2);
    coord.set_stalled(0, true);

    let client = coord.client();
    let mut tickets = Vec::new();
    let mut rejected = 0u64;
    for i in 0..12 {
        match client.submit(short_request(0, 50 + i)) {
            Ok(t) => tickets.push(t),
            Err(e) => {
                // typed cause: saturation of a live pool is QueueFull,
                // and the request comes back intact for the retry path
                assert!(e.is_queue_full(), "live pool reported Closed");
                assert_eq!(e.request().expect("request handed back").stream, 0);
                rejected += 1;
            }
        }
    }
    let accepted = tickets.len() as u64;
    assert!(rejected > 0, "saturating a stalled pool must reject");
    assert!(accepted >= 2, "migration around the stalled worker is dead");
    assert_eq!(coord.stats().rejected_full, rejected);
    assert_eq!(coord.stats().rejected_closed, 0);

    coord.set_stalled(0, false);
    for t in tickets {
        t.wait_timeout(Duration::from_secs(300))
            .expect("accepted request lost across a stall");
    }
    let stats = coord.stats();
    assert_eq!(stats.completed, accepted);
    assert_eq!(stats.completed + stats.rejected_full, 12);
}

#[test]
fn soak_sustained_load_keeps_telemetry_flat_and_percentiles_honest() {
    use deltakws::coordinator::soak::{run_soak, SoakConfig};
    // scaled-down acceptance workload: mixed utterance + stream jobs from
    // concurrent producers; run_soak itself asserts the flat-memory
    // telemetry contract, the cross-checks below pin the rest
    let cfg = SoakConfig::quick();
    let report = run_soak(rng_quant(9), ChipConfig::design_point(), &cfg);
    assert_eq!(report.utterances_done, cfg.utterances);
    assert_eq!(report.chunks_done, cfg.streams as u64 * cfg.chunks_per_stream);
    assert_eq!(
        report.telemetry_bytes_early, report.telemetry_bytes_final,
        "Stats memory must be independent of request count"
    );
    assert!(
        report.percentile_rel_err() <= 0.05,
        "histogram percentiles {}% off exact",
        report.percentile_rel_err() * 100.0
    );
    assert!(report.decisions_per_sec > 0.0);
    let s = &report.final_stats;
    assert_eq!(s.latency.count(), cfg.utterances, "latency histogram lost samples");
    assert_eq!(
        s.chunk_latency.count(),
        cfg.streams as u64 * cfg.chunks_per_stream,
        "chunk histogram lost samples"
    );
    let done: u64 = s.per_worker.iter().map(|w| w.completed).sum();
    assert_eq!(done, cfg.utterances, "per-worker completions don't sum up");
}

#[test]
fn stress_many_streams_land_on_all_workers() {
    let coord = pool(3, 3, 8);
    let n = 9usize;
    let mut responses: Vec<Response> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for i in 0..n {
            let client = coord.client();
            handles.push(scope.spawn(move || {
                let mut req = short_request(i as u64, 200 + i as u64);
                loop {
                    match client.submit(req) {
                        Ok(t) => {
                            return t
                                .wait_timeout(Duration::from_secs(300))
                                .expect("response lost");
                        }
                        Err(e) => {
                            assert!(e.is_queue_full(), "pool died mid-run");
                            req = e.into_request().expect("QueueFull keeps the request");
                            std::thread::sleep(Duration::from_millis(2));
                        }
                    }
                }
            }));
        }
        for h in handles {
            responses.push(h.join().expect("producer thread panicked"));
        }
    });
    assert_eq!(responses.len(), n);
    // 9 concurrent chains against 3 pop-and-steal workers: the load must
    // spread (work stealing makes exact placement nondeterministic, so
    // ask for coverage, not a pinning map)
    let workers: std::collections::HashSet<usize> =
        responses.iter().map(|r| r.worker).collect();
    assert!(workers.len() >= 2, "9 concurrent streams served by a single worker");
}

#[test]
fn stress_churn_open_push_park_wake_swap_close_from_concurrent_clients() {
    // satellite: 4 client threads random-interleaving the whole session
    // lifecycle — open, push (wakes a parked session), idle-wait (lets it
    // re-park), swap_weights, close — with utterance tickets mixed in on
    // *shared* stream ids. Every ticket must resolve to its submitter's
    // mailbox, each client's submissions on a stream must serve in
    // submission order (ascending `stream_seq`), and the pool must end
    // with zero live sessions and zero session bytes.
    const CLIENTS: usize = 4;
    const ROUNDS: usize = 5;
    let coord = pool(11, 3, 8);
    let alt = coord.registry().insert(rng_quant(99), Some(coord.base_version()));

    // one pre-rendered chunk shared by every session push
    let chunk: Vec<i64> = {
        let mut rng = Pcg::new(77);
        let audio = deltakws::audio::synth_utterance(3, &mut rng);
        deltakws::audio::quantize_12b(&audio[..512])
    };

    std::thread::scope(|scope| {
        for c in 0..CLIENTS {
            let coord = &coord;
            let chunk = &chunk;
            scope.spawn(move || {
                let client = coord.client();
                let mut rng = Pcg::new(0xC0FFEE + c as u64);
                let mut seq_by_stream: HashMap<u64, Vec<u64>> = HashMap::new();
                for round in 0..ROUNDS {
                    // sessions use per-client ids; utterances share ids
                    // across clients so their chains interleave
                    let sess_id = (1000 + c * ROUNDS + round) as u64;
                    let sess = coord.open_stream(sess_id).expect("under high-water mark");
                    sess.push_blocking(chunk.clone()).expect("pool alive");
                    if rng.below(2) == 0 {
                        // idle long enough for the session to drain and
                        // re-park, so the next push exercises the wake
                        // path (bounded, best-effort — no assert: other
                        // clients keep the pool busy)
                        let deadline = Instant::now() + Duration::from_millis(50);
                        while coord.stats().sessions_runnable > 0
                            && Instant::now() < deadline
                        {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                    }
                    if rng.below(2) == 0 {
                        coord.swap_weights(&sess, alt).expect("swap accepted");
                    }
                    sess.push_blocking(chunk.clone()).expect("pool alive");

                    // interleaved utterance on a stream id shared by all
                    // clients — chains migrate freely across workers
                    let shared = (round % 2) as u64;
                    let mut req = short_request(shared, (c * 1000 + round) as u64 + 1);
                    let ticket = loop {
                        match client.submit(req) {
                            Ok(t) => break t,
                            Err(e) => {
                                assert!(e.is_queue_full(), "pool died mid-run");
                                req = e.into_request().expect("request kept");
                                std::thread::sleep(Duration::from_millis(1));
                            }
                        }
                    };
                    let id = ticket.id();
                    let resp = ticket
                        .wait_timeout(Duration::from_secs(300))
                        .expect("ticket starved: response lost or stolen");
                    assert_eq!(resp.id, id, "ticket resolved a foreign response");
                    assert_eq!(resp.stream, shared, "response for a foreign stream");
                    seq_by_stream.entry(shared).or_default().push(resp.stream_seq);

                    let events = sess.close();
                    assert!(
                        matches!(events.last(), Some(StreamEvent::Closed { .. })),
                        "churned session closed without its Closed marker"
                    );
                }
                // this client's submissions on a shared stream happened
                // in program order, so their chain positions must ascend
                // even though other clients' requests interleave between
                for (stream, seqs) in seq_by_stream {
                    assert!(
                        seqs.windows(2).all(|w| w[0] < w[1]),
                        "client {c} saw stream {stream} out of order: {seqs:?}"
                    );
                }
            });
        }
    });

    let stats = coord.stats();
    assert_eq!(stats.completed, (CLIENTS * ROUNDS) as u64);
    assert_eq!(stats.sessions_parked, 0, "closed sessions still parked");
    assert_eq!(stats.sessions_runnable, 0, "closed sessions still runnable");
    assert_eq!(stats.session_bytes, 0, "session memory leaked after churn");
    // most sessions drain and re-park while their client blocks on the
    // interleaved ticket; a session closed mid-drain legitimately never
    // re-parks, so ask for evidence of parking, not a per-session count
    assert!(stats.park_transitions >= 1, "churned sessions never parked");
    assert!(stats.weight_swaps <= (CLIENTS * ROUNDS) as u64);
}

//! Stress: N producer threads × M streams through one `Coordinator`.
//!
//! Asserts the serving contract under concurrency and injected failures:
//! request conservation (every accepted id completes exactly once, and
//! accepted + rejected == attempts), per-stream ordering on the pinned
//! path, and backpressure (bounded rejections, no loss) under a stalled
//! worker. Audio is pre-rendered so the submission phase itself is tight.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use deltakws::accel::gru::QuantParams;
use deltakws::chip::ChipConfig;
use deltakws::coordinator::{Coordinator, Request};
use deltakws::util::prng::Pcg;

fn rng_quant(seed: u64) -> QuantParams {
    let mut rng = Pcg::new(seed);
    let mut q = QuantParams::zeroed();
    q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
    q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
    q
}

/// Short (sub-second) utterance: enough frames to exercise the chip while
/// keeping the stress run fast. The chip handles any length.
fn short_request(stream: u64, seed: u64) -> Request {
    let mut rng = Pcg::new(seed);
    let label = (seed % 12) as usize;
    let audio = deltakws::audio::synth_utterance(label, &mut rng);
    Request {
        id: 0,
        stream,
        audio12: deltakws::audio::quantize_12b(&audio[..1024]),
        label: Some(label),
    }
}

#[test]
fn stress_concurrent_producers_conserve_requests() {
    const THREADS: usize = 4;
    const STREAMS_PER_THREAD: usize = 2;
    const REQS_PER_STREAM: usize = 4;
    const TOTAL: usize = THREADS * STREAMS_PER_THREAD * REQS_PER_STREAM;

    let coord = Coordinator::new(rng_quant(1), ChipConfig::design_point(), 3, 4);
    let attempts = AtomicUsize::new(0);
    let accepted = AtomicUsize::new(0);

    // pre-render audio outside the timed/concurrent section
    let mut work: Vec<Vec<Request>> = Vec::new();
    for t in 0..THREADS {
        let mut reqs = Vec::new();
        for s in 0..STREAMS_PER_THREAD {
            let stream = (t * STREAMS_PER_THREAD + s) as u64;
            for r in 0..REQS_PER_STREAM {
                reqs.push(short_request(stream, (stream * 100 + r as u64) + 1));
            }
        }
        work.push(reqs);
    }

    std::thread::scope(|scope| {
        for reqs in work {
            let client = coord.client();
            let attempts = &attempts;
            let accepted = &accepted;
            scope.spawn(move || {
                for mut req in reqs {
                    // retry on backpressure, bail if the pool disappears
                    loop {
                        attempts.fetch_add(1, Ordering::Relaxed);
                        match client.submit(req) {
                            Ok(_) => {
                                accepted.fetch_add(1, Ordering::Relaxed);
                                break;
                            }
                            Err(r) => {
                                assert!(!client.is_closed(), "pool died mid-run");
                                req = r;
                                std::thread::sleep(Duration::from_millis(2));
                            }
                        }
                    }
                }
            });
        }
    });

    let accepted = accepted.load(Ordering::Relaxed);
    assert_eq!(accepted, TOTAL, "every request must eventually be accepted");
    let responses = coord.collect(accepted, Duration::from_secs(300));
    assert_eq!(responses.len(), accepted, "responses lost");

    // conservation: accepted ids are unique and complete exactly once
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), accepted, "duplicate or missing response ids");

    // attempts == accepted + rejected (each failed submit counts once)
    let stats = coord.stats();
    assert_eq!(stats.completed, accepted as u64);
    assert_eq!(
        attempts.load(Ordering::Relaxed) as u64,
        accepted as u64 + stats.rejected,
        "attempt accounting broken: {} attempts, {} accepted, {} rejected",
        attempts.load(Ordering::Relaxed),
        accepted,
        stats.rejected
    );

    // per-stream ordering: a stream served entirely by one worker went
    // through a single FIFO, so its ids must arrive in submission order
    // (the spill path intentionally trades ordering for availability)
    let mut by_stream: HashMap<u64, Vec<(u64, usize)>> = HashMap::new();
    for r in &responses {
        by_stream.entry(r.stream).or_default().push((r.id, r.worker));
    }
    let mut pinned_streams = 0;
    for (stream, seq) in &by_stream {
        let workers: std::collections::HashSet<usize> =
            seq.iter().map(|&(_, w)| w).collect();
        if workers.len() == 1 {
            pinned_streams += 1;
            let ordered = seq.windows(2).all(|w| w[0].0 < w[1].0);
            assert!(ordered, "stream {stream} reordered on its pinned worker: {seq:?}");
        }
    }
    assert!(pinned_streams >= 1, "no stream stayed pinned — ordering never exercised");
}

#[test]
fn stress_backpressure_under_stalled_worker() {
    // one of two workers stalls mid-run: the router must spill, then shed
    // with clean rejections once both queues are full — and complete every
    // accepted request after recovery
    let coord = Coordinator::new(rng_quant(2), ChipConfig::design_point(), 2, 2);
    coord.set_stalled(0, true);

    let client = coord.client();
    let mut accepted = 0u64;
    let mut rejected = 0u64;
    for i in 0..12 {
        match client.submit(short_request(0, 50 + i)) {
            Ok(_) => accepted += 1,
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "saturating a stalled pool must reject");
    assert!(accepted >= 2, "spill around the stalled worker is dead");
    assert_eq!(coord.stats().rejected, rejected);

    coord.set_stalled(0, false);
    let responses = coord.collect(accepted as usize, Duration::from_secs(300));
    assert_eq!(responses.len(), accepted as usize, "accepted requests lost across a stall");
    let stats = coord.stats();
    assert_eq!(stats.completed, accepted);
    assert_eq!(stats.completed + stats.rejected, 12);
}

#[test]
fn soak_sustained_load_keeps_telemetry_flat_and_percentiles_honest() {
    use deltakws::coordinator::soak::{run_soak, SoakConfig};
    // scaled-down acceptance workload: mixed utterance + stream jobs from
    // concurrent producers; run_soak itself asserts the flat-memory
    // telemetry contract, the cross-checks below pin the rest
    let cfg = SoakConfig::quick();
    let report = run_soak(rng_quant(9), ChipConfig::design_point(), &cfg);
    assert_eq!(report.utterances_done, cfg.utterances);
    assert_eq!(report.chunks_done, cfg.streams as u64 * cfg.chunks_per_stream);
    assert_eq!(
        report.telemetry_bytes_early, report.telemetry_bytes_final,
        "Stats memory must be independent of request count"
    );
    assert!(
        report.percentile_rel_err() <= 0.05,
        "histogram percentiles {}% off exact",
        report.percentile_rel_err() * 100.0
    );
    assert!(report.decisions_per_sec > 0.0);
    let s = &report.final_stats;
    assert_eq!(s.latency.count(), cfg.utterances, "latency histogram lost samples");
    assert_eq!(
        s.chunk_latency.count(),
        cfg.streams as u64 * cfg.chunks_per_stream,
        "chunk histogram lost samples"
    );
    let done: u64 = s.per_worker.iter().map(|w| w.completed).sum();
    assert_eq!(done, cfg.utterances, "per-worker completions don't sum up");
}

#[test]
fn stress_many_streams_land_on_all_workers() {
    let coord = Coordinator::new(rng_quant(3), ChipConfig::design_point(), 3, 8);
    let n = 9usize;
    std::thread::scope(|scope| {
        for i in 0..n {
            let client = coord.client();
            scope.spawn(move || {
                let mut req = short_request(i as u64, 200 + i as u64);
                loop {
                    match client.submit(req) {
                        Ok(_) => break,
                        Err(r) => {
                            req = r;
                            std::thread::sleep(Duration::from_millis(2));
                        }
                    }
                }
            });
        }
    });
    let responses = coord.collect(n, Duration::from_secs(300));
    assert_eq!(responses.len(), n);
    let workers: std::collections::HashSet<usize> =
        responses.iter().map(|r| r.worker).collect();
    assert_eq!(workers.len(), 3, "9 distinct streams must cover all 3 workers");
}

//! Flight recorder: bounded per-worker event rings with anomaly-triggered
//! post-mortem dumps (DESIGN.md §12).
//!
//! Each worker owns one [`FlightRecorder`]: a fixed-capacity ring of
//! [`Event`]s stamped with [`monotonic_us`] timestamps and the request's
//! [`TraceId`]. Coordinator-level hooks record queue-shaped events
//! (submit, dequeue, decision, backpressure, drop); chip-level activity is
//! folded through [`RecorderProbe`], which composes the zero-cost
//! [`ChipProbe`] hooks into per-batch counters and gate-edge events — the
//! ring sees one [`EventKind::FrameBatch`] per utterance/chunk, never
//! per-frame traffic.
//!
//! When an [`AnomalyRule`] matches a freshly-recorded event (a wakeword
//! fire, a latency excursion, a backpressure burst), the ring is frozen
//! into a [`FlightDump`] — the last-N-events post-mortem for "why did
//! *this* utterance misbehave?" — retrievable via
//! [`Coordinator::flight_dumps`](crate::coordinator::Coordinator::flight_dumps).
//!
//! A recorder built with [`FlightRecorder::disabled`] (the default for
//! pools that never call
//! [`CoordinatorBuilder::recorder`](crate::coordinator::CoordinatorBuilder::recorder))
//! reduces every [`record`](FlightRecorder::record) to one predictable
//! branch: the lean path stays allocation-free and lock-free.

use std::collections::VecDeque;
use std::sync::Mutex;

use super::{monotonic_us, TraceId};
use crate::chip::FrameOut;
use crate::probe::{ChipProbe, CountingProbe};

/// Default ring capacity (events retained per worker).
pub const DEFAULT_RING_CAPACITY: usize = 256;

/// Default bound on frozen dumps held per worker before oldest-first drop.
pub const DEFAULT_DUMP_CAP: usize = 8;

/// What happened, with the event-specific payload inline.
///
/// Variants are `Copy` and small by design: the ring stores events by
/// value, so recording never allocates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A request (or stream open) was accepted into a lane by the router.
    Submit,
    /// A worker picked the job off its lane after `queued_us` in the queue.
    Dequeue {
        /// microseconds the job spent queued before the worker saw it
        queued_us: u64,
    },
    /// Per-frame probe counters folded over one utterance / audio chunk.
    FrameBatch {
        /// frames consumed (gated + ungated)
        frames: u32,
        /// frames consumed with the ΔRNN clock-gated
        gated: u32,
        /// fired Δ-lanes (input + hidden) summed over the batch
        fired: u32,
    },
    /// The VAD opened the ΔRNN clock gate (idle → active edge).
    GateOpen,
    /// The VAD closed the gate (active → idle edge).
    GateClose,
    /// An utterance decision completed.
    Decision {
        /// winning class index
        class: u8,
        /// enqueue-to-decision service time in microseconds
        service_us: u64,
    },
    /// The wakeword state machine fired on a streaming session.
    Detection {
        /// detected class index
        class: u8,
    },
    /// A submission or stream push was refused with the queue saturated.
    Backpressure,
    /// A stream event was shed on a full per-session channel.
    EventDropped,
    /// A streaming session opened on this worker.
    SessionOpen,
    /// A streaming session closed (client close, GC or shutdown).
    SessionClose,
}

/// One recorded event: ring sequence number, monotonic timestamp, the
/// request's trace id, the owning worker, and the [`EventKind`] payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// per-recorder monotonic sequence number (never reused)
    pub seq: u64,
    /// [`monotonic_us`] timestamp (shared process timebase)
    pub at_us: u64,
    /// the request this event belongs to ([`TraceId::NONE`] if none)
    pub trace: TraceId,
    /// worker index that recorded the event
    pub worker: u32,
    /// what happened
    pub kind: EventKind,
}

/// Condition that freezes the ring into a [`FlightDump`] when a
/// just-recorded event matches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AnomalyRule {
    /// A [`Decision`](EventKind::Decision) or
    /// [`Detection`](EventKind::Detection) for this class — e.g. a
    /// wakeword fire, or an always-suspicious class.
    DecisionClass {
        /// class index to trip on
        class: usize,
    },
    /// A [`Decision`](EventKind::Decision) whose service time exceeded
    /// `us` — the p99-excursion trigger.
    LatencyAboveUs {
        /// service-time threshold in microseconds (strictly above trips)
        us: u64,
    },
    /// At least `count` [`Backpressure`](EventKind::Backpressure) events
    /// (including the current one) within the trailing `window_us`
    /// microseconds still held by the ring — the QueueFull-burst trigger.
    BackpressureBurst {
        /// backpressure events required within the window
        count: usize,
        /// trailing window in microseconds
        window_us: u64,
    },
}

/// Flight-recorder configuration, passed to
/// [`CoordinatorBuilder::recorder`](crate::coordinator::CoordinatorBuilder::recorder).
#[derive(Debug, Clone)]
pub struct RecorderConfig {
    /// ring capacity in events per worker (must be ≥ 1)
    pub capacity: usize,
    /// frozen dumps held per worker before oldest-first drop (must be ≥ 1)
    pub dump_cap: usize,
    /// anomaly rules evaluated against every recorded event
    pub rules: Vec<AnomalyRule>,
}

impl Default for RecorderConfig {
    fn default() -> Self {
        RecorderConfig {
            capacity: DEFAULT_RING_CAPACITY,
            dump_cap: DEFAULT_DUMP_CAP,
            rules: Vec::new(),
        }
    }
}

impl RecorderConfig {
    /// Add an anomaly rule (builder-style).
    pub fn dump_on(mut self, rule: AnomalyRule) -> Self {
        self.rules.push(rule);
        self
    }
}

/// A frozen post-mortem: the ring contents at the moment `rule` matched
/// `trigger`, oldest event first.
#[derive(Debug, Clone)]
pub struct FlightDump {
    /// the rule that fired
    pub rule: AnomalyRule,
    /// the event that tripped it (also the last entry of `events`)
    pub trigger: Event,
    /// ring contents at freeze time, oldest first
    pub events: Vec<Event>,
}

impl FlightDump {
    /// The subset of events belonging to one request, oldest first — the
    /// trace-correlated timeline for the offending utterance.
    pub fn events_for(&self, trace: TraceId) -> Vec<Event> {
        self.events.iter().filter(|e| e.trace == trace).copied().collect()
    }
}

/// Folded recorder totals, exposed through the metrics snapshot.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecorderStats {
    /// events recorded since startup (including ones the ring evicted)
    pub events: u64,
    /// dumps frozen by anomaly rules
    pub dumps_taken: u64,
    /// frozen dumps discarded oldest-first at the dump cap
    pub dumps_dropped: u64,
    /// dumps currently held (un-drained)
    pub dumps_held: u64,
}

impl RecorderStats {
    /// Fold another recorder's totals into this one (per-worker → pool).
    pub fn merge(&mut self, other: &RecorderStats) {
        self.events += other.events;
        self.dumps_taken += other.dumps_taken;
        self.dumps_dropped += other.dumps_dropped;
        self.dumps_held += other.dumps_held;
    }
}

struct Inner {
    ring: VecDeque<Event>,
    seq: u64,
    events: u64,
    dumps: VecDeque<FlightDump>,
    dumps_taken: u64,
    dumps_dropped: u64,
}

/// One worker's bounded event ring plus its frozen dumps.
///
/// The mutex is uncontended in practice — each worker records onto its own
/// recorder; readers ([`stats`](Self::stats) / [`take_dumps`](Self::take_dumps))
/// run at snapshot cadence, not per event.
pub struct FlightRecorder {
    enabled: bool,
    capacity: usize,
    dump_cap: usize,
    rules: Vec<AnomalyRule>,
    inner: Mutex<Inner>,
}

impl std::fmt::Debug for FlightRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlightRecorder")
            .field("enabled", &self.enabled)
            .field("capacity", &self.capacity)
            .field("dump_cap", &self.dump_cap)
            .field("rules", &self.rules)
            .finish_non_exhaustive()
    }
}

impl FlightRecorder {
    /// An enabled recorder with the given configuration.
    pub fn new(config: RecorderConfig) -> Self {
        Self::build(config, true)
    }

    /// The disabled recorder: [`record`](Self::record) is a single branch,
    /// [`stats`](Self::stats) reports zeros. Pools built without
    /// [`CoordinatorBuilder::recorder`](crate::coordinator::CoordinatorBuilder::recorder)
    /// use this so the lean path carries no ring, no lock traffic and no
    /// timestamp reads.
    pub fn disabled() -> Self {
        Self::build(
            RecorderConfig { capacity: 1, dump_cap: 1, rules: Vec::new() },
            false,
        )
    }

    fn build(config: RecorderConfig, enabled: bool) -> Self {
        let capacity = config.capacity.max(1);
        FlightRecorder {
            enabled,
            capacity,
            dump_cap: config.dump_cap.max(1),
            rules: config.rules,
            inner: Mutex::new(Inner {
                ring: VecDeque::with_capacity(if enabled { capacity } else { 0 }),
                seq: 0,
                events: 0,
                dumps: VecDeque::new(),
                dumps_taken: 0,
                dumps_dropped: 0,
            }),
        }
    }

    /// True when this recorder actually records. Callers use this to skip
    /// probe construction / timestamp math entirely on the lean path.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Record one event (no-op when disabled). Evicts the oldest event at
    /// capacity, then evaluates the anomaly rules against the new event;
    /// the first match freezes the ring into a [`FlightDump`].
    pub fn record(&self, worker: u32, trace: TraceId, kind: EventKind) {
        if !self.enabled {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        let ev = Event { seq: g.seq, at_us: monotonic_us(), trace, worker, kind };
        g.seq += 1;
        g.events += 1;
        if g.ring.len() == self.capacity {
            g.ring.pop_front();
        }
        g.ring.push_back(ev);
        if let Some(rule) = self.rules.iter().find(|r| rule_hits(r, &ev, &g.ring)).copied() {
            if g.dumps.len() == self.dump_cap {
                g.dumps.pop_front();
                g.dumps_dropped += 1;
            }
            let events: Vec<Event> = g.ring.iter().copied().collect();
            g.dumps.push_back(FlightDump { rule, trigger: ev, events });
            g.dumps_taken += 1;
        }
    }

    /// Drain the frozen dumps, oldest first.
    pub fn take_dumps(&self) -> Vec<FlightDump> {
        let mut g = self.inner.lock().unwrap();
        g.dumps.drain(..).collect()
    }

    /// Totals for the metrics snapshot.
    pub fn stats(&self) -> RecorderStats {
        let g = self.inner.lock().unwrap();
        RecorderStats {
            events: g.events,
            dumps_taken: g.dumps_taken,
            dumps_dropped: g.dumps_dropped,
            dumps_held: g.dumps.len() as u64,
        }
    }
}

fn rule_hits(rule: &AnomalyRule, ev: &Event, ring: &VecDeque<Event>) -> bool {
    match *rule {
        AnomalyRule::DecisionClass { class } => match ev.kind {
            EventKind::Decision { class: c, .. } | EventKind::Detection { class: c } => {
                c as usize == class
            }
            _ => false,
        },
        AnomalyRule::LatencyAboveUs { us } => {
            matches!(ev.kind, EventKind::Decision { service_us, .. } if service_us > us)
        }
        AnomalyRule::BackpressureBurst { count, window_us } => {
            if !matches!(ev.kind, EventKind::Backpressure) {
                return false;
            }
            let horizon = ev.at_us.saturating_sub(window_us);
            let recent = ring
                .iter()
                .rev()
                .take_while(|e| e.at_us >= horizon)
                .filter(|e| matches!(e.kind, EventKind::Backpressure))
                .count();
            recent >= count
        }
    }
}

/// The recorder's [`ChipProbe`]: folds per-frame hooks into
/// [`CountingProbe`] counters and emits gate-edge events in real time; the
/// accumulated counters become one [`EventKind::FrameBatch`] on
/// [`flush_frame_batch`](Self::flush_frame_batch).
///
/// Gate state threads across probe instances (chunked stream pushes) via
/// [`with_gate_state`](Self::with_gate_state) / [`gate_state`](Self::gate_state),
/// so a gate edge spanning two audio chunks is still recorded exactly once.
#[derive(Debug)]
pub struct RecorderProbe<'a> {
    rec: &'a FlightRecorder,
    worker: u32,
    trace: TraceId,
    /// per-frame counters accumulated since the last flush
    pub counters: CountingProbe,
    last_gated: Option<bool>,
}

impl<'a> RecorderProbe<'a> {
    /// A probe with unknown prior gate state (fresh utterance): the first
    /// frame establishes the state and emits the corresponding edge event.
    pub fn new(rec: &'a FlightRecorder, worker: u32, trace: TraceId) -> Self {
        Self::with_gate_state(rec, worker, trace, None)
    }

    /// A probe resuming a session whose last-seen gate state is known.
    pub fn with_gate_state(
        rec: &'a FlightRecorder,
        worker: u32,
        trace: TraceId,
        last_gated: Option<bool>,
    ) -> Self {
        RecorderProbe { rec, worker, trace, counters: CountingProbe::default(), last_gated }
    }

    /// Gate state after the frames seen so far (`Some(true)` = gated /
    /// clock off), for threading into the next probe instance.
    pub fn gate_state(&self) -> Option<bool> {
        self.last_gated
    }

    /// Emit one [`EventKind::FrameBatch`] from the accumulated counters
    /// and reset them. No event is emitted if no frame completed.
    pub fn flush_frame_batch(&mut self) {
        if self.counters.frames == 0 {
            return;
        }
        let clamp = |v: u64| v.min(u32::MAX as u64) as u32;
        self.rec.record(
            self.worker,
            self.trace,
            EventKind::FrameBatch {
                frames: clamp(self.counters.frames),
                gated: clamp(self.counters.gated),
                fired: clamp(self.counters.fired_x + self.counters.fired_h),
            },
        );
        self.counters = CountingProbe::default();
    }
}

impl ChipProbe for RecorderProbe<'_> {
    #[inline]
    fn frame_completed(&mut self, frame: &FrameOut) {
        self.counters.frame_completed(frame);
        if self.last_gated != Some(frame.gated) {
            self.last_gated = Some(frame.gated);
            let edge = if frame.gated { EventKind::GateClose } else { EventKind::GateOpen };
            self.rec.record(self.worker, self.trace, edge);
        }
    }

    #[inline]
    fn lanes_fired(&mut self, fired_x: usize, fired_h: usize) {
        self.counters.lanes_fired(fired_x, fired_h);
    }

    #[inline]
    fn sram_row_read(&mut self, base_word: usize, words: usize) {
        self.counters.sram_row_read(base_word, words);
    }

    #[inline]
    fn gate_skipped(&mut self, index: u64) {
        self.counters.gate_skipped(index);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fex::MAX_CHANNELS;

    fn frame(index: u64, gated: bool) -> FrameOut {
        FrameOut {
            index,
            feat: [0i64; MAX_CHANNELS],
            logits: [0i64; crate::NUM_CLASSES],
            fired: 2,
            cycles: 10,
            gated,
        }
    }

    fn kinds(rec: &FlightRecorder) -> Vec<EventKind> {
        let g = rec.inner.lock().unwrap();
        g.ring.iter().map(|e| e.kind).collect()
    }

    #[test]
    fn ring_bounded_and_seq_monotonic() {
        let rec = FlightRecorder::new(RecorderConfig {
            capacity: 4,
            ..RecorderConfig::default()
        });
        for i in 0..10 {
            rec.record(0, TraceId(i), EventKind::Submit);
        }
        let g = rec.inner.lock().unwrap();
        assert_eq!(g.ring.len(), 4, "ring must stay at capacity");
        let seqs: Vec<u64> = g.ring.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9], "oldest evicted, seq never reused");
        assert_eq!(g.events, 10, "events counts evictions too");
    }

    #[test]
    fn disabled_recorder_is_a_no_op() {
        let rec = FlightRecorder::disabled();
        assert!(!rec.is_enabled());
        rec.record(0, TraceId(1), EventKind::Submit);
        assert_eq!(rec.stats(), RecorderStats::default());
        assert!(rec.take_dumps().is_empty());
    }

    #[test]
    fn decision_class_rule_freezes_ring() {
        let rec = FlightRecorder::new(
            RecorderConfig::default().dump_on(AnomalyRule::DecisionClass { class: 11 }),
        );
        let t = TraceId(7);
        rec.record(0, t, EventKind::Submit);
        rec.record(0, t, EventKind::Dequeue { queued_us: 5 });
        rec.record(0, t, EventKind::Decision { class: 3, service_us: 10 });
        assert!(rec.take_dumps().is_empty(), "class 3 must not trip a class-11 rule");
        rec.record(0, t, EventKind::Decision { class: 11, service_us: 20 });
        let dumps = rec.take_dumps();
        assert_eq!(dumps.len(), 1);
        let d = &dumps[0];
        assert_eq!(d.rule, AnomalyRule::DecisionClass { class: 11 });
        assert_eq!(d.trigger.kind, EventKind::Decision { class: 11, service_us: 20 });
        assert_eq!(d.events.len(), 4, "dump holds the whole ring");
        assert_eq!(*d.events.last().unwrap(), d.trigger);
        assert_eq!(d.events_for(t).len(), 4);
        assert!(d.events_for(TraceId(99)).is_empty());
        assert!(rec.take_dumps().is_empty(), "take_dumps drains");
    }

    #[test]
    fn detection_trips_decision_class_rule() {
        let rec = FlightRecorder::new(
            RecorderConfig::default().dump_on(AnomalyRule::DecisionClass { class: 11 }),
        );
        rec.record(0, TraceId(1), EventKind::Detection { class: 11 });
        assert_eq!(rec.take_dumps().len(), 1, "wakeword fire must dump");
    }

    #[test]
    fn latency_rule_is_strictly_above() {
        let rec = FlightRecorder::new(
            RecorderConfig::default().dump_on(AnomalyRule::LatencyAboveUs { us: 100 }),
        );
        rec.record(0, TraceId(1), EventKind::Decision { class: 0, service_us: 100 });
        assert!(rec.take_dumps().is_empty());
        rec.record(0, TraceId(2), EventKind::Decision { class: 0, service_us: 101 });
        assert_eq!(rec.take_dumps().len(), 1);
    }

    #[test]
    fn backpressure_burst_counts_window() {
        let rec = FlightRecorder::new(RecorderConfig::default().dump_on(
            AnomalyRule::BackpressureBurst { count: 3, window_us: u64::MAX },
        ));
        rec.record(0, TraceId::NONE, EventKind::Backpressure);
        rec.record(0, TraceId::NONE, EventKind::Backpressure);
        assert!(rec.take_dumps().is_empty(), "2 < count");
        rec.record(0, TraceId::NONE, EventKind::Backpressure);
        let dumps = rec.take_dumps();
        assert_eq!(dumps.len(), 1);
        assert_eq!(dumps[0].trigger.kind, EventKind::Backpressure);
    }

    #[test]
    fn dump_cap_drops_oldest() {
        let rec = FlightRecorder::new(RecorderConfig {
            capacity: 8,
            dump_cap: 2,
            rules: vec![AnomalyRule::DecisionClass { class: 0 }],
        });
        for i in 0..3u64 {
            rec.record(0, TraceId(i + 1), EventKind::Decision { class: 0, service_us: i });
        }
        let stats = rec.stats();
        assert_eq!(stats.dumps_taken, 3);
        assert_eq!(stats.dumps_dropped, 1);
        assert_eq!(stats.dumps_held, 2);
        let dumps = rec.take_dumps();
        assert_eq!(dumps.len(), 2);
        assert_eq!(
            dumps[0].trigger.kind,
            EventKind::Decision { class: 0, service_us: 1 },
            "oldest dump was dropped"
        );
    }

    #[test]
    fn recorder_probe_emits_edges_and_one_batch() {
        let rec = FlightRecorder::new(RecorderConfig::default());
        let t = TraceId(5);
        let mut p = RecorderProbe::new(&rec, 0, t);
        // active, active, gated, gated, active: two edges + the initial one
        p.frame_completed(&frame(0, false));
        p.frame_completed(&frame(1, false));
        p.gate_skipped(2);
        p.frame_completed(&frame(2, true));
        p.frame_completed(&frame(3, true));
        p.frame_completed(&frame(4, false));
        p.lanes_fired(3, 4);
        assert_eq!(p.gate_state(), Some(false));
        p.flush_frame_batch();
        p.flush_frame_batch(); // second flush: empty counters, no event
        assert_eq!(
            kinds(&rec),
            vec![
                EventKind::GateOpen,
                EventKind::GateClose,
                EventKind::GateOpen,
                EventKind::FrameBatch { frames: 5, gated: 1, fired: 7 },
            ]
        );
        let g = rec.inner.lock().unwrap();
        assert!(g.ring.iter().all(|e| e.trace == t));
    }

    #[test]
    fn recorder_probe_threads_gate_state_across_chunks() {
        let rec = FlightRecorder::new(RecorderConfig::default());
        let mut p1 = RecorderProbe::new(&rec, 0, TraceId(1));
        p1.frame_completed(&frame(0, true));
        let carried = p1.gate_state();
        p1.flush_frame_batch();
        assert_eq!(carried, Some(true));
        // same gate state in the next chunk: no spurious edge
        let mut p2 = RecorderProbe::with_gate_state(&rec, 0, TraceId(1), carried);
        p2.frame_completed(&frame(1, true));
        p2.flush_frame_batch();
        let edge_count = kinds(&rec)
            .iter()
            .filter(|k| matches!(k, EventKind::GateClose | EventKind::GateOpen))
            .count();
        assert_eq!(edge_count, 1, "one edge for the initial state, none for the resume");
    }

    #[test]
    fn timestamps_monotonic_within_ring() {
        let rec = FlightRecorder::new(RecorderConfig::default());
        for i in 0..5 {
            rec.record(0, TraceId(i), EventKind::Submit);
        }
        let g = rec.inner.lock().unwrap();
        let ts: Vec<u64> = g.ring.iter().map(|e| e.at_us).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
    }
}

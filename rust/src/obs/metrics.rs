//! Metrics exposition: fold [`Stats`] into a versioned snapshot and
//! serialize it as Prometheus-style text and JSON (DESIGN.md §12).
//!
//! [`MetricsRegistry`] is the stateful folder
//! ([`Coordinator::metrics`](crate::coordinator::Coordinator::metrics)
//! holds one): each [`fold`](MetricsRegistry::fold) bumps the snapshot
//! sequence number and, from the second fold on, attaches a
//! [`StatsDelta`] computed against the previous snapshot so rates
//! (decisions/sec, drops/sec) come straight off the exposition instead of
//! being re-derived by hand. [`MetricsSnapshot::from_stats`] is the
//! stateless one-shot for harnesses that already hold a [`Stats`].
//!
//! The serialized field names, label sets and histogram bucket layout are
//! a **stable schema** ([`METRICS_SCHEMA`]), pinned by
//! `tests/obs_exposition.rs` and validated in CI by
//! `tools/bench_report.py --validate-metrics` against the soak run's
//! emitted snapshot.

use crate::coordinator::{Stats, StatsDelta};
use crate::util::hist::LogHistogram;
use crate::util::json::Json;

use super::recorder::RecorderStats;

/// Schema tag stamped on every snapshot (bump on any breaking change to
/// field names, label sets or bucket layout).
pub const METRICS_SCHEMA: &str = "deltakws-metrics/3";

/// `le` bounds (µs) for the exposed latency histograms. All powers of two
/// ≥ 32, i.e. exact [`LogHistogram`] bucket boundaries, so the cumulative
/// counts from [`LogHistogram::count_below`] are exact — with one
/// documented skew: `le="N"` here means *strictly below* N µs (Prometheus
/// proper is inclusive; at an exact boundary the difference is only the
/// samples equal to N).
pub const LATENCY_LE_US: [u64; 8] =
    [128, 512, 2_048, 8_192, 32_768, 131_072, 524_288, 2_097_152];

/// One versioned, self-describing metrics snapshot.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// snapshot sequence number within the owning registry (1-based;
    /// 0 for stateless [`from_stats`](Self::from_stats) snapshots)
    pub seq: u64,
    /// the folded serving statistics (timestamped via
    /// [`Stats::captured_us`])
    pub stats: Stats,
    /// flight-recorder totals; `None` when the pool has no recorder
    pub recorder: Option<RecorderStats>,
    /// rates window vs the registry's previous snapshot; `None` on the
    /// first fold and for stateless snapshots
    pub rates: Option<StatsDelta>,
}

impl MetricsSnapshot {
    /// Stateless snapshot straight from a [`Stats`] (no sequence, no
    /// recorder section, no rates) — what `examples/soak.rs` emits.
    pub fn from_stats(stats: &Stats) -> Self {
        MetricsSnapshot { seq: 0, stats: stats.clone(), recorder: None, rates: None }
    }

    /// Prometheus-style text exposition. Metric names, label sets and the
    /// `le` sequence are schema-stable (see [`METRICS_SCHEMA`]).
    pub fn to_prometheus(&self) -> String {
        let s = &self.stats;
        let a = &s.activity;
        let mut out = String::with_capacity(4096);

        counter_u64(&mut out, "deltakws_metrics_seq", "gauge", self.seq);
        counter_u64(&mut out, "deltakws_metrics_captured_us", "gauge", s.captured_us);

        counter_u64(&mut out, "deltakws_completed_total", "counter", s.completed);
        counter_u64(&mut out, "deltakws_labelled_total", "counter", s.labelled);
        counter_u64(&mut out, "deltakws_correct_total", "counter", s.correct);
        gauge_f64(&mut out, "deltakws_accuracy", s.accuracy());

        type_line(&mut out, "deltakws_rejected_total", "counter");
        labeled_u64(&mut out, "deltakws_rejected_total", "cause", "queue_full", s.rejected_full);
        labeled_u64(&mut out, "deltakws_rejected_total", "cause", "closed", s.rejected_closed);

        counter_u64(&mut out, "deltakws_steals_total", "counter", s.steals);
        counter_u64(
            &mut out,
            "deltakws_park_transitions_total",
            "counter",
            s.park_transitions,
        );
        counter_u64(&mut out, "deltakws_shed_overloaded_total", "counter", s.shed_overloaded);
        counter_u64(&mut out, "deltakws_sessions_parked", "gauge", s.sessions_parked);
        counter_u64(&mut out, "deltakws_sessions_runnable", "gauge", s.sessions_runnable);
        counter_u64(&mut out, "deltakws_fused_batches_total", "counter", s.fused_batches);
        counter_u64(
            &mut out,
            "deltakws_stream_events_dropped_total",
            "counter",
            s.stream_events_dropped,
        );
        counter_u64(&mut out, "deltakws_session_bytes", "gauge", s.session_bytes);
        counter_u64(&mut out, "deltakws_weight_swaps_total", "counter", s.weight_swaps);
        counter_u64(
            &mut out,
            "deltakws_resident_weight_versions",
            "gauge",
            s.resident_versions,
        );

        counter_u64(&mut out, "deltakws_chip_frames_total", "counter", a.frames);
        counter_u64(&mut out, "deltakws_chip_gated_frames_total", "counter", a.gated_frames);
        counter_u64(&mut out, "deltakws_chip_mac_ops_total", "counter", a.mac_ops);
        counter_u64(
            &mut out,
            "deltakws_chip_sram_word_reads_total",
            "counter",
            a.sram_word_reads,
        );
        counter_u64(&mut out, "deltakws_chip_rnn_cycles_total", "counter", a.rnn_cycles);
        counter_u64(&mut out, "deltakws_chip_fired_lanes_total", "counter", a.fired_lanes);
        counter_u64(&mut out, "deltakws_chip_scanned_lanes_total", "counter", a.total_lanes);
        counter_u64(&mut out, "deltakws_chip_fex_visits_total", "counter", a.fex_visits);
        gauge_f64(&mut out, "deltakws_chip_sparsity", a.sparsity());
        gauge_f64(&mut out, "deltakws_chip_duty_cycle", a.duty_cycle());

        type_line(&mut out, "deltakws_worker_completed_total", "counter");
        for (w, lane) in s.per_worker.iter().enumerate() {
            labeled_worker(&mut out, "deltakws_worker_completed_total", w, lane.completed);
        }
        type_line(&mut out, "deltakws_worker_steals_total", "counter");
        for (w, lane) in s.per_worker.iter().enumerate() {
            labeled_worker(&mut out, "deltakws_worker_steals_total", w, lane.steals);
        }
        type_line(&mut out, "deltakws_worker_stream_chunks_total", "counter");
        for (w, lane) in s.per_worker.iter().enumerate() {
            labeled_worker(&mut out, "deltakws_worker_stream_chunks_total", w, lane.stream_chunks);
        }

        histogram(&mut out, "deltakws_latency_us", &s.latency);
        histogram(&mut out, "deltakws_chunk_latency_us", &s.chunk_latency);
        histogram(&mut out, "deltakws_sched_latency_us", &s.sched_latency);
        histogram(&mut out, "deltakws_enroll_latency_us", &s.enroll_latency);

        if let Some(r) = &self.recorder {
            counter_u64(&mut out, "deltakws_recorder_events_total", "counter", r.events);
            counter_u64(&mut out, "deltakws_flight_dumps_total", "counter", r.dumps_taken);
            counter_u64(
                &mut out,
                "deltakws_flight_dumps_dropped_total",
                "counter",
                r.dumps_dropped,
            );
            counter_u64(&mut out, "deltakws_flight_dumps_held", "gauge", r.dumps_held);
        }

        if let Some(d) = &self.rates {
            counter_u64(&mut out, "deltakws_rate_window_us", "gauge", d.elapsed_us);
            gauge_f64(&mut out, "deltakws_decisions_per_sec", d.decisions_per_sec());
            gauge_f64(&mut out, "deltakws_drops_per_sec", d.drops_per_sec());
            gauge_f64(&mut out, "deltakws_stream_chunks_per_sec", d.chunks_per_sec());
            gauge_f64(&mut out, "deltakws_chip_frames_per_sec", d.frames_per_sec());
            gauge_f64(&mut out, "deltakws_steals_per_sec", d.steals_per_sec());
        }
        out
    }

    /// JSON exposition (same schema family as the text form; key sets are
    /// pinned by the golden tests). `recorder` / `rates` serialize as
    /// `null` when absent so the document shape is constant.
    pub fn to_json(&self) -> Json {
        let s = &self.stats;
        let a = &s.activity;
        Json::obj(vec![
            ("schema", Json::str(METRICS_SCHEMA)),
            ("seq", jnum(self.seq)),
            ("captured_us", jnum(s.captured_us)),
            (
                "counters",
                Json::obj(vec![
                    ("completed", jnum(s.completed)),
                    ("correct", jnum(s.correct)),
                    ("labelled", jnum(s.labelled)),
                    ("rejected_full", jnum(s.rejected_full)),
                    ("rejected_closed", jnum(s.rejected_closed)),
                    ("shed_overloaded", jnum(s.shed_overloaded)),
                    ("steals", jnum(s.steals)),
                    ("park_transitions", jnum(s.park_transitions)),
                    ("fused_batches", jnum(s.fused_batches)),
                    ("stream_events_dropped", jnum(s.stream_events_dropped)),
                    ("weight_swaps", jnum(s.weight_swaps)),
                ]),
            ),
            (
                "gauges",
                Json::obj(vec![
                    ("accuracy", Json::num(s.accuracy())),
                    ("sessions_parked", jnum(s.sessions_parked)),
                    ("sessions_runnable", jnum(s.sessions_runnable)),
                    ("session_bytes", jnum(s.session_bytes)),
                    ("telemetry_bytes", jnum(s.telemetry_bytes() as u64)),
                    ("resident_weight_versions", jnum(s.resident_versions)),
                ]),
            ),
            (
                "activity",
                Json::obj(vec![
                    ("frames", jnum(a.frames)),
                    ("gated_frames", jnum(a.gated_frames)),
                    ("mac_ops", jnum(a.mac_ops)),
                    ("sram_word_reads", jnum(a.sram_word_reads)),
                    ("rnn_cycles", jnum(a.rnn_cycles)),
                    ("fired_lanes", jnum(a.fired_lanes)),
                    ("total_lanes", jnum(a.total_lanes)),
                    ("fired_x", jnum(a.fired_x)),
                    ("total_x", jnum(a.total_x)),
                    ("fired_h", jnum(a.fired_h)),
                    ("total_h", jnum(a.total_h)),
                    ("fex_visits", jnum(a.fex_visits)),
                    ("sparsity", Json::num(a.sparsity())),
                    ("duty_cycle", Json::num(a.duty_cycle())),
                ]),
            ),
            ("latency_us", hist_json(&s.latency)),
            ("chunk_latency_us", hist_json(&s.chunk_latency)),
            ("sched_latency_us", hist_json(&s.sched_latency)),
            ("enroll_latency_us", hist_json(&s.enroll_latency)),
            (
                "per_worker",
                Json::arr(s.per_worker.iter().enumerate().map(|(w, lane)| {
                    Json::obj(vec![
                        ("worker", jnum(w as u64)),
                        ("completed", jnum(lane.completed)),
                        ("steals", jnum(lane.steals)),
                        ("stream_chunks", jnum(lane.stream_chunks)),
                    ])
                })),
            ),
            (
                "recorder",
                match &self.recorder {
                    Some(r) => Json::obj(vec![
                        ("events", jnum(r.events)),
                        ("dumps_taken", jnum(r.dumps_taken)),
                        ("dumps_dropped", jnum(r.dumps_dropped)),
                        ("dumps_held", jnum(r.dumps_held)),
                    ]),
                    None => Json::Null,
                },
            ),
            (
                "rates",
                match &self.rates {
                    Some(d) => Json::obj(vec![
                        ("elapsed_us", jnum(d.elapsed_us)),
                        ("decisions_per_sec", Json::num(d.decisions_per_sec())),
                        ("drops_per_sec", Json::num(d.drops_per_sec())),
                        ("chunks_per_sec", Json::num(d.chunks_per_sec())),
                        ("frames_per_sec", Json::num(d.frames_per_sec())),
                        ("steals_per_sec", Json::num(d.steals_per_sec())),
                    ]),
                    None => Json::Null,
                },
            ),
        ])
    }
}

/// Stateful snapshot folder: owns the sequence counter and the previous
/// [`Stats`] so consecutive folds expose rates via
/// [`Stats::delta_since`].
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    seq: u64,
    prev: Option<Stats>,
}

impl MetricsRegistry {
    /// A registry with no history (first fold yields `rates: None`).
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one stats capture (plus optional recorder totals) into the
    /// next versioned snapshot.
    pub fn fold(&mut self, stats: Stats, recorder: Option<RecorderStats>) -> MetricsSnapshot {
        self.seq += 1;
        let rates = self.prev.as_ref().map(|prev| stats.delta_since(prev));
        let snap =
            MetricsSnapshot { seq: self.seq, stats: stats.clone(), recorder, rates };
        self.prev = Some(stats);
        snap
    }
}

#[inline]
fn jnum(v: u64) -> Json {
    Json::num(v as f64)
}

/// Stable float formatting shared by both expositions: integral values
/// print as integers (the [`Json`] writer's rule).
fn fmt_f64(v: f64) -> String {
    Json::Num(v).to_string()
}

fn type_line(out: &mut String, name: &str, kind: &str) {
    out.push_str("# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(kind);
    out.push('\n');
}

fn counter_u64(out: &mut String, name: &str, kind: &str, v: u64) {
    type_line(out, name, kind);
    out.push_str(name);
    out.push(' ');
    out.push_str(&v.to_string());
    out.push('\n');
}

fn gauge_f64(out: &mut String, name: &str, v: f64) {
    type_line(out, name, "gauge");
    out.push_str(name);
    out.push(' ');
    out.push_str(&fmt_f64(v));
    out.push('\n');
}

fn labeled_u64(out: &mut String, name: &str, label: &str, value: &str, v: u64) {
    out.push_str(&format!("{name}{{{label}=\"{value}\"}} {v}\n"));
}

fn labeled_worker(out: &mut String, name: &str, worker: usize, v: u64) {
    out.push_str(&format!("{name}{{worker=\"{worker}\"}} {v}\n"));
}

fn histogram(out: &mut String, name: &str, h: &LogHistogram) {
    type_line(out, name, "histogram");
    for le in LATENCY_LE_US {
        out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {}\n", h.count_below(le)));
    }
    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
    out.push_str(&format!("{name}_sum {}\n", h.sum()));
    out.push_str(&format!("{name}_count {}\n", h.count()));
}

fn hist_json(h: &LogHistogram) -> Json {
    let mut buckets: Vec<Json> = LATENCY_LE_US
        .iter()
        .map(|&le| Json::obj(vec![("le", jnum(le)), ("count", jnum(h.count_below(le)))]))
        .collect();
    // `le: null` is the +Inf bucket
    buckets.push(Json::obj(vec![("le", Json::Null), ("count", jnum(h.count()))]));
    Json::obj(vec![
        ("count", jnum(h.count())),
        ("sum", jnum(h.sum())),
        ("mean", Json::num(h.mean())),
        ("p50", jnum(h.percentile(0.50))),
        ("p90", jnum(h.percentile(0.90))),
        ("p99", jnum(h.percentile(0.99))),
        ("buckets", Json::arr(buckets)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_sequences_and_rates() {
        let mut reg = MetricsRegistry::new();
        let s1 = Stats { captured_us: 1_000_000, completed: 100, ..Stats::default() };
        let first = reg.fold(s1, None);
        assert_eq!(first.seq, 1);
        assert!(first.rates.is_none(), "no previous snapshot on the first fold");

        let s2 = Stats { captured_us: 3_000_000, completed: 500, ..Stats::default() };
        let second = reg.fold(s2, None);
        assert_eq!(second.seq, 2);
        let d = second.rates.expect("second fold has a rates window");
        assert_eq!(d.elapsed_us, 2_000_000);
        assert_eq!(d.completed, 400);
        assert!((d.decisions_per_sec() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn stateless_snapshot_has_no_seq_or_rates() {
        let snap = MetricsSnapshot::from_stats(&Stats::default());
        assert_eq!(snap.seq, 0);
        assert!(snap.recorder.is_none());
        assert!(snap.rates.is_none());
        let text = snap.to_prometheus();
        assert!(!text.contains("deltakws_decisions_per_sec"));
        assert!(!text.contains("deltakws_recorder_events_total"));
        assert_eq!(snap.to_json().get("rates"), Some(&Json::Null));
    }
}

//! Observability: metrics exposition, flight recorder, request tracing
//! (DESIGN.md §12).
//!
//! The paper's story is told in counters — temporal sparsity %, gated
//! frames, SRAM reads, nJ/decision — and the serving layer's story is told
//! in latencies, queue depths and admission decisions. Until this module
//! those numbers lived in internal structs
//! ([`WorkerShard`](crate::coordinator::telemetry::WorkerShard),
//! [`ChipActivity`](crate::energy::ChipActivity), the log-bucketed
//! histograms) with no exposition surface, no event timeline, and no way
//! to answer "why did *this* utterance produce a false accept at minute 43
//! of a soak?". Three layers fix that:
//!
//! * [`metrics`] — [`MetricsRegistry`] folds a [`Stats`](crate::coordinator::Stats)
//!   snapshot (plus optional recorder totals) into a versioned
//!   [`MetricsSnapshot`], serialized as Prometheus-style text and JSON.
//!   [`Coordinator::metrics`](crate::coordinator::Coordinator::metrics) is
//!   the pool-level entry point; `deltakws serve` dumps snapshots on
//!   SIGUSR1 / an interval, `examples/soak.rs` at exit.
//! * [`recorder`] — a bounded per-worker ring of structured [`Event`]s
//!   (submit, dequeue, frame-batch, gate edges, decision, backpressure,
//!   drop) with monotonic timestamps, recorded through [`RecorderProbe`]
//!   (composing the zero-cost [`ChipProbe`](crate::probe::ChipProbe)
//!   hooks) plus coordinator-level hooks. An [`AnomalyRule`] freezes the
//!   ring into a post-mortem [`FlightDump`] when it fires.
//! * [`TraceId`] — request-scoped tracing: minted at submit / stream-open,
//!   carried through the job queue and session state, stamped on every
//!   recorder event and on
//!   [`Response`](crate::coordinator::Response) /
//!   [`StreamEvent`](crate::coordinator::StreamEvent), so one utterance's
//!   life is reconstructable end to end across lanes.
//!
//! The lean path stays lean: a pool built without
//! [`CoordinatorBuilder::recorder`](crate::coordinator::CoordinatorBuilder::recorder)
//! runs the same monomorphized `NoProbe` datapath as before (bit-exact,
//! allocation-free), paying only one predictable `enabled` branch per
//! *job* — never per frame. `hotpath_bench` A/Bs the recorder tax.

pub mod metrics;
pub mod recorder;

pub use metrics::{MetricsRegistry, MetricsSnapshot, LATENCY_LE_US, METRICS_SCHEMA};
pub use recorder::{
    AnomalyRule, Event, EventKind, FlightDump, FlightRecorder, RecorderConfig, RecorderProbe,
    RecorderStats,
};

use std::sync::OnceLock;
use std::time::Instant;

/// Request-scoped trace id: minted once per submission / stream open by
/// the router, stamped on every recorder [`Event`] and on the request's
/// [`Response`](crate::coordinator::Response) (or the session's
/// [`StreamEvent`](crate::coordinator::StreamEvent)s), so the flight
/// recorder's timeline can be filtered down to one utterance's life.
///
/// `0` is reserved as the [`NONE`](Self::NONE) sentinel (events not tied
/// to any request); minted ids start at 1 and are unique per pool.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TraceId(pub u64);

impl TraceId {
    /// "No request": the id stamped on events outside any request scope.
    pub const NONE: TraceId = TraceId(0);

    /// True for the [`NONE`](Self::NONE) sentinel.
    pub fn is_none(&self) -> bool {
        self.0 == 0
    }
}

impl std::fmt::Display for TraceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "t{}", self.0)
    }
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Monotonic microseconds since this process first asked for the time
/// (lazily-initialized epoch). One shared timebase for every recorder
/// ring and every [`Stats::captured_us`](crate::coordinator::Stats::captured_us)
/// stamp, so timestamps are comparable across workers and across
/// snapshots — which is what makes
/// [`Stats::delta_since`](crate::coordinator::Stats::delta_since) rates
/// and cross-lane event correlation meaningful.
pub fn monotonic_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_id_none_sentinel() {
        assert!(TraceId::NONE.is_none());
        assert!(TraceId::default().is_none());
        assert!(!TraceId(1).is_none());
        assert_eq!(TraceId(42).to_string(), "t42");
    }

    #[test]
    fn monotonic_us_never_goes_backwards() {
        let a = monotonic_us();
        let b = monotonic_us();
        assert!(b >= a);
    }
}

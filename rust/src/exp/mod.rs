//! Experiment drivers: regenerate every table and figure of the paper.
//!
//! Each `fig*`/`table*` function runs the corresponding workload on the
//! digital twin, prints a paper-vs-measured table to stdout and writes
//! machine-readable CSV/JSON under `results/`. `run("all", ..)` regenerates
//! the full evaluation section. The experiment index lives in DESIGN.md §3;
//! measured numbers are recorded in EXPERIMENTS.md.
//!
//! All experiments use models trained via the delta-aware `train_step` of
//! the active execution backend (native by default, PJRT-artifact-backed
//! with `--features pjrt`) on the synthetic GSCD substrate and quantised to
//! the chip's int8/Q8.8 formats. Train/deploy channel selections always
//! match: the main model is trained at the design point's 10 channels, and
//! the Fig. 6 sweep trains one model per channel configuration (the paper's
//! methodology).

use std::path::{Path, PathBuf};

use crate::accel::gru::QuantParams;
use crate::baseline::{DenseGruAccel, SkipRnn};
use crate::chip::{ChipConfig, KwsChip};
use crate::config::RunConfig;
use crate::dataset::{Dataset, Split};
use crate::energy::SramKind;
use crate::fex::biquad::Arch;
use crate::fex::{area as fexarea, FexConfig};
use crate::runtime;
use crate::train::{self, Trainer};
use crate::util::prng::Pcg;

/// Results directory.
pub fn results_dir() -> PathBuf {
    let d = PathBuf::from("results");
    std::fs::create_dir_all(&d).ok();
    d
}

fn write_result(name: &str, contents: &str) {
    let path = results_dir().join(name);
    if let Err(e) = std::fs::write(&path, contents) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("  -> results/{name}");
    }
}

/// Train a model on a specific FEx configuration and persist it.
///
/// Train/deploy consistency matters: the network must see at training time
/// exactly the channel selection it will see on-chip (lanes outside the
/// selection read zero and receive no gradient), so every channel
/// configuration gets its own weights — the paper's Fig. 6 methodology.
pub fn train_weights(
    cfg: &RunConfig,
    fex: FexConfig,
    steps: usize,
    path: &Path,
) -> crate::Result<QuantParams> {
    let backend = runtime::backend_for(&cfg.artifacts)?;
    let ds = Dataset::with_fex(cfg.seed, fex);
    let mut trainer = Trainer::new(backend, ds, cfg.batch, cfg.train_delta_th)?;
    let mut state = trainer.init_state(cfg.seed);
    trainer.fit(&mut state, steps, true)?;
    let (acc, sp) = trainer.evaluate(&state, Split::Test, 128, cfg.train_delta_th)?;
    println!("float model: test acc {:.1}%  sparsity {:.1}%", acc * 100.0, sp * 100.0);
    let q = trainer.export(&state);
    train::save_weights(path, &q)?;
    println!("saved weights to {}", path.display());
    Ok(q)
}

/// Load the trained weight image for the run's chip config, or train one
/// via the execution backend if missing.
pub fn ensure_weights(cfg: &RunConfig) -> crate::Result<QuantParams> {
    let path = Path::new(&cfg.weights).to_path_buf();
    if path.exists() {
        return train::load_weights(&path);
    }
    println!(
        "no weights at {} — training ({} steps)...",
        cfg.weights, cfg.train_steps
    );
    train_weights(cfg, cfg.chip_config().fex.clone(), cfg.train_steps, &path)
}

/// Per-channel-count weights for the Fig. 6 sweep (cached on disk).
fn ensure_weights_for_channels(cfg: &RunConfig, n: usize) -> crate::Result<QuantParams> {
    if n == cfg.channels {
        return ensure_weights(cfg);
    }
    let path = results_dir().join(format!("weights_ch{n}.bin"));
    if path.exists() {
        return train::load_weights(&path);
    }
    println!("fig6: training {n}-channel model ({} steps)...", FIG6_TRAIN_STEPS);
    train_weights(cfg, FexConfig::n_channels(cfg.arch, n), FIG6_TRAIN_STEPS, &path)
}

/// Reduced step budget for the per-configuration Fig. 6 models.
const FIG6_TRAIN_STEPS: usize = 600;

/// Chip accuracy over `n` test utterances at a chip config.
/// Returns (acc12, acc11, merged report fields via the chip).
pub fn chip_accuracy(
    params: &QuantParams,
    chip_cfg: &ChipConfig,
    ds: &Dataset,
    n: usize,
) -> (f64, f64, crate::chip::ChipReport) {
    let mut chip = KwsChip::new(params.clone(), chip_cfg.clone());
    let mut correct12 = 0usize;
    let mut total12 = 0usize;
    let mut correct11 = 0usize;
    let mut total11 = 0usize;
    for i in 0..n {
        let utt = ds.utterance(Split::Test, i);
        let d = chip.process_utterance(&utt.audio12);
        total12 += 1;
        if d.class == utt.label {
            correct12 += 1;
        }
        // 11-class protocol [6]: drop the 'unknown' category entirely
        if utt.label != 1 {
            let pred11 = (0..crate::NUM_CLASSES)
                .filter(|&k| k != 1)
                .max_by_key(|&k| d.logits[k])
                .unwrap();
            total11 += 1;
            if pred11 == utt.label {
                correct11 += 1;
            }
        }
    }
    (
        correct12 as f64 / total12 as f64,
        correct11 as f64 / total11.max(1) as f64,
        chip.report(),
    )
}

/// Dispatch by experiment id.
pub fn run(id: &str, cfg: &RunConfig) -> crate::Result<()> {
    match id {
        "fig6" => fig6(cfg),
        "fig7" => fig7(cfg),
        "fig10" => fig10(cfg),
        "fig11" => fig11(cfg),
        "fig12" => fig12(cfg),
        "fig13" => fig13(cfg),
        "table1" => table1(cfg),
        "table2" => table2(cfg),
        "ablation" => ablation(cfg),
        "all" => {
            for e in
                ["fig6", "fig7", "fig10", "fig11", "fig12", "fig13", "table1", "table2", "ablation"]
            {
                println!("\n################ {e} ################");
                run(e, cfg)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment '{other}' (fig6/fig7/fig10/fig11/fig12/fig13/table1/table2/ablation/all)"),
    }
}

// ---------------------------------------------------------------------------
// Fig. 6 — FEx power vs accuracy over channel count
// ---------------------------------------------------------------------------

pub fn fig6(cfg: &RunConfig) -> crate::Result<()> {
    println!("Fig. 6: 12-class accuracy + FEx power vs number of IIR channels");
    println!("paper: accuracy maintained down to 10 channels; 10ch saves 30% FEx power vs 16\n");
    let mut csv = String::from("channels,fex_power_uw,accuracy\n");
    println!("{:>9} {:>14} {:>10}", "channels", "FEx power µW", "accuracy");
    for n in [2usize, 4, 6, 8, 10, 12, 14, 16] {
        // per-configuration model: train/deploy channel selections match
        let params = ensure_weights_for_channels(cfg, n)?;
        let chip_cfg = ChipConfig::builder().channels(n).build()?;
        let ds = Dataset::with_fex(cfg.seed, chip_cfg.fex.clone());
        let (acc, _a11, _rep) = chip_accuracy(&params, &chip_cfg, &ds, cfg.eval_utterances);
        let p = fexarea::power_uw(cfg.arch, n);
        println!("{n:>9} {p:>14.3} {:>9.1}%", acc * 100.0);
        csv.push_str(&format!("{n},{p:.4},{acc:.4}\n"));
    }
    let p10 = fexarea::power_uw(cfg.arch, 10);
    let p16 = fexarea::power_uw(cfg.arch, 16);
    println!("\n10ch vs 16ch FEx power saving: {:.0}% (paper: 30%)", (1.0 - p10 / p16) * 100.0);
    write_result("fig6.csv", &csv);
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 7 — FEx optimisation steps (area/power)
// ---------------------------------------------------------------------------

pub fn fig7(_cfg: &RunConfig) -> crate::Result<()> {
    println!("Fig. 7: FEx datapath optimisation steps (vs 16-fraction-bit baseline)");
    println!("paper: mixed precision 2.4x power / 2.6x area; +shift-sub 1.8x/1.8x; total 5.7x/4.7x\n");
    let steps = fexarea::fig7_steps();
    let labels = ["baseline (16b fraction coeffs)", "+ 12b/8b mixed precision", "+ shift-substituted multipliers"];
    let mut csv = String::from("step,arch,area_reduction,power_reduction,gates,area_mm2\n");
    println!("{:<34} {:>10} {:>11} {:>9} {:>9}", "step", "area red.", "power red.", "kGE", "mm²");
    for (i, (arch, ar, pr)) in steps.iter().enumerate() {
        let gates = fexarea::area(*arch).total_gates();
        let mm2 = fexarea::area(*arch).area_mm2();
        println!(
            "{:<34} {:>9.2}x {:>10.2}x {:>9.1} {:>9.4}",
            labels[i], ar, pr, gates / 1000.0, mm2
        );
        csv.push_str(&format!("{i},{arch:?},{ar:.3},{pr:.3},{gates:.0},{mm2:.4}\n"));
    }
    let (_, area_total, pow_total) = steps[2];
    println!(
        "\ntotal: {area_total:.1}x area, {pow_total:.1}x power (paper: 4.7x / 5.7x; \
         gap = first-order gate model vs synthesis, see EXPERIMENTS.md)"
    );
    write_result("fig7.csv", &csv);
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 10 — power & area breakdown
// ---------------------------------------------------------------------------

pub fn fig10(cfg: &RunConfig) -> crate::Result<()> {
    println!("Fig. 10: measured power & area breakdown at the design point");
    println!("paper: power FEx 25% / ΔRNN 57% / SRAM 18% of 5.22 µW; area 11/41/48% of 0.78 mm²\n");
    let params = ensure_weights(cfg)?;
    let chip_cfg = cfg.chip_config();
    let ds = Dataset::with_fex(cfg.seed, chip_cfg.fex.clone());
    let mut chip = KwsChip::new(params, chip_cfg);
    for i in 0..cfg.eval_utterances.min(64) {
        let utt = ds.utterance(Split::Test, i);
        chip.process_utterance(&utt.audio12);
    }
    let p = chip.power();
    let a = crate::energy::AreaBreakdown::chip();
    let t = p.total_uw();
    println!("power: FEx {:.2} µW ({:.0}%)  ΔRNN {:.2} µW ({:.0}%)  SRAM {:.2} µW ({:.0}%)  misc {:.2} µW  | total {:.2} µW (paper 5.22)",
        p.fex_uw, 100.0 * p.fex_uw / t, p.rnn_uw, 100.0 * p.rnn_uw / t,
        p.sram_uw, 100.0 * p.sram_uw / t, p.misc_uw, t);
    let at = a.total_mm2();
    println!("area : FEx {:.3} mm² ({:.0}%)  ΔRNN {:.3} mm² ({:.0}%)  SRAM {:.3} mm² ({:.0}%)  | total {:.3} mm² (paper 0.78)",
        a.fex_mm2, 100.0 * a.fex_mm2 / at, a.rnn_mm2, 100.0 * a.rnn_mm2 / at,
        a.sram_mm2, 100.0 * a.sram_mm2 / at, at);
    write_result(
        "fig10.json",
        &format!(
            "{{\"power_uw\":{{\"fex\":{:.4},\"rnn\":{:.4},\"sram\":{:.4},\"misc\":{:.4}}},\"area_mm2\":{{\"fex\":{:.4},\"rnn\":{:.4},\"sram\":{:.4}}}}}\n",
            p.fex_uw, p.rnn_uw, p.sram_uw, p.misc_uw, a.fex_mm2, a.rnn_mm2, a.sram_mm2
        ),
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 11 — "yes" utterance trace: features + per-frame latency
// ---------------------------------------------------------------------------

pub fn fig11(cfg: &RunConfig) -> crate::Result<()> {
    println!("Fig. 11: 'yes' utterance — IIR features and ΔRNN latency for two Δ_TH");
    println!("paper: silent frames show ~40% latency reduction vs active frames\n");
    let params = ensure_weights(cfg)?;
    // one deterministic "yes"
    let mut rng = Pcg::new(cfg.seed ^ 0x796573);
    let audio = crate::audio::synth_utterance(11, &mut rng);
    let audio12 = crate::audio::quantize_12b(&audio);

    let mut csv = String::from("frame,th0_cycles,th0_ms,th02_cycles,th02_ms,feat_sum\n");
    // the Fig. 11 traces come from the opt-in TraceProbe path — the lean
    // Decision no longer carries per-frame diagnostics
    let run_th = |th: i16| {
        let mut chip = KwsChip::new(params.clone(), cfg.chip_config().with_delta_th(th));
        chip.process_utterance_traced(&audio12)
    };
    let (d0, t0) = run_th(0);
    let (_d2, t2) = run_th(51);
    let ms = |c: u64| c as f64 / crate::energy::calib::CLOCK_HZ * 1e3;
    for t in 0..t0.frame_cycles.len() {
        let feat_sum: i64 = t2.feat_trace[t].iter().sum();
        csv.push_str(&format!(
            "{t},{},{:.3},{},{:.3},{feat_sum}\n",
            t0.frame_cycles[t],
            ms(t0.frame_cycles[t]),
            t2.frame_cycles[t],
            ms(t2.frame_cycles[t]),
        ));
    }
    // silent vs active frames at the design point
    let mut sums: Vec<(i64, u64)> = t2
        .feat_trace
        .iter()
        .zip(&t2.frame_cycles)
        .map(|(f, &c)| (f.iter().sum::<i64>(), c))
        .collect();
    sums.sort_by_key(|&(s, _)| s);
    let q = sums.len() / 4;
    let silent: f64 = sums[..q].iter().map(|&(_, c)| c as f64).sum::<f64>() / q as f64;
    let active: f64 = sums[sums.len() - q..].iter().map(|&(_, c)| c as f64).sum::<f64>() / q as f64;
    println!(
        "Δ_TH=0.2: silent-quartile latency {:.2} ms vs active-quartile {:.2} ms  ({:.0}% reduction; paper ~40%)",
        ms(silent as u64),
        ms(active as u64),
        (1.0 - silent / active) * 100.0
    );
    println!(
        "Δ_TH=0 mean latency {:.2} ms; Δ_TH=0.2 mean latency {:.2} ms",
        ms(d0.total_cycles / d0.frames.max(1)),
        ms(t2.frame_cycles.iter().sum::<u64>() / t2.frame_cycles.len().max(1) as u64)
    );
    write_result("fig11.csv", &csv);
    // audio waveform for the top panel
    let mut wav = String::from("sample,amplitude\n");
    for (i, v) in audio.iter().enumerate().step_by(4) {
        wav.push_str(&format!("{i},{v:.5}\n"));
    }
    write_result("fig11_audio.csv", &wav);
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 12 — the headline sweep: accuracy/energy/sparsity/latency vs Δ_TH
// ---------------------------------------------------------------------------

pub fn fig12(cfg: &RunConfig) -> crate::Result<()> {
    println!("Fig. 12: accuracy, energy/decision, temporal sparsity, latency vs Δ_TH");
    println!("paper @Δ=0:   121.2 nJ, 16.4 ms | @Δ=0.2: 89.5% (12-cls), 87% sparsity, 36.11 nJ, 6.9 ms\n");
    let params = ensure_weights(cfg)?;
    let ds = Dataset::with_fex(cfg.seed, cfg.chip_config().fex.clone());
    let mut csv = String::from(
        "delta_th_q8,delta_th,acc12,acc11,energy_nj,latency_ms,sparsity,input_sparsity,hidden_sparsity,power_uw\n",
    );
    println!(
        "{:>6} {:>7} {:>7} {:>10} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "Δ_TH", "acc12%", "acc11%", "E/dec nJ", "lat ms", "spars%", "x-spars%", "h-spars%", "P µW"
    );
    for th in [0i16, 6, 13, 26, 38, 51, 64, 77, 102, 128] {
        let chip_cfg = cfg.chip_config().with_delta_th(th);
        let (acc12, acc11, rep) = chip_accuracy(&params, &chip_cfg, &ds, cfg.eval_utterances);
        let thf = th as f64 / 256.0;
        println!(
            "{thf:>6.3} {:>7.1} {:>7.1} {:>10.2} {:>9.2} {:>9.1} {:>9.1} {:>9.1} {:>9.2}",
            acc12 * 100.0,
            acc11 * 100.0,
            rep.energy_per_decision_nj,
            rep.latency_ms,
            rep.sparsity * 100.0,
            rep.input_sparsity * 100.0,
            rep.hidden_sparsity * 100.0,
            rep.power.total_uw()
        );
        csv.push_str(&format!(
            "{th},{thf:.4},{acc12:.4},{acc11:.4},{:.3},{:.4},{:.4},{:.4},{:.4},{:.4}\n",
            rep.energy_per_decision_nj,
            rep.latency_ms,
            rep.sparsity,
            rep.input_sparsity,
            rep.hidden_sparsity,
            rep.power.total_uw()
        ));
    }
    write_result("fig12.csv", &csv);
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 13 — SRAM skew-resistant column MUX waveform
// ---------------------------------------------------------------------------

pub fn fig13(_cfg: &RunConfig) -> crate::Result<()> {
    println!("Fig. 13: PCHCMX — Q refreshes at the falling clock edge under skew\n");
    use crate::sram::timing::{q_offsets_from_falling_edge, simulate, waveform_csv, TimingParams};
    let mut all = String::new();
    println!("{:>9} {:>22}", "skew ns", "Q offset from fall ns");
    for skew in [-400.0, -200.0, 0.0, 200.0, 400.0] {
        let p = TimingParams { skew_ns: skew, ..Default::default() };
        let offs = q_offsets_from_falling_edge(&p, 4);
        let max_off = offs.iter().fold(0.0f64, |m, &o| m.max(o.abs()));
        println!("{skew:>9.0} {max_off:>22.2}");
        if skew == 0.0 {
            all = waveform_csv(&simulate(&p, 3));
        }
    }
    println!("\nQ refresh is skew-independent (paper Fig. 13's claim) ✓");
    write_result("fig13_waveform.csv", &all);
    Ok(())
}

// ---------------------------------------------------------------------------
// Table I — digital FEx comparison
// ---------------------------------------------------------------------------

pub fn table1(_cfg: &RunConfig) -> crate::Result<()> {
    println!("Table I: digital FEx implementations\n");
    let ours_area = fexarea::area(Arch::MixedShift).area_mm2();
    let ours_power = fexarea::power_uw(Arch::MixedShift, 10);
    // FEx storage: biquad state RF (16ch x 9 x 16b = 288 B) + coeff RF — the
    // paper reports 200 B of data storage
    let storage = 16 * (2 * 4 + 1) * 2;
    let rows = [
        // name, process, area, clock, in-bits, feat-bits, type, dim, storage, power µW, serial
        ("Shan ISSCC'20 [2]", 28, 0.057, 40_000, 16, 8, "MFCC/FFT", 8, 256, 0.34, true),
        ("Giraldo JSSC'20 [4]", 65, 0.66, 250_000, 10, 8, "MFCC/FFT", 32, 0, 7.2, false),
        ("Shan JSSC'23 [16]", 28, 0.093, 8_000, 16, 8, "MFCC/FFT", 11, 512, 0.17, true),
    ];
    println!(
        "{:<22} {:>4} {:>8} {:>8} {:>6} {:>6} {:>9} {:>4} {:>8} {:>9} {:>7}",
        "FEx", "nm", "mm²", "clk Hz", "in b", "ft b", "type", "dim", "store B", "power µW", "serial"
    );
    for r in rows {
        println!(
            "{:<22} {:>4} {:>8.3} {:>8} {:>6} {:>6} {:>9} {:>4} {:>8} {:>9.2} {:>7}",
            r.0, r.1, r.2, r.3, r.4, r.5, r.6, r.7, r.8, r.9, r.10
        );
    }
    println!(
        "{:<22} {:>4} {:>8.3} {:>8} {:>6} {:>6} {:>9} {:>4} {:>8} {:>9.2} {:>7}",
        "This work (model)", 65, ours_area, 128_000, 12, 12, "IIR-BPF", 16, storage, ours_power, true
    );
    println!("\npaper 'This Work' column: 0.084 mm², 128 kHz, 12b/12b, ≤16 ch, 200 B, 1.22 µW, serial");
    write_result(
        "table1.csv",
        &format!("area_mm2,power_uw,storage_b\n{ours_area:.4},{ours_power:.3},{storage}\n"),
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Table II — KWS chip comparison (+ our baselines)
// ---------------------------------------------------------------------------

pub fn table2(cfg: &RunConfig) -> crate::Result<()> {
    println!("Table II: KWS implementations — this work at Δ_TH = 0 and 0.2\n");
    let params = ensure_weights(cfg)?;
    let ds = Dataset::with_fex(cfg.seed, cfg.chip_config().fex.clone());
    let n = cfg.eval_utterances;

    let mut rows = Vec::new();
    for (label, th) in [("Δ_TH = 0", 0i16), ("Δ_TH = 0.2", 51)] {
        let chip_cfg = cfg.chip_config().with_delta_th(th);
        let (acc12, acc11, rep) = chip_accuracy(&params, &chip_cfg, &ds, n);
        rows.push((label.to_string(), acc12, acc11, rep));
    }

    // dense baseline (no Δ machinery at all) for the ablation row
    let mut dense = DenseGruAccel::new(
        params.clone(),
        crate::accel::AccelConfig::design_point().active_x,
        SramKind::NearVth,
    );
    let mut dense_correct = 0usize;
    let mut fexer = crate::fex::Fex::new(cfg.chip_config().fex.clone());
    for i in 0..n {
        let utt = ds.utterance(Split::Test, i);
        let feats = ds.features_for(&mut fexer, &utt);
        let pred = dense.classify(&feats.feats, 4);
        if pred == utt.label {
            dense_correct += 1;
        }
    }
    let dense_act = dense.activity;
    let dense_power = crate::energy::chip_power(
        &dense_act,
        fexarea::power_uw(cfg.arch, cfg.channels),
        SramKind::NearVth,
    );
    let dense_energy = crate::energy::energy_per_decision_nj(&dense_power, &dense_act);

    println!(
        "{:<14} {:>7} {:>7} {:>10} {:>9} {:>9} {:>9}",
        "operating pt", "acc12%", "acc11%", "E/dec nJ", "lat ms", "P µW", "spars%"
    );
    let mut csv =
        String::from("point,acc12,acc11,energy_nj,latency_ms,power_uw,sparsity\n");
    for (label, acc12, acc11, rep) in &rows {
        println!(
            "{label:<14} {:>7.1} {:>7.1} {:>10.2} {:>9.2} {:>9.2} {:>9.1}",
            acc12 * 100.0,
            acc11 * 100.0,
            rep.energy_per_decision_nj,
            rep.latency_ms,
            rep.power.total_uw(),
            rep.sparsity * 100.0
        );
        csv.push_str(&format!(
            "{label},{acc12:.4},{acc11:.4},{:.3},{:.3},{:.3},{:.4}\n",
            rep.energy_per_decision_nj,
            rep.latency_ms,
            rep.power.total_uw(),
            rep.sparsity
        ));
    }
    println!(
        "{:<14} {:>7.1} {:>7} {:>10.2} {:>9.2} {:>9.2} {:>9}",
        "dense GRU",
        100.0 * dense_correct as f64 / n as f64,
        "-",
        dense_energy,
        dense_act.avg_latency_ms(),
        dense_power.total_uw(),
        "0.0"
    );
    let e0 = rows[0].3.energy_per_decision_nj;
    let e2 = rows[1].3.energy_per_decision_nj;
    let l0 = rows[0].3.latency_ms;
    let l2 = rows[1].3.latency_ms;
    println!(
        "\nΔ_TH 0 -> 0.2: energy {:.1}x lower (paper 3.4x), latency {:.1}x lower (paper 2.4x)",
        e0 / e2,
        l0 / l2
    );
    println!(
        "paper Table II 'This Work': 121.2/36.11 nJ, 16.4/6.9 ms, 7.36/5.22 µW, 91.1→90.5% (11-cls), 90.1→89.5% (12-cls)"
    );
    println!("on-chip memory: 24 kB SRAM + 0.58 kB state + FEx RF ≈ 26.3 kB (paper 26.3 kB)");
    write_result("table2.csv", &csv);
    Ok(())
}

// ---------------------------------------------------------------------------
// Ablations — design choices DESIGN.md calls out
// ---------------------------------------------------------------------------

pub fn ablation(cfg: &RunConfig) -> crate::Result<()> {
    println!("Ablations: Δ-side, MAC lanes, skip-RNN comparison\n");
    let params = ensure_weights(cfg)?;
    let ds = Dataset::with_fex(cfg.seed, cfg.chip_config().fex.clone());
    let n = cfg.eval_utterances.min(128);
    let mut csv = String::from("variant,acc12,energy_nj,latency_ms,sparsity\n");

    // --- Δ on x only / h only / both --------------------------------------
    println!("(a) which side is delta-gated (Δ_TH = 0.2 where applied):");
    for (label, thx, thh) in [
        ("Δ both (chip)", Some(51), Some(51)),
        ("Δ on x only", Some(51), Some(0)),
        ("Δ on h only", Some(0), Some(51)),
        ("no Δ (Θ=0)", Some(0), Some(0)),
    ] {
        let mut chip_cfg = cfg.chip_config();
        chip_cfg.accel.delta_th_x_q8 = thx;
        chip_cfg.accel.delta_th_h_q8 = thh;
        let (acc12, _a11, rep) = chip_accuracy(&params, &chip_cfg, &ds, n);
        println!(
            "  {label:<16} acc {:.1}%  E {:.1} nJ  lat {:.2} ms  sparsity {:.1}%",
            acc12 * 100.0,
            rep.energy_per_decision_nj,
            rep.latency_ms,
            rep.sparsity * 100.0
        );
        csv.push_str(&format!(
            "{label},{acc12:.4},{:.3},{:.3},{:.4}\n",
            rep.energy_per_decision_nj, rep.latency_ms, rep.sparsity
        ));
    }

    // --- MAC lane count -----------------------------------------------------
    println!("\n(b) MAC lanes (latency scaling at fixed sparsity):");
    for lanes in [1usize, 2, 4, 8, 16] {
        let mut chip_cfg = cfg.chip_config();
        chip_cfg.accel.mac_lanes = lanes;
        let (_acc, _a11, rep) = chip_accuracy(&params, &chip_cfg, &ds, 32);
        println!("  {lanes:>2} lanes: latency {:.2} ms", rep.latency_ms);
        csv.push_str(&format!("mac_lanes_{lanes},,,{:.3},\n", rep.latency_ms));
    }

    // --- skip-RNN (coarse) vs ΔRNN (fine) at matched compute ----------------
    println!("\n(c) coarse frame skipping ([8]-style) vs fine-grained Δ:");
    let mut fexer = crate::fex::Fex::new(cfg.chip_config().fex.clone());
    for skip_th in [0i64, 100, 200, 400] {
        let mut skip = SkipRnn::new(
            params.clone(),
            crate::accel::AccelConfig::design_point().active_x,
            skip_th,
        );
        let mut correct = 0usize;
        for i in 0..n {
            let utt = ds.utterance(Split::Test, i);
            let feats = ds.features_for(&mut fexer, &utt);
            if skip.classify(&feats.feats, 4) == utt.label {
                correct += 1;
            }
        }
        let act = skip.inner.activity;
        let power = crate::energy::chip_power(
            &act,
            fexarea::power_uw(cfg.arch, cfg.channels),
            SramKind::NearVth,
        );
        let energy = crate::energy::energy_per_decision_nj(&power, &act);
        println!(
            "  skip_th {skip_th:>4}: acc {:.1}%  skip-rate {:.0}%  E {:.1} nJ",
            100.0 * correct as f64 / n as f64,
            skip.skip_rate() * 100.0,
            energy
        );
        csv.push_str(&format!(
            "skip_rnn_{skip_th},{:.4},{energy:.3},,{:.4}\n",
            correct as f64 / n as f64,
            skip.skip_rate()
        ));
    }
    write_result("ablation.csv", &csv);
    Ok(())
}

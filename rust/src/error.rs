//! Typed error surface of the serving API (v2).
//!
//! Before this module, failure modes were encoded as ad-hoc
//! `Result<_, payload>` bounces: `Client::submit` returned the rejected
//! `Request` whether the pool was merely saturated (retry) or gone for
//! good (stop), and callers had to poll `Client::is_closed` to tell the
//! two apart. The types here name the cause *and* still hand the payload
//! back, so a producer can pattern-match once:
//!
//! * [`SubmitError`] — why a request submission failed, request inside;
//! * [`StreamPushError`] — why a streaming chunk push failed, chunk inside;
//! * [`WaitError`] — why waiting on a completion ticket ended without a
//!   response (timeouts hand the [`Ticket`](crate::coordinator::Ticket)
//!   back so the wait can resume);
//! * [`ChipError`] — why the chip twin itself refused input (CDC FIFO
//!   backpressure: the caller stopped polling frames). Nothing is
//!   consumed on rejection, so the same samples can be re-pushed after
//!   draining; the stream layer surfaces this as
//!   [`StreamPushError::Backpressure`].
//! * [`Error`] — the crate-wide sum of the above plus builder validation
//!   failures ([`Error::InvalidConfig`]).
//!
//! Everything implements [`std::error::Error`], so all variants propagate
//! through the crate's anyhow-based [`crate::Result`] with `?`.

#![deny(missing_docs)]

use std::fmt;

use crate::coordinator::{Request, Ticket};
use crate::custom::RegistryError;

/// Crate-wide error type: every typed failure the serving and
/// construction APIs can report.
#[derive(Debug)]
pub enum Error {
    /// A builder rejected a configuration value (the message names the
    /// violated constraint; nothing was constructed).
    InvalidConfig {
        /// builder field that failed validation
        field: &'static str,
        /// human-readable constraint violation
        message: String,
    },
    /// A request submission was rejected (see [`SubmitError`]).
    Submit(SubmitError),
    /// A streaming-session push was rejected (see [`StreamPushError`]).
    StreamPush(StreamPushError),
    /// Waiting on a completion ticket ended without a response.
    Wait(WaitError),
    /// The chip twin refused input (see [`ChipError`]).
    Chip(ChipError),
    /// A weight-version lookup failed (see
    /// [`RegistryError`](crate::custom::RegistryError); the offending
    /// version rides along).
    Registry(RegistryError),
}

impl Error {
    /// Construct an [`Error::InvalidConfig`] (builder validation helper).
    pub fn invalid_config(field: &'static str, message: impl Into<String>) -> Self {
        Error::InvalidConfig { field, message: message.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidConfig { field, message } => {
                write!(f, "invalid configuration: {field}: {message}")
            }
            Error::Submit(e) => write!(f, "{e}"),
            Error::StreamPush(e) => write!(f, "{e}"),
            Error::Wait(e) => write!(f, "{e}"),
            Error::Chip(e) => write!(f, "{e}"),
            Error::Registry(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::InvalidConfig { .. } => None,
            Error::Submit(e) => Some(e),
            Error::StreamPush(e) => Some(e),
            Error::Wait(e) => Some(e),
            Error::Chip(e) => Some(e),
            Error::Registry(e) => Some(e),
        }
    }
}

impl From<RegistryError> for Error {
    fn from(e: RegistryError) -> Self {
        Error::Registry(e)
    }
}

impl From<ChipError> for Error {
    fn from(e: ChipError) -> Self {
        Error::Chip(e)
    }
}

impl From<SubmitError> for Error {
    fn from(e: SubmitError) -> Self {
        Error::Submit(e)
    }
}

impl From<StreamPushError> for Error {
    fn from(e: StreamPushError) -> Self {
        Error::StreamPush(e)
    }
}

impl From<WaitError> for Error {
    fn from(e: WaitError) -> Self {
        Error::Wait(e)
    }
}

/// Why a [`Request`] submission failed. The rejected request rides along
/// in every variant — nothing is lost, the caller decides whether to
/// retry, shed, or re-route.
#[derive(Debug)]
pub enum SubmitError {
    /// Every reachable worker queue was full: transient global
    /// backpressure. Retry (with backoff) or shed load; the pool is
    /// still alive.
    QueueFull(Request),
    /// The coordinator has shut down (or every worker lane is
    /// disconnected): permanent. Stop retrying.
    Closed(Request),
    /// The request named a [`WeightVersion`](crate::custom::WeightVersion)
    /// the registry cannot serve (never registered, or evicted under LRU
    /// pressure — the [`RegistryError`] says which). Permanent for this
    /// version: re-enroll or retarget, don't retry.
    UnknownWeights(Request, RegistryError),
    /// Admission control shed an `open_stream` call: the pool already
    /// serves `live` sessions, at (or beyond) its configured `high_water`
    /// mark
    /// ([`CoordinatorBuilder::max_sessions`](crate::coordinator::CoordinatorBuilder::max_sessions)).
    /// Typed load-shedding: already-admitted sessions keep their latency
    /// budget instead of everyone degrading. Close a session (or raise
    /// the mark) and retry. No request payload — the rejected operation
    /// was a session open, not a submission.
    Overloaded {
        /// sessions live when the open was shed
        live: u64,
        /// the pool's configured high-water mark
        high_water: u64,
    },
}

impl SubmitError {
    /// Recover the rejected request (e.g. to resubmit it). `None` for
    /// [`SubmitError::Overloaded`], which carries no request.
    pub fn into_request(self) -> Option<Request> {
        match self {
            SubmitError::QueueFull(r)
            | SubmitError::Closed(r)
            | SubmitError::UnknownWeights(r, _) => Some(r),
            SubmitError::Overloaded { .. } => None,
        }
    }

    /// Borrow the rejected request (`None` for
    /// [`SubmitError::Overloaded`]).
    pub fn request(&self) -> Option<&Request> {
        match self {
            SubmitError::QueueFull(r)
            | SubmitError::Closed(r)
            | SubmitError::UnknownWeights(r, _) => Some(r),
            SubmitError::Overloaded { .. } => None,
        }
    }

    /// True for transient backpressure (retryable).
    pub fn is_queue_full(&self) -> bool {
        matches!(self, SubmitError::QueueFull(_))
    }

    /// True once the pool is gone (not retryable).
    pub fn is_closed(&self) -> bool {
        matches!(self, SubmitError::Closed(_))
    }

    /// True when the request named an unresolvable weight version
    /// (not retryable as-is; the cause is in the [`RegistryError`]).
    pub fn is_unknown_weights(&self) -> bool {
        matches!(self, SubmitError::UnknownWeights(_, _))
    }

    /// True when admission control shed a session open at the live-session
    /// high-water mark (retryable once a session closes).
    pub fn is_overloaded(&self) -> bool {
        matches!(self, SubmitError::Overloaded { .. })
    }
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::QueueFull(r) => {
                write!(f, "submit rejected: every worker queue full (request {}, stream {})", r.id, r.stream)
            }
            SubmitError::Closed(r) => {
                write!(f, "submit rejected: coordinator closed (request {}, stream {})", r.id, r.stream)
            }
            SubmitError::UnknownWeights(r, e) => {
                write!(f, "submit rejected: {e} (request {}, stream {})", r.id, r.stream)
            }
            SubmitError::Overloaded { live, high_water } => {
                write!(
                    f,
                    "open_stream shed: {live} live sessions at high-water mark {high_water}"
                )
            }
        }
    }
}

impl std::error::Error for SubmitError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SubmitError::UnknownWeights(_, e) => Some(e),
            _ => None,
        }
    }
}

/// Why a [`StreamSession`](crate::coordinator::StreamSession) chunk push
/// failed. The chunk rides along in every variant.
#[derive(Debug)]
pub enum StreamPushError {
    /// The session's chunk window is full (`queue_depth` chunks already
    /// queued on its inbox). Pace the producer and retry.
    Backpressure(Vec<i64>),
    /// The worker pool is gone (coordinator dropped) or the session is
    /// closed. The session is dead; stop pushing.
    Closed(Vec<i64>),
}

impl StreamPushError {
    /// Recover the rejected audio chunk.
    pub fn into_chunk(self) -> Vec<i64> {
        match self {
            StreamPushError::Backpressure(c) | StreamPushError::Closed(c) => c,
        }
    }

    /// Borrow the rejected audio chunk.
    pub fn chunk(&self) -> &[i64] {
        match self {
            StreamPushError::Backpressure(c) | StreamPushError::Closed(c) => c,
        }
    }

    /// True for transient session-window backpressure (retryable).
    pub fn is_backpressure(&self) -> bool {
        matches!(self, StreamPushError::Backpressure(_))
    }

    /// True once the pool (or the session) is gone.
    pub fn is_closed(&self) -> bool {
        matches!(self, StreamPushError::Closed(_))
    }
}

impl fmt::Display for StreamPushError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StreamPushError::Backpressure(c) => {
                write!(f, "stream push rejected: session chunk window full ({} samples)", c.len())
            }
            StreamPushError::Closed(c) => {
                write!(f, "stream push rejected: worker pool closed ({} samples)", c.len())
            }
        }
    }
}

impl std::error::Error for StreamPushError {}

/// Why a [`Ticket`] wait ended without a response.
#[derive(Debug)]
pub enum WaitError {
    /// The deadline expired first. The ticket is handed back so the
    /// caller can keep waiting — the request is still in flight and the
    /// response will be held for this ticket when it completes.
    Timeout(Ticket),
    /// The coordinator shut down before the response was produced (or
    /// the response was already taken). Permanent for this ticket.
    Closed,
}

impl WaitError {
    /// Recover the ticket after a timeout (`None` for [`WaitError::Closed`]).
    pub fn into_ticket(self) -> Option<Ticket> {
        match self {
            WaitError::Timeout(t) => Some(t),
            WaitError::Closed => None,
        }
    }

    /// True when the wait merely timed out (the request is still in flight).
    pub fn is_timeout(&self) -> bool {
        matches!(self, WaitError::Timeout(_))
    }

    /// True once the pool shut down without producing the response.
    pub fn is_closed(&self) -> bool {
        matches!(self, WaitError::Closed)
    }
}

impl fmt::Display for WaitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WaitError::Timeout(t) => {
                write!(f, "timed out waiting for request {} (stream {})", t.id(), t.stream())
            }
            WaitError::Closed => write!(f, "coordinator closed before the response was produced"),
        }
    }
}

impl std::error::Error for WaitError {}

/// Why the chip twin refused input. Replaces the old
/// `expect("CDC FIFO overflow: accelerator starved")` panic in
/// [`KwsChip::push_samples`](crate::chip::KwsChip::push_samples) — a
/// hostile stream chunk used to be able to kill a coordinator worker
/// thread; now the condition is typed, nothing is consumed, and the
/// caller drains frames (or sheds the chunk) and retries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChipError {
    /// Pushing these samples would complete more feature frames than the
    /// chip's frame buffer can hold (the CDC-FIFO staging queue between
    /// the FEx clock domain and the ΔRNN). The caller must consume frames
    /// via `poll_frame`/`skip_frame` before pushing more. No sample was
    /// consumed.
    FifoOverflow {
        /// feature frames currently buffered and ready to consume
        pending: usize,
        /// frames the push would have added on top of `pending`
        incoming: usize,
        /// the frame buffer's capacity
        /// ([`PENDING_FRAME_CAP`](crate::chip::PENDING_FRAME_CAP))
        capacity: usize,
    },
}

impl fmt::Display for ChipError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ChipError::FifoOverflow { pending, incoming, capacity } => write!(
                f,
                "CDC FIFO overflow: accelerator starved ({pending} frames pending + \
                 {incoming} incoming > capacity {capacity}); poll/skip frames before pushing"
            ),
        }
    }
}

impl std::error::Error for ChipError {}

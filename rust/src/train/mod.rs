//! Training driver: runs the AOT-compiled `train_step` through PJRT.
//!
//! The whole learning loop is Rust: synthetic utterances are rendered by
//! the audio substrate, featurised by the *fixed-point FEx twin* (so the
//! network trains on exactly the features the chip computes), batched into
//! tensors, and pushed through the `train_step.hlo.txt` artifact (delta-
//! aware forward with straight-through thresholding + Adam, lowered once
//! from JAX — see python/compile/model.py). The resulting float weights are
//! quantised to the chip's int8/Q8.8 formats and serialised as the SRAM
//! weight image the accelerator twin loads.
//!
//! ABI (python/compile/model.train_step_flat):
//!   args:    5 params, 5 adam-m, 5 adam-v, step, feats [B,T,C], labels [B] s32, delta_th
//!   results: 5 params, 5 adam-m, 5 adam-v, step, loss

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context};

use crate::accel::gru::{self, FloatParams, QuantParams};
use crate::dataset::{Dataset, Split};
use crate::runtime::{Executable, IntTensor, Runtime, Tensor, Value};
use crate::util::prng::Pcg;

/// Number of parameter tensors in the canonical order (w_x, w_h, b, w_fc, b_fc).
pub const N_PARAMS: usize = 5;

/// Base Adam learning rate (dense phase; matches python ADAM_LR).
pub const BASE_LR: f32 = 3e-3;
/// Fine-tuning rate once the straight-through Θ is active.
pub const FINETUNE_LR: f32 = 3e-4;

/// Float training state (host-side mirrors of the device tensors).
#[derive(Debug, Clone)]
pub struct TrainState {
    pub params: Vec<Tensor>,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub step: f32,
}

impl TrainState {
    /// Glorot-uniform init matching `python/compile/model.init_params`
    /// (update-gate bias +1).
    pub fn init(rt: &Runtime, seed: u64) -> Self {
        let mut rng = Pcg::new(seed);
        let mut params = Vec::with_capacity(N_PARAMS);
        for (name, shape) in &rt.manifest.param_shapes {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = if name == "b" {
                // zero biases, +1 on the update-gate block
                let h = rt.manifest.hidden;
                (0..n).map(|i| if i >= h && i < 2 * h { 1.0 } else { 0.0 }).collect()
            } else if name.starts_with('b') {
                vec![0.0; n]
            } else {
                let (fan_in, fan_out) = (shape[0] as f64, shape[1] as f64);
                let lim = (6.0 / (fan_in + fan_out)).sqrt();
                (0..n).map(|_| rng.range_f64(-lim, lim) as f32).collect()
            };
            params.push(Tensor::new(shape.clone(), data));
        }
        let zeros: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        Self { params, m: zeros.clone(), v: zeros, step: 0.0 }
    }
}

/// Per-step record for the loss curve (EXPERIMENTS.md end-to-end run).
#[derive(Debug, Clone, Copy)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
}

/// The trainer.
pub struct Trainer {
    pub dataset: Dataset,
    pub batch: usize,
    pub delta_th: f32,
    train_exe: Executable,
    fwd_exe: Executable,
    frames: usize,
    channels: usize,
    pub log: Vec<StepLog>,
}

impl Trainer {
    pub fn new(rt: &Runtime, dataset: Dataset, batch: usize, delta_th: f32) -> crate::Result<Self> {
        if batch != rt.manifest.batch {
            bail!("batch {} != artifact batch {}", batch, rt.manifest.batch);
        }
        Ok(Self {
            dataset,
            batch,
            delta_th,
            train_exe: rt.load("train_step.hlo.txt")?,
            fwd_exe: rt.load("kws_fwd_b16.hlo.txt")?,
            frames: rt.manifest.frames,
            channels: rt.manifest.channels,
            log: Vec::new(),
        })
    }

    /// Assemble a feature/label batch as device tensors. Features are the
    /// fixed-point FEx twin's Q0.8 outputs rescaled to [0, 1) floats.
    pub fn batch_tensors(&self, split: Split, start: usize) -> (Tensor, IntTensor) {
        let seqs = self.dataset.feature_batch(split, start, self.batch);
        let mut feats = Vec::with_capacity(self.batch * self.frames * self.channels);
        let mut labels = Vec::with_capacity(self.batch);
        for s in &seqs {
            labels.push(s.label as i32);
            for t in 0..self.frames {
                let frame = s.feats.get(t).copied().unwrap_or([0i16; 16]);
                for c in 0..self.channels {
                    feats.push(frame[c] as f32 / 256.0);
                }
            }
        }
        (
            Tensor::new(vec![self.batch, self.frames, self.channels], feats),
            IntTensor::new(vec![self.batch], labels),
        )
    }

    /// One optimisation step at an explicit threshold + learning rate.
    pub fn step_at(
        &mut self,
        state: &mut TrainState,
        batch_index: usize,
        delta_th: f32,
        lr: f32,
    ) -> crate::Result<f32> {
        let (feats, labels) = self.batch_tensors(Split::Train, batch_index * self.batch);
        let mut inputs: Vec<Value> = Vec::with_capacity(20);
        for t in &state.params {
            inputs.push(t.clone().into());
        }
        for t in &state.m {
            inputs.push(t.clone().into());
        }
        for t in &state.v {
            inputs.push(t.clone().into());
        }
        inputs.push(Tensor::scalar(state.step).into());
        inputs.push(feats.into());
        inputs.push(labels.into());
        inputs.push(Tensor::scalar(delta_th).into());
        inputs.push(Tensor::scalar(lr).into());

        let out = self.train_exe.run(&inputs)?;
        if out.len() != 3 * N_PARAMS + 2 {
            bail!("train_step returned {} tensors, expected {}", out.len(), 3 * N_PARAMS + 2);
        }
        state.params = out[..N_PARAMS].to_vec();
        state.m = out[N_PARAMS..2 * N_PARAMS].to_vec();
        state.v = out[2 * N_PARAMS..3 * N_PARAMS].to_vec();
        state.step = out[3 * N_PARAMS].data[0];
        let loss = out[3 * N_PARAMS + 1].data[0];
        self.log.push(StepLog { step: state.step as usize, loss });
        Ok(loss)
    }

    /// One optimisation step at the trainer's target threshold.
    pub fn step(&mut self, state: &mut TrainState, batch_index: usize) -> crate::Result<f32> {
        self.step_at(state, batch_index, self.delta_th, BASE_LR)
    }

    /// Threshold curriculum (DeltaRNN training recipe): dense pretraining
    /// for the first 60%, a linear Θ ramp over the next 20%, then
    /// fine-tuning at the target threshold. Training with the threshold
    /// active from step 0 stalls (the STE gradient is too noisy before the
    /// features are linearly separable); fine-tuning at full LR diverges —
    /// hence the paired LR schedule below.
    pub fn schedule_th(&self, s: usize, total: usize) -> f32 {
        let frac = s as f32 / total.max(1) as f32;
        if frac < 0.6 {
            0.0
        } else if frac < 0.8 {
            self.delta_th * (frac - 0.6) * 5.0
        } else {
            self.delta_th
        }
    }

    /// LR paired with the Θ curriculum: full rate while dense, 10x lower
    /// once the straight-through threshold is active.
    pub fn schedule_lr(&self, s: usize, total: usize) -> f32 {
        let frac = s as f32 / total.max(1) as f32;
        if frac < 0.6 {
            BASE_LR
        } else {
            FINETUNE_LR
        }
    }

    /// Run `steps` optimisation steps with the threshold/LR curriculum,
    /// streaming fresh synthetic utterances throughout.
    pub fn fit(&mut self, state: &mut TrainState, steps: usize, verbose: bool) -> crate::Result<()> {
        for s in 0..steps {
            let th = self.schedule_th(s, steps);
            let lr = self.schedule_lr(s, steps);
            let loss = self.step_at(state, s, th, lr)?;
            if verbose && (s < 5 || s % 50 == 0 || s + 1 == steps) {
                println!("step {s:>4}  loss {loss:.4}  (train Θ = {th:.3}, lr = {lr:.4})");
            }
            if !loss.is_finite() {
                bail!("training diverged at step {s} (loss = {loss})");
            }
        }
        Ok(())
    }

    /// Float-model accuracy via the batched forward artifact at `delta_th`.
    pub fn evaluate(
        &self,
        state: &TrainState,
        split: Split,
        utterances: usize,
        delta_th: f32,
    ) -> crate::Result<(f64, f64)> {
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut sparsity_sum = 0.0f64;
        let mut start = 0usize;
        while total < utterances {
            let (feats, labels) = self.batch_tensors(split, start);
            start += self.batch;
            let mut inputs: Vec<Value> =
                state.params.iter().map(|t| Value::from(t.clone())).collect();
            inputs.push(feats.into());
            inputs.push(Tensor::scalar(delta_th).into());
            let out = self.fwd_exe.run(&inputs)?;
            let logits = &out[0]; // [B, 12]
            let sparsity = &out[1]; // [B]
            for b in 0..self.batch {
                if total >= utterances {
                    break;
                }
                let row = &logits.data[b * 12..(b + 1) * 12];
                let pred = (0..12)
                    .max_by(|&i, &j| row[i].partial_cmp(&row[j]).unwrap())
                    .unwrap();
                if pred as i32 == labels.data[b] {
                    correct += 1;
                }
                sparsity_sum += sparsity.data[b] as f64;
                total += 1;
            }
        }
        Ok((correct as f64 / total as f64, sparsity_sum / total as f64))
    }

    /// Convert the trained float tensors into chip formats.
    pub fn export(&self, state: &TrainState) -> QuantParams {
        gru::quantize_params(&float_params_from_tensors(&state.params))
    }
}

/// Reassemble [`FloatParams`] from the canonical tensor list.
pub fn float_params_from_tensors(params: &[Tensor]) -> FloatParams {
    assert_eq!(params.len(), N_PARAMS);
    let (c, g) = (gru::C, gru::G);
    let h = gru::H;
    let k = gru::K;
    let mut p = FloatParams::zeros();
    for i in 0..c {
        p.w_x[i].copy_from_slice(&params[0].data[i * g..(i + 1) * g]);
    }
    for j in 0..h {
        p.w_h[j].copy_from_slice(&params[1].data[j * g..(j + 1) * g]);
    }
    p.b.copy_from_slice(&params[2].data);
    for j in 0..h {
        p.w_fc[j].copy_from_slice(&params[3].data[j * k..(j + 1) * k]);
    }
    p.b_fc.copy_from_slice(&params[4].data);
    p
}

// ---------------------------------------------------------------------------
// Weight image persistence (results/weights.bin)
// ---------------------------------------------------------------------------

const MAGIC: &[u8; 8] = b"DKWSWv1\0";

/// Save a quantised model as an SRAM weight image file.
pub fn save_weights(path: &Path, q: &QuantParams) -> crate::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let img = gru::to_sram_image(q);
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&(img.len() as u32).to_le_bytes())?;
    for w in &img {
        f.write_all(&w.to_le_bytes())?;
    }
    Ok(())
}

/// Load a weight image file back into quantised parameters.
pub fn load_weights(path: &Path) -> crate::Result<QuantParams> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening weights {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad weights magic in {}", path.display());
    }
    let mut len4 = [0u8; 4];
    f.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len != gru::IMAGE_WORDS {
        bail!("weight image is {len} words, expected {}", gru::IMAGE_WORDS);
    }
    let mut buf = vec![0u8; len * 2];
    f.read_exact(&mut buf)?;
    let img: Vec<u16> =
        buf.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect();
    Ok(gru::from_sram_image(&img))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn float_params_roundtrip_layout() {
        // tensor list -> FloatParams keeps row-major [lane][gate] layout
        let g = gru::G;
        let mut t_wx = Tensor::zeros(&[gru::C, g]);
        t_wx.data[2 * g + 5] = 0.75; // lane 2, gate 5
        let params = vec![
            t_wx,
            Tensor::zeros(&[gru::H, g]),
            Tensor::zeros(&[g]),
            Tensor::zeros(&[gru::H, gru::K]),
            Tensor::zeros(&[gru::K]),
        ];
        let p = float_params_from_tensors(&params);
        assert_eq!(p.w_x[2][5], 0.75);
        assert_eq!(p.w_x[0][0], 0.0);
    }

    #[test]
    fn weights_file_roundtrip() {
        let mut p = FloatParams::zeros();
        p.w_x[3][7] = 0.5;
        p.b[10] = -1.25;
        p.w_fc[63][11] = -0.5;
        let q = gru::quantize_params(&p);
        let path = std::env::temp_dir().join("deltakws_weights_test.bin");
        save_weights(&path, &q).unwrap();
        let q2 = load_weights(&path).unwrap();
        assert_eq!(q.w_x, q2.w_x);
        assert_eq!(q.b, q2.b);
        assert_eq!(q.w_fc, q2.w_fc);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_bad_magic() {
        let path = std::env::temp_dir().join("deltakws_badmagic.bin");
        std::fs::write(&path, b"NOTDKWS\0aaaa").unwrap();
        assert!(load_weights(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    // PJRT-backed Trainer tests live in rust/tests/train_integration.rs.
}

//! Training driver: runs the delta-aware `train_step` through a pluggable
//! execution [`Backend`].
//!
//! The whole learning loop is Rust: synthetic utterances are rendered by
//! the audio substrate, featurised by the *fixed-point FEx twin* (so the
//! network trains on exactly the features the chip computes), batched into
//! tensors, and pushed through the backend's training step (delta-aware
//! forward with straight-through thresholding + Adam). The default build
//! uses the pure-Rust [`crate::runtime::NativeBackend`]; with the `pjrt`
//! feature and AOT artifacts present, the identical step executes as the
//! lowered `train_step.hlo.txt` (see python/compile/model.py). The
//! resulting float weights are quantised to the chip's int8/Q8.8 formats
//! and serialised as the SRAM weight image the accelerator twin loads.
//!
//! ABI (python/compile/model.train_step_flat):
//!   args:    5 params, 5 adam-m, 5 adam-v, step, feats [B,T,C], labels [B] s32, delta_th, lr
//!   results: 5 params, 5 adam-m, 5 adam-v, step, loss

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context};

use crate::accel::gru::{self, FloatParams, QuantParams};
use crate::dataset::{Dataset, Split};
use crate::runtime::{Backend, IntTensor, Manifest, Tensor};

pub use crate::runtime::TrainState;

/// Number of parameter tensors in the canonical order (w_x, w_h, b, w_fc, b_fc).
pub const N_PARAMS: usize = 5;

/// Base Adam learning rate (dense phase; matches python ADAM_LR).
pub const BASE_LR: f32 = 3e-3;
/// Fine-tuning rate once the straight-through Θ is active.
pub const FINETUNE_LR: f32 = 3e-4;

/// Per-step record for the loss curve (EXPERIMENTS.md end-to-end run).
#[derive(Debug, Clone, Copy)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
}

/// The trainer: dataset + featurisation + the backend's train/eval steps.
pub struct Trainer {
    pub dataset: Dataset,
    pub batch: usize,
    pub delta_th: f32,
    backend: Box<dyn Backend>,
    frames: usize,
    channels: usize,
    pub log: Vec<StepLog>,
}

impl Trainer {
    pub fn new(
        backend: Box<dyn Backend>,
        dataset: Dataset,
        batch: usize,
        delta_th: f32,
    ) -> crate::Result<Self> {
        if !backend.supports_batch(batch) {
            bail!(
                "batch {} unsupported by backend {} (nominal batch {})",
                batch,
                backend.name(),
                backend.manifest().batch
            );
        }
        let frames = backend.manifest().frames;
        let channels = backend.manifest().channels;
        Ok(Self { dataset, batch, delta_th, backend, frames, channels, log: Vec::new() })
    }

    /// The backend's model geometry.
    pub fn manifest(&self) -> &Manifest {
        self.backend.manifest()
    }

    /// Backend identity (for logging).
    pub fn backend_name(&self) -> String {
        self.backend.name()
    }

    /// Fresh training state seeded for this backend's geometry.
    pub fn init_state(&self, seed: u64) -> TrainState {
        TrainState::init(self.backend.manifest(), seed)
    }

    /// Assemble a feature/label batch as host tensors. Features are the
    /// fixed-point FEx twin's Q0.8 outputs rescaled to [0, 1) floats.
    pub fn batch_tensors(&self, split: Split, start: usize) -> (Tensor, IntTensor) {
        let seqs = self.dataset.feature_batch(split, start, self.batch);
        let mut feats = Vec::with_capacity(self.batch * self.frames * self.channels);
        let mut labels = Vec::with_capacity(self.batch);
        for s in &seqs {
            labels.push(s.label as i32);
            for t in 0..self.frames {
                let frame = s.feats.get(t).copied().unwrap_or([0i16; 16]);
                for c in 0..self.channels {
                    feats.push(frame[c] as f32 / 256.0);
                }
            }
        }
        (
            Tensor::new(vec![self.batch, self.frames, self.channels], feats),
            IntTensor::new(vec![self.batch], labels),
        )
    }

    /// One optimisation step at an explicit threshold + learning rate.
    pub fn step_at(
        &mut self,
        state: &mut TrainState,
        batch_index: usize,
        delta_th: f32,
        lr: f32,
    ) -> crate::Result<f32> {
        let (feats, labels) = self.batch_tensors(Split::Train, batch_index * self.batch);
        let loss = self.backend.train_step(state, &feats, &labels, delta_th, lr)?;
        self.log.push(StepLog { step: state.step as usize, loss });
        Ok(loss)
    }

    /// One optimisation step at the trainer's target threshold.
    pub fn step(&mut self, state: &mut TrainState, batch_index: usize) -> crate::Result<f32> {
        self.step_at(state, batch_index, self.delta_th, BASE_LR)
    }

    /// Threshold curriculum (DeltaRNN training recipe): dense pretraining
    /// for the first 60%, a linear Θ ramp over the next 20%, then
    /// fine-tuning at the target threshold. Training with the threshold
    /// active from step 0 stalls (the STE gradient is too noisy before the
    /// features are linearly separable); fine-tuning at full LR diverges —
    /// hence the paired LR schedule below.
    pub fn schedule_th(&self, s: usize, total: usize) -> f32 {
        let frac = s as f32 / total.max(1) as f32;
        if frac < 0.6 {
            0.0
        } else if frac < 0.8 {
            self.delta_th * (frac - 0.6) * 5.0
        } else {
            self.delta_th
        }
    }

    /// LR paired with the Θ curriculum: full rate while dense, 10x lower
    /// once the straight-through threshold is active.
    pub fn schedule_lr(&self, s: usize, total: usize) -> f32 {
        let frac = s as f32 / total.max(1) as f32;
        if frac < 0.6 {
            BASE_LR
        } else {
            FINETUNE_LR
        }
    }

    /// Run `steps` optimisation steps with the threshold/LR curriculum,
    /// streaming fresh synthetic utterances throughout.
    pub fn fit(&mut self, state: &mut TrainState, steps: usize, verbose: bool) -> crate::Result<()> {
        for s in 0..steps {
            let th = self.schedule_th(s, steps);
            let lr = self.schedule_lr(s, steps);
            let loss = self.step_at(state, s, th, lr)?;
            if verbose && (s < 5 || s % 50 == 0 || s + 1 == steps) {
                println!("step {s:>4}  loss {loss:.4}  (train Θ = {th:.3}, lr = {lr:.4})");
            }
            if !loss.is_finite() {
                bail!("training diverged at step {s} (loss = {loss})");
            }
        }
        Ok(())
    }

    /// Float-model accuracy via the backend's batched forward at `delta_th`.
    pub fn evaluate(
        &self,
        state: &TrainState,
        split: Split,
        utterances: usize,
        delta_th: f32,
    ) -> crate::Result<(f64, f64)> {
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut sparsity_sum = 0.0f64;
        let mut start = 0usize;
        let k = self.backend.manifest().classes;
        while total < utterances {
            let (feats, labels) = self.batch_tensors(split, start);
            start += self.batch;
            let out = self.backend.forward(&state.params, &feats, delta_th)?;
            for b in 0..self.batch {
                if total >= utterances {
                    break;
                }
                let row = &out.logits.data[b * k..(b + 1) * k];
                let pred = (0..k)
                    .max_by(|&i, &j| row[i].partial_cmp(&row[j]).unwrap())
                    .unwrap();
                if pred as i32 == labels.data[b] {
                    correct += 1;
                }
                sparsity_sum += out.sparsity.data[b] as f64;
                total += 1;
            }
        }
        Ok((correct as f64 / total as f64, sparsity_sum / total as f64))
    }

    /// Convert the trained float tensors into chip formats.
    pub fn export(&self, state: &TrainState) -> QuantParams {
        gru::quantize_params(&float_params_from_tensors(&state.params))
    }
}

/// Reassemble [`FloatParams`] from the canonical tensor list.
pub fn float_params_from_tensors(params: &[Tensor]) -> FloatParams {
    assert_eq!(params.len(), N_PARAMS);
    let (c, g) = (gru::C, gru::G);
    let h = gru::H;
    let k = gru::K;
    let mut p = FloatParams::zeros();
    for i in 0..c {
        p.w_x[i].copy_from_slice(&params[0].data[i * g..(i + 1) * g]);
    }
    for j in 0..h {
        p.w_h[j].copy_from_slice(&params[1].data[j * g..(j + 1) * g]);
    }
    p.b.copy_from_slice(&params[2].data);
    for j in 0..h {
        p.w_fc[j].copy_from_slice(&params[3].data[j * k..(j + 1) * k]);
    }
    p.b_fc.copy_from_slice(&params[4].data);
    p
}

// ---------------------------------------------------------------------------
// Weight image persistence (results/weights.bin)
// ---------------------------------------------------------------------------

const MAGIC: &[u8; 8] = b"DKWSWv1\0";

/// Save a quantised model as an SRAM weight image file.
pub fn save_weights(path: &Path, q: &QuantParams) -> crate::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let img = gru::to_sram_image(q);
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&(img.len() as u32).to_le_bytes())?;
    for w in &img {
        f.write_all(&w.to_le_bytes())?;
    }
    Ok(())
}

/// Load a weight image file back into quantised parameters.
pub fn load_weights(path: &Path) -> crate::Result<QuantParams> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening weights {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad weights magic in {}", path.display());
    }
    let mut len4 = [0u8; 4];
    f.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len != gru::IMAGE_WORDS {
        bail!("weight image is {len} words, expected {}", gru::IMAGE_WORDS);
    }
    let mut buf = vec![0u8; len * 2];
    f.read_exact(&mut buf)?;
    let img: Vec<u16> =
        buf.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect();
    Ok(gru::from_sram_image(&img))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn float_params_roundtrip_layout() {
        // tensor list -> FloatParams keeps row-major [lane][gate] layout
        let g = gru::G;
        let mut t_wx = Tensor::zeros(&[gru::C, g]);
        t_wx.data[2 * g + 5] = 0.75; // lane 2, gate 5
        let params = vec![
            t_wx,
            Tensor::zeros(&[gru::H, g]),
            Tensor::zeros(&[g]),
            Tensor::zeros(&[gru::H, gru::K]),
            Tensor::zeros(&[gru::K]),
        ];
        let p = float_params_from_tensors(&params);
        assert_eq!(p.w_x[2][5], 0.75);
        assert_eq!(p.w_x[0][0], 0.0);
    }

    #[test]
    fn weights_file_roundtrip() {
        let mut p = FloatParams::zeros();
        p.w_x[3][7] = 0.5;
        p.b[10] = -1.25;
        p.w_fc[63][11] = -0.5;
        let q = gru::quantize_params(&p);
        let path = std::env::temp_dir().join("deltakws_weights_test.bin");
        save_weights(&path, &q).unwrap();
        let q2 = load_weights(&path).unwrap();
        assert_eq!(q.w_x, q2.w_x);
        assert_eq!(q.b, q2.b);
        assert_eq!(q.w_fc, q2.w_fc);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_bad_magic() {
        let path = std::env::temp_dir().join("deltakws_badmagic.bin");
        std::fs::write(&path, b"NOTDKWS\0aaaa").unwrap();
        assert!(load_weights(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn trainer_rejects_unsupported_batch() {
        // nominal-batch backends gate on supports_batch
        let backend = crate::runtime::backend_for("artifacts").unwrap();
        let ds = Dataset::new(1);
        assert!(Trainer::new(backend, ds, 0, 0.1).is_err());
    }

    // Backend-driven Trainer tests live in rust/tests/train_integration.rs.
}

//! 24 kB near-V_TH weight SRAM twin (paper §II-D, Fig. 8).
//!
//! Organisation: 12 banks x 2 kB, 16-bit words (each holding two 8-bit ΔRNN
//! weights), 10-bit in-bank addresses. The functional model provides
//! word-addressed read/write with per-bank activity counters; energy comes
//! from [`crate::energy::calib`] (near-V_TH vs foundry flavours — the 6.6x
//! read-power comparison), area from the bitcell model below, and the
//! skew-resistant column-MUX timing from the discrete-event model in
//! [`timing`] (paper Fig. 13).

pub mod timing;

use std::sync::Arc;

use crate::energy::SramKind;

/// Total capacity: 24 kB = 12,288 16-bit words.
pub const WORDS: usize = 12 * 1024;
/// Banks (2 kB each).
pub const BANKS: usize = 12;
/// Words per bank.
pub const WORDS_PER_BANK: usize = WORDS / BANKS;

/// 65 nm bitcell + periphery area model, anchored at the paper's block
/// areas: the full-custom near-V_TH macro measures 0.381 mm² for 24 kB and
/// is "2x larger" than the foundry push-rule macro (§II-D).
///
/// 0.381 mm² / 196,608 bits = 1.94 µm²/bit effective; we attribute
/// 1.43 µm² to the 8T high-V_TH bitcell with pitch-matched 6T WL level
/// shifters and 35% to periphery (WL drivers, booster, timing generator,
/// column MUX, I/O level shifters).
pub const CELL_UM2: f64 = 1.435;
pub const PERIPHERY_FACTOR: f64 = 1.35;
/// Foundry push-rule equivalent bit area (µm²) including periphery.
pub const FOUNDRY_BIT_UM2: f64 = 0.97;

/// Area of the near-V_TH macro (mm²).
pub fn area_mm2() -> f64 {
    (WORDS * 16) as f64 * CELL_UM2 * PERIPHERY_FACTOR * 1e-6
}

/// Area of the foundry comparison macro (mm²).
pub fn foundry_area_mm2() -> f64 {
    (WORDS * 16) as f64 * FOUNDRY_BIT_UM2 * 1e-6
}

/// Build a full-length, reference-counted SRAM image from a (possibly
/// shorter) serialised weight image: the tail beyond `words.len()` is
/// zero, exactly the state a freshly constructed [`WeightSram`] holds
/// after [`load_image`](WeightSram::load_image). One shared image backs
/// every chip twin serving the same weight version — at 10k+ parked
/// sessions this is the difference between 24 kB and 24 MB-per-thousand
/// of resident weight memory.
pub fn shared_image(words: &[u16]) -> Arc<Vec<u16>> {
    assert!(words.len() <= WORDS, "image larger than SRAM");
    let mut full = vec![0u16; WORDS];
    full[..words.len()].copy_from_slice(words);
    Arc::new(full)
}

/// The weight SRAM twin.
///
/// The data array is reference-counted with copy-on-write semantics:
/// [`load_shared_image`](Self::load_shared_image) installs a shared
/// pointer (O(1), no copy), and any subsequent [`write_word`]
/// (Self::write_word) detaches a private copy first. Cloning a
/// `WeightSram` therefore shares the word array until either side
/// writes — observable behaviour is identical to the old deep-copy
/// model, but a thousand idle sessions on the same weight version hold
/// one 24 kB image, not a thousand.
#[derive(Debug, Clone)]
pub struct WeightSram {
    data: Arc<Vec<u16>>,
    pub kind: SramKind,
    /// total word reads / writes
    pub reads: u64,
    pub writes: u64,
    /// per-bank read counters (banking utilisation analysis)
    pub bank_reads: [u64; BANKS],
}

impl WeightSram {
    pub fn new(kind: SramKind) -> Self {
        Self {
            data: Arc::new(vec![0; WORDS]),
            kind,
            reads: 0,
            writes: 0,
            bank_reads: [0; BANKS],
        }
    }

    /// Bank index of a word address.
    #[inline]
    pub fn bank_of(addr: usize) -> usize {
        addr / WORDS_PER_BANK
    }

    /// Read one 16-bit word (counted).
    #[inline]
    pub fn read_word(&mut self, addr: usize) -> u16 {
        debug_assert!(addr < WORDS, "SRAM read OOB: {addr}");
        self.reads += 1;
        self.bank_reads[Self::bank_of(addr)] += 1;
        self.data[addr]
    }

    /// Read two packed int8 weights from one word: (low, high).
    #[inline]
    pub fn read_weight_pair(&mut self, addr: usize) -> (i8, i8) {
        let w = self.read_word(addr);
        ((w & 0xff) as i8, (w >> 8) as i8)
    }

    /// Count a contiguous burst of `words` reads starting at `base` without
    /// touching the data array: the per-word and per-bank counters end up
    /// exactly as if [`read_word`](Self::read_word) had walked the span.
    /// This is the accounting half of the row-burst path the vectorized
    /// MAC kernels use (one counter update per row instead of 96).
    pub fn record_row_read(&mut self, base: usize, words: usize) {
        debug_assert!(base + words <= WORDS, "SRAM burst OOB: {base}+{words}");
        self.reads += words as u64;
        let mut addr = base;
        let end = base + words;
        while addr < end {
            let bank = Self::bank_of(addr);
            let span = end.min((bank + 1) * WORDS_PER_BANK) - addr;
            self.bank_reads[bank] += span as u64;
            addr += span;
        }
    }

    /// Read a contiguous row burst: counts like `words` single reads (see
    /// [`record_row_read`](Self::record_row_read)) and returns the word
    /// slice for lane-packed consumption.
    #[inline]
    pub fn read_row(&mut self, base: usize, words: usize) -> &[u16] {
        self.record_row_read(base, words);
        &self.data[base..base + words]
    }

    /// Write one word (counted; used by the weight loader). Detaches a
    /// private copy first if the word array is currently shared.
    pub fn write_word(&mut self, addr: usize, v: u16) {
        assert!(addr < WORDS, "SRAM write OOB: {addr}");
        self.writes += 1;
        Arc::make_mut(&mut self.data)[addr] = v;
    }

    /// Pack two int8 weights into a word and write it.
    pub fn write_weight_pair(&mut self, addr: usize, lo: i8, hi: i8) {
        self.write_word(addr, (lo as u8 as u16) | ((hi as u8 as u16) << 8));
    }

    /// Bulk-load a weight image starting at word 0.
    pub fn load_image(&mut self, words: &[u16]) {
        assert!(words.len() <= WORDS, "image larger than SRAM");
        for (addr, &w) in words.iter().enumerate() {
            self.write_word(addr, w);
        }
    }

    /// Install a pre-built full-length image by pointer (see
    /// [`shared_image`]): O(1), no word copy, the array is shared with
    /// every other SRAM serving the same image until one of them writes.
    /// Write accounting matches the per-word loader — the macro "wrote"
    /// the whole array, however the functional model got the bits in.
    pub fn load_shared_image(&mut self, image: &Arc<Vec<u16>>) {
        assert!(image.len() == WORDS, "shared image must span the full SRAM");
        self.writes += WORDS as u64;
        self.data = Arc::clone(image);
    }

    /// Read energy consumed so far (nJ), by SRAM flavour.
    pub fn read_energy_nj(&self) -> f64 {
        self.reads as f64 * self.kind.word_energy_pj() * 1e-3
    }

    /// Direct (uncounted) access for test/debug inspection.
    pub fn peek(&self, addr: usize) -> u16 {
        self.data[addr]
    }

    pub fn reset_counters(&mut self) {
        self.reads = 0;
        self.writes = 0;
        self.bank_reads = [0; BANKS];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry() {
        assert_eq!(WORDS, 12_288); // 24 kB of 16-bit words
        assert_eq!(WORDS_PER_BANK, 1_024); // 2 kB banks
    }

    #[test]
    fn area_anchored_to_paper() {
        let a = area_mm2();
        assert!((a - 0.381).abs() / 0.381 < 0.02, "{a}");
        // paper: "2x larger area than the push-rule foundry SRAM"
        let ratio = a / foundry_area_mm2();
        assert!((ratio - 2.0).abs() < 0.1, "{ratio}");
    }

    #[test]
    fn rw_roundtrip_and_counters() {
        let mut s = WeightSram::new(SramKind::NearVth);
        s.write_word(100, 0xBEEF);
        assert_eq!(s.read_word(100), 0xBEEF);
        assert_eq!(s.reads, 1);
        assert_eq!(s.writes, 1);
        assert_eq!(s.bank_reads[0], 1);
    }

    #[test]
    fn weight_pair_packing_signed() {
        let mut s = WeightSram::new(SramKind::NearVth);
        s.write_weight_pair(0, -128, 127);
        assert_eq!(s.read_weight_pair(0), (-128, 127));
        s.write_weight_pair(1, -1, 1);
        assert_eq!(s.read_weight_pair(1), (-1, 1));
    }

    #[test]
    fn bank_mapping() {
        assert_eq!(WeightSram::bank_of(0), 0);
        assert_eq!(WeightSram::bank_of(1023), 0);
        assert_eq!(WeightSram::bank_of(1024), 1);
        assert_eq!(WeightSram::bank_of(WORDS - 1), BANKS - 1);
    }

    #[test]
    fn row_burst_counts_like_single_reads() {
        let mut a = WeightSram::new(SramKind::NearVth);
        let mut b = WeightSram::new(SramKind::NearVth);
        for addr in 0..WORDS {
            a.write_word(addr, (addr % 65536) as u16);
            b.write_word(addr, (addr % 65536) as u16);
        }
        // a bank-straddling burst (1000..1100 crosses the 1024 boundary)
        let row: Vec<u16> = a.read_row(1000, 100).to_vec();
        for (i, addr) in (1000..1100).enumerate() {
            assert_eq!(row[i], b.read_word(addr));
        }
        assert_eq!(a.reads, b.reads);
        assert_eq!(a.bank_reads, b.bank_reads);
    }

    #[test]
    fn bank_counters_attribute_reads() {
        let mut s = WeightSram::new(SramKind::NearVth);
        for addr in [0usize, 1024, 1025, 5000, 12_287] {
            s.read_word(addr);
        }
        assert_eq!(s.bank_reads[0], 1);
        assert_eq!(s.bank_reads[1], 2);
        assert_eq!(s.bank_reads[4], 1);
        assert_eq!(s.bank_reads[11], 1);
    }

    #[test]
    fn read_energy_flavours_differ_6_6x_ish() {
        let mut near = WeightSram::new(SramKind::NearVth);
        let mut foundry = WeightSram::new(SramKind::Foundry);
        for a in 0..1000 {
            near.read_word(a);
            foundry.read_word(a);
        }
        let r = foundry.read_energy_nj() / near.read_energy_nj();
        assert!(r > 4.0 && r < 7.0, "{r}"); // dynamic-only ratio (5.5x)
    }

    #[test]
    fn load_image() {
        let mut s = WeightSram::new(SramKind::NearVth);
        s.load_image(&[1, 2, 3]);
        assert_eq!(s.peek(0), 1);
        assert_eq!(s.peek(2), 3);
    }

    #[test]
    #[should_panic]
    fn oob_write_panics() {
        let mut s = WeightSram::new(SramKind::NearVth);
        s.write_word(WORDS, 0);
    }

    #[test]
    fn shared_image_installs_by_pointer_and_pads_tail() {
        let img = shared_image(&[7, 8, 9]);
        let mut a = WeightSram::new(SramKind::NearVth);
        let mut b = WeightSram::new(SramKind::NearVth);
        a.load_shared_image(&img);
        b.load_shared_image(&img);
        assert!(Arc::ptr_eq(&a.data, &b.data), "twins must share one image");
        assert_eq!(a.peek(1), 8);
        assert_eq!(a.peek(3), 0, "tail beyond the image is zero");
        assert_eq!(a.peek(WORDS - 1), 0);
        assert_eq!(a.writes, WORDS as u64);
    }

    #[test]
    fn shared_image_is_copy_on_write() {
        let img = shared_image(&[1, 2, 3]);
        let mut a = WeightSram::new(SramKind::NearVth);
        let mut b = WeightSram::new(SramKind::NearVth);
        a.load_shared_image(&img);
        b.load_shared_image(&img);
        a.write_word(0, 0xDEAD);
        assert_eq!(a.peek(0), 0xDEAD);
        assert_eq!(b.peek(0), 1, "write detached a private copy, peer unchanged");
        assert_eq!(img[0], 1, "the shared image itself is immutable");
        assert!(!Arc::ptr_eq(&a.data, &b.data));
    }

    #[test]
    fn shared_matches_per_word_load_bit_for_bit() {
        let words: Vec<u16> = (0..500u16).map(|i| i.wrapping_mul(31)).collect();
        let mut shared = WeightSram::new(SramKind::NearVth);
        let mut plain = WeightSram::new(SramKind::NearVth);
        shared.load_shared_image(&shared_image(&words));
        plain.load_image(&words);
        for addr in 0..WORDS {
            assert_eq!(shared.peek(addr), plain.peek(addr), "word {addr}");
        }
    }
}

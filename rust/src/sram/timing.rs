//! Discrete-event timing model of the skew-resistant column-MUX pre-charge
//! scheme (PCHCMX, paper §II-D and Fig. 13).
//!
//! The problem the scheme solves: the SRAM macro is full-custom but must
//! integrate with synthesized logic whose clock arrives with unknown skew.
//! A conventional column MUX evaluated directly by the logic clock would
//! sample the read bitlines at a skew-dependent moment — potentially before
//! the WL/booster sequence completes. The PCHCMX scheme instead derives the
//! dynamic-NOR pre-charge and evaluate strobes from the SRAM's *internal
//! timing generator* (launched by the clock's rising edge), so the output
//! register Q always refreshes just before/at the **falling** clock edge,
//! independent of moderate skew.
//!
//! The model is a gate-delay-level DES over the signals of Fig. 8/13:
//! CLK (skewed), WL (boosted word line), PCH (column-MUX pre-charge, active
//! low), EVAL (dynamic-NOR evaluate) and Q (output register). Tests assert
//! the paper's claim: one Q refresh per cycle, always inside a fixed window
//! around the falling edge, for every skew in the tolerated range.

/// Nominal internal delays (ns) at 0.6 V near-V_TH, 65 nm — slow but the
/// cycle is 8 µs at 125 kHz, so margins are enormous; the interesting
/// behaviour is the *ordering*, not the absolute numbers.
#[derive(Debug, Clone, Copy)]
pub struct TimingParams {
    /// clock period (ns): 8000 at 125 kHz
    pub period_ns: f64,
    /// high phase duration (ns)
    pub high_ns: f64,
    /// decoder + WL level-shifter + booster delay from rising edge
    pub wl_delay_ns: f64,
    /// bitcell read, bitline development time
    pub bl_develop_ns: f64,
    /// pre-charge pulse width for the dynamic-NOR column MUX
    pub pch_width_ns: f64,
    /// column-MUX evaluate -> Q register delay
    pub mux_delay_ns: f64,
    /// clock skew of the synthesized-logic clock vs the SRAM clock (ns);
    /// positive = logic clock late
    pub skew_ns: f64,
}

impl Default for TimingParams {
    fn default() -> Self {
        Self {
            period_ns: 8_000.0,
            high_ns: 4_000.0,
            wl_delay_ns: 220.0,
            bl_develop_ns: 900.0,
            pch_width_ns: 300.0,
            mux_delay_ns: 180.0,
            skew_ns: 0.0,
        }
    }
}

/// Signals of the Fig. 13 waveform.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Signal {
    /// synthesized-logic clock (skewed)
    Clk,
    /// boosted word line
    Wl,
    /// column-MUX pre-charge (active low)
    PchN,
    /// dynamic-NOR evaluate strobe
    Eval,
    /// 16-bit output register refresh (level toggles per refresh)
    Q,
}

/// One waveform edge.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Edge {
    pub t_ns: f64,
    pub signal: Signal,
    pub level: bool,
}

/// Simulate `cycles` read cycles; returns the edge list (sorted by time).
///
/// Sequencing per cycle (internal timing generator, launched at the SRAM
/// clock rising edge r = n*T):
///   WL rises at r + wl_delay, bitlines develop, PCH_N pulses low
///   (pre-charging the dynamic-NOR mux) after bitline development, EVAL
///   strobes at the end of the pre-charge, and Q refreshes mux_delay later —
///   placed so Q lands at the *falling* edge of the nominal clock. The
///   logic-side CLK edges are drawn skewed by `skew_ns` (what a scope
///   probing the logic clock would show, as in Fig. 13).
pub fn simulate(p: &TimingParams, cycles: usize) -> Vec<Edge> {
    let mut edges = Vec::with_capacity(cycles * 10);
    let mut q_level = false;
    for n in 0..cycles {
        let r = n as f64 * p.period_ns; // SRAM-internal rising edge
        let logic_r = r + p.skew_ns;
        // logic clock as observed (skewed)
        edges.push(Edge { t_ns: logic_r, signal: Signal::Clk, level: true });
        edges.push(Edge { t_ns: logic_r + p.high_ns, signal: Signal::Clk, level: false });
        // internal sequence (skew-independent: launched by the SRAM clock)
        let wl_up = r + p.wl_delay_ns;
        edges.push(Edge { t_ns: wl_up, signal: Signal::Wl, level: true });
        let bl_ready = wl_up + p.bl_develop_ns;
        // pre-charge pulse ends exactly pch_width before the evaluate point,
        // which the timing generator places so Q lands at the falling edge
        let eval_t = r + p.high_ns - p.mux_delay_ns;
        let pch_start = (eval_t - p.pch_width_ns).max(bl_ready);
        edges.push(Edge { t_ns: pch_start, signal: Signal::PchN, level: false });
        edges.push(Edge { t_ns: eval_t, signal: Signal::PchN, level: true });
        edges.push(Edge { t_ns: eval_t, signal: Signal::Eval, level: true });
        edges.push(Edge { t_ns: eval_t + 40.0, signal: Signal::Eval, level: false });
        let q_t = eval_t + p.mux_delay_ns; // == r + high_ns (falling edge)
        q_level = !q_level;
        edges.push(Edge { t_ns: q_t, signal: Signal::Q, level: q_level });
        // WL drops after evaluation
        edges.push(Edge { t_ns: eval_t + 60.0, signal: Signal::Wl, level: false });
    }
    edges.sort_by(|a, b| a.t_ns.partial_cmp(&b.t_ns).unwrap());
    edges
}

/// Q-refresh times relative to each cycle's *nominal* falling clock edge.
pub fn q_offsets_from_falling_edge(p: &TimingParams, cycles: usize) -> Vec<f64> {
    simulate(p, cycles)
        .iter()
        .filter(|e| e.signal == Signal::Q)
        .enumerate()
        .map(|(n, e)| e.t_ns - (n as f64 * p.period_ns + p.high_ns))
        .collect()
}

/// Render the waveform as CSV (t_ns, signal, level) for `exp fig13`.
pub fn waveform_csv(edges: &[Edge]) -> String {
    let mut s = String::from("t_ns,signal,level\n");
    for e in edges {
        s.push_str(&format!("{:.1},{:?},{}\n", e.t_ns, e.signal, e.level as u8));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_q_refresh_per_cycle() {
        let p = TimingParams::default();
        let edges = simulate(&p, 10);
        let q_edges = edges.iter().filter(|e| e.signal == Signal::Q).count();
        assert_eq!(q_edges, 10);
    }

    #[test]
    fn q_lands_on_falling_edge_at_zero_skew() {
        let p = TimingParams::default();
        for off in q_offsets_from_falling_edge(&p, 5) {
            assert!(off.abs() < 1.0, "offset {off}");
        }
    }

    #[test]
    fn q_timing_immune_to_skew() {
        // the paper's claim: Q refreshes near the falling edge regardless of
        // the logic-clock skew, because the strobe chain is internal
        for skew in [-400.0, -100.0, 0.0, 100.0, 400.0] {
            let p = TimingParams { skew_ns: skew, ..Default::default() };
            for off in q_offsets_from_falling_edge(&p, 5) {
                assert!(off.abs() < 1.0, "skew {skew}: offset {off}");
            }
        }
    }

    #[test]
    fn precharge_completes_before_eval() {
        let p = TimingParams::default();
        let edges = simulate(&p, 3);
        let mut pch_low_t = None;
        for e in &edges {
            match e.signal {
                Signal::PchN if !e.level => pch_low_t = Some(e.t_ns),
                Signal::Eval if e.level => {
                    let start = pch_low_t.expect("eval before any precharge");
                    assert!(e.t_ns - start >= p.pch_width_ns - 1.0, "short precharge");
                }
                _ => {}
            }
        }
    }

    #[test]
    fn wl_up_before_bitline_use() {
        let p = TimingParams::default();
        let edges = simulate(&p, 2);
        let wl_up: Vec<f64> = edges
            .iter()
            .filter(|e| e.signal == Signal::Wl && e.level)
            .map(|e| e.t_ns)
            .collect();
        let evals: Vec<f64> = edges
            .iter()
            .filter(|e| e.signal == Signal::Eval && e.level)
            .map(|e| e.t_ns)
            .collect();
        for (w, e) in wl_up.iter().zip(&evals) {
            assert!(e - w >= p.bl_develop_ns - p.pch_width_ns, "eval before bitlines settle");
        }
    }

    #[test]
    fn edges_sorted() {
        let edges = simulate(&TimingParams::default(), 4);
        for w in edges.windows(2) {
            assert!(w[0].t_ns <= w[1].t_ns);
        }
    }

    #[test]
    fn csv_renders() {
        let csv = waveform_csv(&simulate(&TimingParams::default(), 1));
        assert!(csv.starts_with("t_ns,signal,level\n"));
        assert!(csv.contains("Q"));
    }
}

//! `deltakws` — launcher CLI for the DeltaKWS system.
//!
//! Subcommands (hand-rolled parsing; no clap in the vendored set):
//!
//! ```text
//! deltakws train  [--steps N] [--batch B] [--seed S] [--out weights.bin]
//! deltakws eval   [--delta-th-q8 T] [--channels N] [--utterances N]
//! deltakws exp    <fig6|fig7|fig10|fig11|fig12|fig13|table1|table2|ablation|all>
//! deltakws serve  [--workers N] [--requests N] [--metrics-out BASE]
//!                 [--metrics-interval-s S]
//! deltakws enroll [--speaker S] [--target K] [--shots N] [--steps N]
//! deltakws info
//! ```
//!
//! Every subcommand accepts `--config path.toml` (see `configs/`), with
//! flags overriding file values. `make exp` == `deltakws exp all`.

use anyhow::{bail, Context};
use deltakws::config::RunConfig;
use deltakws::dataset::{Dataset, Split};
use deltakws::runtime;
use deltakws::train::Trainer;
use deltakws::{chip::KwsChip, coordinator, exp};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// SIGUSR1 → "dump a metrics snapshot now" (std-only: no signal crate in
/// the vendored set). The handler only flips an atomic flag; the serve
/// loop's watcher thread does the actual capture and file writes.
#[cfg(unix)]
mod sigusr1 {
    use std::sync::atomic::{AtomicBool, Ordering};

    static REQUESTED: AtomicBool = AtomicBool::new(false);

    #[cfg(any(target_os = "linux", target_os = "android"))]
    const SIGUSR1: i32 = 10;
    #[cfg(not(any(target_os = "linux", target_os = "android")))]
    const SIGUSR1: i32 = 30;

    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }

    extern "C" fn handler(_sig: i32) {
        REQUESTED.store(true, Ordering::Relaxed);
    }

    /// Install the handler (idempotent; best-effort).
    pub fn install() {
        // lint:allow(no-unsafe): FFI signal(2) registration is inherently unsafe; the handler only stores a relaxed atomic flag
        unsafe {
            signal(SIGUSR1, handler as extern "C" fn(i32) as usize);
        }
    }

    /// True once per delivered signal (consumes the request).
    pub fn take() -> bool {
        REQUESTED.swap(false, Ordering::Relaxed)
    }
}

#[cfg(not(unix))]
mod sigusr1 {
    pub fn install() {}
    pub fn take() -> bool {
        false
    }
}

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> anyhow::Result<Self> {
        let mut positional = Vec::new();
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                let val = argv
                    .get(i + 1)
                    .with_context(|| format!("--{key} needs a value"))?
                    .clone();
                flags.insert(key.to_string(), val);
                i += 2;
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Ok(Self { positional, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn num<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }
}

fn load_config(args: &Args) -> anyhow::Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(v) = args.num::<i16>("delta-th-q8")? {
        cfg.delta_th_q8 = v;
    }
    if let Some(v) = args.num::<usize>("channels")? {
        cfg.channels = v;
    }
    if let Some(v) = args.num::<u64>("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = args.num::<usize>("steps")? {
        cfg.train_steps = v;
    }
    if let Some(v) = args.num::<usize>("batch")? {
        cfg.batch = v;
    }
    if let Some(v) = args.num::<usize>("utterances")? {
        cfg.eval_utterances = v;
    }
    if let Some(v) = args.num::<usize>("workers")? {
        cfg.workers = v;
    }
    if let Some(v) = args.get("out") {
        cfg.weights = v.to_string();
    }
    if let Some(v) = args.get("weights") {
        cfg.weights = v.to_string();
    }
    if let Some(v) = args.get("artifacts") {
        cfg.artifacts = v.to_string();
    }
    Ok(cfg)
}

fn run() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_help();
        return Ok(());
    }
    let cmd = argv[0].as_str();
    let args = Args::parse(&argv[1..])?;
    let cfg = load_config(&args)?;
    // surface misconfiguration (channels/Δ_TH out of range) as a typed
    // error up front, before any subcommand trains or deploys on it
    cfg.chip_config_checked().context("invalid chip configuration")?;

    match cmd {
        "train" => cmd_train(&cfg),
        "eval" => cmd_eval(&cfg),
        "exp" => {
            let id = args.positional.first().map(String::as_str).unwrap_or("all");
            exp::run(id, &cfg)
        }
        "serve" => {
            let requests = args.num::<usize>("requests")?.unwrap_or(32);
            let metrics_out =
                args.get("metrics-out").unwrap_or("results/serve_metrics").to_string();
            let metrics_interval_s = args.num::<u64>("metrics-interval-s")?.unwrap_or(0);
            cmd_serve(&cfg, requests, &metrics_out, metrics_interval_s)
        }
        "enroll" => {
            let speaker = args.num::<u64>("speaker")?.unwrap_or(7);
            let target = args.num::<usize>("target")?.unwrap_or(11);
            let shots = args.num::<usize>("shots")?;
            let steps = args.num::<usize>("steps")?;
            cmd_enroll(&cfg, speaker, target, shots, steps)
        }
        "info" => cmd_info(&cfg),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' — try `deltakws help`"),
    }
}

fn cmd_train(cfg: &RunConfig) -> anyhow::Result<()> {
    let backend = runtime::backend_for(&cfg.artifacts)?;
    println!("execution backend: {}", backend.name());
    // train on exactly the channel selection the chip will deploy with
    let ds = Dataset::with_fex(cfg.seed, cfg.chip_config().fex.clone());
    let mut trainer = Trainer::new(backend, ds, cfg.batch, cfg.train_delta_th)?;
    let mut state = trainer.init_state(cfg.seed);
    println!(
        "training {} steps (batch {}, train Δ_TH {}) ...",
        cfg.train_steps, cfg.batch, cfg.train_delta_th
    );
    trainer.fit(&mut state, cfg.train_steps, true)?;
    for (split, name) in [(Split::Train, "train"), (Split::Test, "test")] {
        let (acc, sp) = trainer.evaluate(&state, split, 128, cfg.train_delta_th)?;
        println!("float {name} accuracy {:.1}%  (sparsity {:.1}%)", acc * 100.0, sp * 100.0);
    }
    let q = trainer.export(&state);
    let clip = deltakws::train::float_params_from_tensors(&state.params).quant_clip_fraction();
    println!("int8 quantisation clip fraction: {:.3}%", clip * 100.0);
    deltakws::train::save_weights(std::path::Path::new(&cfg.weights), &q)?;
    println!("weights saved to {}", cfg.weights);
    // loss curve dump
    let mut csv = String::from("step,loss\n");
    for l in &trainer.log {
        csv.push_str(&format!("{},{}\n", l.step, l.loss));
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/loss_curve.csv", csv)?;
    println!("loss curve -> results/loss_curve.csv");
    Ok(())
}

fn cmd_eval(cfg: &RunConfig) -> anyhow::Result<()> {
    let params = exp::ensure_weights(cfg)?;
    let chip_cfg = cfg.chip_config();
    let ds = Dataset::with_fex(cfg.seed, chip_cfg.fex.clone());
    let (acc12, acc11, rep) = exp::chip_accuracy(&params, &chip_cfg, &ds, cfg.eval_utterances);
    println!(
        "chip twin @ Δ_TH={:.3}, {} channels, {} test utterances:",
        cfg.delta_th_q8 as f64 / 256.0,
        cfg.channels,
        cfg.eval_utterances
    );
    println!("  accuracy       12-class {:.1}%   11-class {:.1}%", acc12 * 100.0, acc11 * 100.0);
    println!("  energy/decision {:.2} nJ", rep.energy_per_decision_nj);
    println!("  latency         {:.2} ms", rep.latency_ms);
    println!(
        "  sparsity        {:.1}% (x {:.1}%, h {:.1}%)",
        rep.sparsity * 100.0,
        rep.input_sparsity * 100.0,
        rep.hidden_sparsity * 100.0
    );
    println!("  power           {:.2} µW", rep.power.total_uw());
    Ok(())
}

/// Capture one metrics snapshot and write both expositions next to each
/// other: `<base>.json` and `<base>.prom`.
fn dump_metrics(coord: &coordinator::Coordinator, base: &str) -> anyhow::Result<()> {
    let snap = coord.metrics();
    if let Some(dir) = std::path::Path::new(base).parent() {
        std::fs::create_dir_all(dir).ok();
    }
    std::fs::write(format!("{base}.json"), format!("{}\n", snap.to_json()))?;
    std::fs::write(format!("{base}.prom"), snap.to_prometheus())?;
    println!(
        "metrics snapshot #{} -> {base}.json / {base}.prom  ({} decisions)",
        snap.seq, snap.stats.completed
    );
    Ok(())
}

fn cmd_serve(
    cfg: &RunConfig,
    requests: usize,
    metrics_out: &str,
    metrics_interval_s: u64,
) -> anyhow::Result<()> {
    let params = exp::ensure_weights(cfg)?;
    println!("starting coordinator with {} chip workers ...", cfg.workers);
    let coord = coordinator::Coordinator::builder(params, cfg.chip_config_checked()?)
        .workers(cfg.workers)
        .queue_depth(16)
        .build()
        .context("invalid serving configuration")?;
    sigusr1::install();
    println!("metrics: SIGUSR1 dumps to {metrics_out}.json/.prom (interval {metrics_interval_s}s; 0 = signal-only)");
    let ds = Dataset::new(cfg.seed);
    let t0 = std::time::Instant::now();
    let done = std::sync::atomic::AtomicBool::new(false);
    let (responses, submitted) = std::thread::scope(|s| {
        // watcher: polls the signal flag (and the optional interval clock)
        // while the workload runs; every trigger snapshots the live pool
        s.spawn(|| {
            let interval = std::time::Duration::from_secs(metrics_interval_s);
            let mut last = std::time::Instant::now();
            while !done.load(std::sync::atomic::Ordering::Relaxed) {
                std::thread::sleep(std::time::Duration::from_millis(200));
                let interval_due = metrics_interval_s > 0 && last.elapsed() >= interval;
                if sigusr1::take() || interval_due {
                    last = std::time::Instant::now();
                    if let Err(e) = dump_metrics(&coord, metrics_out) {
                        eprintln!("metrics dump failed: {e:#}");
                    }
                }
            }
        });
        // v2 surface: batch submission (lazy iterator — requests
        // materialise as they are accepted, blocking through
        // backpressure) and ticket-routed responses — no global collect
        let reqs = (0..requests).map(|i| {
            let utt = ds.utterance(Split::Test, i);
            coordinator::Request {
                id: 0,
                stream: (i % 8) as u64,
                audio12: utt.audio12,
                label: Some(utt.label),
                trace: false,
                weights: None,
            }
        });
        let r = coord
            .submit_batch(reqs)
            .context("worker pool died mid-submit")
            .map(|batch| {
                let submitted = batch.len();
                (batch.wait_all(std::time::Duration::from_secs(300)), submitted)
            });
        done.store(true, std::sync::atomic::Ordering::Relaxed);
        r
    })?;
    let wall = t0.elapsed();
    let stats = coord.stats();
    dump_metrics(&coord, metrics_out)?;
    println!(
        "served {}/{requests} requests ({submitted} submitted) in {:.2}s  ({:.1} utt/s)",
        responses.len(),
        wall.as_secs_f64(),
        responses.len() as f64 / wall.as_secs_f64()
    );
    println!(
        "online accuracy {:.1}%  p50 {:.1} ms  p99 {:.1} ms  rejected {} (backpressure) / {} (closed)",
        stats.accuracy() * 100.0,
        stats.p50_us() as f64 / 1e3,
        stats.p99_us() as f64 / 1e3,
        stats.rejected_full,
        stats.rejected_closed
    );
    println!(
        "simulated chip: {:.1}% sparsity over {} frames",
        stats.activity.sparsity() * 100.0,
        stats.activity.frames
    );
    Ok(())
}

/// Few-shot per-user enrollment against the configured base weights:
/// registers the fine-tuned FC head as a new version in the pool's
/// registry and reports the held-out effect on the synthetic speaker.
fn cmd_enroll(
    cfg: &RunConfig,
    speaker: u64,
    target: usize,
    shots: Option<usize>,
    steps: Option<usize>,
) -> anyhow::Result<()> {
    let params = exp::ensure_weights(cfg)?;
    let chip_cfg = cfg.chip_config_checked()?;
    let coord = coordinator::Coordinator::builder(params, chip_cfg.clone())
        .workers(cfg.workers.max(1))
        .build()
        .context("invalid serving configuration")?;
    let mut ecfg = deltakws::custom::EnrollConfig::design_point(speaker, target);
    if let Some(v) = shots {
        ecfg.shots = v;
    }
    if let Some(v) = steps {
        ecfg.steps = v;
    }
    println!(
        "enrolling speaker {speaker} on '{}' ({} shots + {} counters, {} steps) ...",
        deltakws::CLASS_LABELS[target],
        ecfg.shots,
        ecfg.counter_shots,
        ecfg.steps
    );
    let out = coord.enroll(None, ecfg)?;
    println!("  version    {}  (parent {})", out.version, out.parent);
    println!(
        "  trained    {} steps in {:.1} ms  (final loss {:.4})",
        out.steps,
        out.latency_us as f64 / 1e3,
        out.final_loss
    );
    // held-out effect: chip-twin accuracy on the speaker's unseen clips
    let voice = deltakws::custom::SpeakerVoice::new(speaker);
    let held = voice.holdout(target, 12);
    let acc = |p: &deltakws::accel::gru::QuantParams| {
        let mut chip = KwsChip::new(p.clone(), chip_cfg.clone());
        held.iter().filter(|u| chip.process_utterance(&u.audio12).class == target).count()
    };
    let base = coord.registry().get(coord.base_version())?;
    let enrolled = coord.registry().get(out.version)?;
    println!(
        "  held-out   '{}' {}/{} base -> {}/{} enrolled",
        deltakws::CLASS_LABELS[target],
        acc(&base),
        held.len(),
        acc(&enrolled),
        held.len()
    );
    println!(
        "  registry   {} resident versions (lineage: {:?})",
        coord.registry().resident_count(),
        coord.registry().lineage(out.version)
    );
    Ok(())
}

fn cmd_info(cfg: &RunConfig) -> anyhow::Result<()> {
    println!("DeltaKWS digital twin — paper DOI 10.1109/TCASAI.2024.3507694");
    let a = deltakws::energy::AreaBreakdown::chip();
    println!(
        "chip area model: FEx {:.3} + ΔRNN {:.3} + SRAM {:.3} = {:.3} mm² (paper 0.78)",
        a.fex_mm2,
        a.rnn_mm2,
        a.sram_mm2,
        a.total_mm2()
    );
    println!(
        "design point: Δ_TH = {:.3}, {} channels",
        cfg.delta_th_q8 as f64 / 256.0,
        cfg.channels
    );
    match runtime::backend_for(&cfg.artifacts) {
        Ok(backend) => {
            let m = backend.manifest();
            println!("execution backend: {}", backend.name());
            println!(
                "model: {} frames x {} ch -> GRU-{} -> {} classes (batch {})",
                m.frames, m.channels, m.hidden, m.classes, m.batch
            );
        }
        Err(e) => println!("backend: unavailable ({e})"),
    }
    // quick single-utterance demo if weights exist
    if std::path::Path::new(&cfg.weights).exists() {
        let params = deltakws::train::load_weights(std::path::Path::new(&cfg.weights))?;
        let mut chip = KwsChip::new(params, cfg.chip_config());
        let ds = Dataset::new(cfg.seed);
        let utt = ds.utterance(Split::Test, 0);
        let d = chip.process_utterance(&utt.audio12);
        println!(
            "demo: test[0] label '{}' -> predicted '{}'",
            deltakws::CLASS_LABELS[utt.label],
            deltakws::CLASS_LABELS[d.class]
        );
    }
    Ok(())
}

fn print_help() {
    println!(
        "deltakws — DeltaKWS temporal-sparsity KWS system (TCAS-AI 2024 reproduction)

USAGE: deltakws <command> [flags]

COMMANDS:
  train     train the ΔGRU (native backend; PJRT artifacts with --features pjrt)
  eval      evaluate the chip twin on synthetic-GSCD test utterances
  exp       regenerate paper experiments: fig6 fig7 fig10 fig11 fig12 fig13
            table1 table2 ablation all
  serve     run the streaming coordinator demo
  enroll    few-shot per-user enrollment (FC head only) into the registry
  info      print system/model/area info

FLAGS (all commands):
  --config path.toml    load a run config (see configs/)
  --seed N --channels N --delta-th-q8 N --utterances N
  --steps N --batch N --out FILE --weights FILE --workers N --artifacts DIR"
    );
}

//! Energy-based voice-activity detection over FEx feature frames.
//!
//! The paper's FEx runs every sample regardless of content (the serial IIR
//! pipeline is the chip's cheapest block); the expensive parts — ΔRNN MACs
//! and weight-SRAM reads — are what the Δ-threshold already gates *within*
//! speech. The VAD extends that story to the always-on limit: between
//! utterances it clock-gates the ΔRNN entirely, so idle time costs only
//! FEx + leakage (the energy model sees the gated frames through
//! [`crate::energy::ChipActivity::gated_frames`]).
//!
//! Mechanism: frame energy = sum of the 12-bit log-compressed features.
//! An adaptive noise floor tracks the minimum (instant down, slow up via a
//! `floor_shift` EMA); the gate opens when energy rises `margin` above the
//! floor for `attack_frames` consecutive frames and stays open for
//! `hangover_frames` after energy drops (so word tails and short pauses
//! don't chop a keyword). Integer-only arithmetic, deterministic.

use crate::fex::FeatureFrame;

/// VAD tuning.
#[derive(Debug, Clone)]
pub struct VadConfig {
    /// master switch: `false` = gate always open (ΔRNN never gated)
    pub enabled: bool,
    /// energy rise above the adaptive noise floor that counts as speech
    /// (summed 12-bit features over the active channels)
    pub margin: i64,
    /// consecutive speech frames required to open the gate
    pub attack_frames: u32,
    /// frames the gate stays open after energy falls back to the floor
    pub hangover_frames: u32,
    /// noise-floor EMA shift: floor += (energy - floor) >> floor_shift
    /// when energy is above the floor (larger = slower creep)
    pub floor_shift: u32,
}

impl VadConfig {
    /// Design point: open within one 16 ms frame, hold ~200 ms, floor time
    /// constant ~2 s.
    pub fn design_point() -> Self {
        Self { enabled: true, margin: 3000, attack_frames: 1, hangover_frames: 12, floor_shift: 7 }
    }

    /// Gate permanently open (for A/B energy comparisons and batch-equiv
    /// tests).
    pub fn disabled() -> Self {
        Self { enabled: false, ..Self::design_point() }
    }
}

/// Extra floor-EMA shift while the gate is open (8x slower adaptation):
/// large enough that speech never closes its own gate, small enough that
/// a stationary noise step re-arms gating in tens of seconds.
const OPEN_FLOOR_PENALTY: u32 = 3;

/// The VAD gate.
#[derive(Debug, Clone)]
pub struct Vad {
    pub config: VadConfig,
    /// adaptive noise floor (negative = unset)
    floor: i64,
    above: u32,
    hang: u32,
    active: bool,
    /// telemetry
    pub frames_active: u64,
    pub frames_idle: u64,
}

impl Vad {
    pub fn new(config: VadConfig) -> Self {
        Self { config, floor: -1, above: 0, hang: 0, active: false, frames_active: 0, frames_idle: 0 }
    }

    /// Frame energy: summed 12-bit features (inactive slots read 0).
    pub fn energy(feat: &FeatureFrame) -> i64 {
        feat.iter().sum()
    }

    /// Advance one frame; returns whether the ΔRNN gate is open.
    pub fn step(&mut self, feat: &FeatureFrame) -> bool {
        if !self.config.enabled {
            self.frames_active += 1;
            return true;
        }
        let e = Self::energy(feat);
        if self.floor < 0 || e < self.floor {
            self.floor = e; // instant floor drop
        } else {
            // asymmetric adaptation: fast-ish creep while the gate is
            // closed, much slower while it is open — a keyword-length
            // utterance cannot drag the floor to speech level and cut
            // itself off, but a *sustained* ambient step (a fan turning
            // on) still re-arms gating within ~30 s instead of pinning
            // the ΔRNN duty cycle at 100% forever
            let shift = if self.active {
                self.config.floor_shift + OPEN_FLOOR_PENALTY
            } else {
                self.config.floor_shift
            };
            self.floor += (e - self.floor) >> shift;
        }
        let speech = e - self.floor >= self.config.margin;
        if speech {
            self.above += 1;
            if self.above >= self.config.attack_frames {
                self.active = true;
                self.hang = self.config.hangover_frames;
            }
        } else {
            self.above = 0;
            if self.active {
                if self.hang > 0 {
                    self.hang -= 1;
                } else {
                    self.active = false;
                }
            }
        }
        if self.active {
            self.frames_active += 1;
        } else {
            self.frames_idle += 1;
        }
        self.active
    }

    /// Restore power-on state (keeps config, clears telemetry).
    ///
    /// Note: the authoritative ΔRNN duty cycle lives in
    /// [`crate::energy::ChipActivity::duty_cycle`] (gated-frame counts);
    /// `frames_active`/`frames_idle` here are the VAD's own gate
    /// telemetry for standalone use.
    pub fn reset(&mut self) {
        self.floor = -1;
        self.above = 0;
        self.hang = 0;
        self.active = false;
        self.frames_active = 0;
        self.frames_idle = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fex::MAX_CHANNELS;

    fn frame(per_channel: i64) -> FeatureFrame {
        let mut f = [0i64; MAX_CHANNELS];
        for v in f.iter_mut().take(14).skip(4) {
            *v = per_channel;
        }
        f
    }

    #[test]
    fn opens_on_energy_rise_and_holds_hangover() {
        let mut vad = Vad::new(VadConfig::design_point());
        // settle the floor on quiet frames
        for _ in 0..10 {
            assert!(!vad.step(&frame(100)));
        }
        // loud burst opens the gate on the first frame (attack 1)
        assert!(vad.step(&frame(2000)));
        // back to quiet: stays open for hangover frames, then closes
        let hang = vad.config.hangover_frames;
        for i in 0..hang {
            assert!(vad.step(&frame(100)), "closed early at hangover frame {i}");
        }
        assert!(!vad.step(&frame(100)), "hangover did not expire");
    }

    #[test]
    fn adapts_to_noise_floor_level() {
        // a *constant* high floor must not read as speech
        let mut vad = Vad::new(VadConfig::design_point());
        assert!(!vad.step(&frame(2500)), "first frame sets the floor");
        for _ in 0..20 {
            assert!(!vad.step(&frame(2500)), "steady state misread as speech");
        }
        // but a rise above that floor does
        assert!(vad.step(&frame(3000)));
    }

    #[test]
    fn disabled_vad_never_gates() {
        let mut vad = Vad::new(VadConfig::disabled());
        for _ in 0..5 {
            assert!(vad.step(&frame(0)));
        }
        assert_eq!(vad.frames_idle, 0);
        assert_eq!(vad.frames_active, 5);
    }

    #[test]
    fn attack_requires_consecutive_frames() {
        let mut cfg = VadConfig::design_point();
        cfg.attack_frames = 3;
        let mut vad = Vad::new(cfg);
        for _ in 0..5 {
            vad.step(&frame(100));
        }
        assert!(!vad.step(&frame(2000)), "one frame must not open at attack 3");
        assert!(!vad.step(&frame(2000)));
        assert!(vad.step(&frame(2000)), "third consecutive frame opens");
    }

    #[test]
    fn floor_adapts_slowly_open_fast_closed() {
        let mut vad = Vad::new(VadConfig::design_point());
        for _ in 0..10 {
            vad.step(&frame(100)); // learn a quiet floor
        }
        // a multi-second utterance must stay gated open throughout (the
        // floor creeps only at the slow open-gate rate) ...
        for i in 0..300 {
            assert!(vad.step(&frame(2000)), "gate closed mid-utterance at frame {i}");
        }
        // ... but a *sustained* ambient step (fan turns on and stays on)
        // must eventually re-arm gating instead of pinning the gate open
        let mut closed = false;
        for _ in 0..4_000 {
            if !vad.step(&frame(2000)) {
                closed = true;
                break;
            }
        }
        assert!(closed, "gate never re-armed after a stationary noise step");
    }

    #[test]
    fn reset_restores_power_on() {
        let mut vad = Vad::new(VadConfig::design_point());
        vad.step(&frame(100));
        vad.step(&frame(4000));
        vad.reset();
        assert_eq!(vad.frames_active + vad.frames_idle, 0);
        assert!(!vad.step(&frame(4000)), "floor must be re-learnt after reset");
    }
}

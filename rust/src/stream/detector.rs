//! Wakeword detection state machine: sliding-window posterior smoothing +
//! hysteresis + refractory debounce over the chip's per-frame logits.
//!
//! ```text
//!            window full, top is a keyword,
//!            margin >= margin_q            run == on_frames
//!   ┌──────┐ ───────────────────────► ┌────────┐ ───────► ┌────────────┐
//!   │ IDLE │                          │ ARMING │  emit    │ REFRACTORY │
//!   └──────┘ ◄─────────────────────── └────────┘          └────────────┘
//!      ▲       margin lost / class flip    │                    │
//!      │       (run restarts on flip)      │                    │
//!      └───────────────────────────────────┴──── refractory over┘
//!              VAD-gated frame: flush window + run from any state
//! ```
//!
//! Smoothing uses *summed* logits over a full `window` frames (no division
//! — exact integer arithmetic, mirrored by `tools/gen_goldens.py` as a
//! golden regression vector). A detection is emitted when the same keyword
//! class holds the smoothed top spot with margin `margin_q` over the
//! runner-up for `on_frames` consecutive frames; the machine then sleeps
//! `refractory_frames` (debounce) with the window flushed, so one keyword
//! occurrence produces one event.

use std::collections::VecDeque;

use crate::NUM_CLASSES;

/// First class index that counts as a wakeword (0 = silence, 1 = unknown).
pub const FIRST_KEYWORD_CLASS: usize = 2;

/// Detector tuning.
#[derive(Debug, Clone)]
pub struct DetectorConfig {
    /// posterior smoothing window (frames); detection requires a full one
    pub window: usize,
    /// required margin between the top keyword and the runner-up, on
    /// *summed* logits over the window (logit value fraction x window)
    pub margin_q: i64,
    /// consecutive qualifying frames to confirm (hysteresis)
    pub on_frames: u32,
    /// dead frames after an emission (debounce)
    pub refractory_frames: u32,
}

impl DetectorConfig {
    /// Design point: 8-frame (128 ms) smoothing, 3-frame confirm, 480 ms
    /// refractory. `margin_q` is 2.0 in posterior units per averaged frame
    /// (logit fraction 14 → 2.0 * 2^14 * window).
    pub fn design_point() -> Self {
        Self { window: 8, margin_q: 2 * (1 << 14) * 8, on_frames: 3, refractory_frames: 30 }
    }
}

/// One emitted wakeword detection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DetectionEvent {
    /// detected keyword class (always >= [`FIRST_KEYWORD_CLASS`])
    pub class: usize,
    /// frame index at which the detection was confirmed
    pub frame: u64,
    /// frame index where the confirming run began (onset estimate)
    pub onset_frame: u64,
    /// smoothed margin (summed logits) at confirmation
    pub margin: i64,
}

impl DetectionEvent {
    /// End-of-frame sample index of the confirming frame.
    pub fn sample(&self) -> u64 {
        (self.frame + 1) * crate::FRAME_SAMPLES as u64
    }

    /// Wall-clock time of the confirmation (ms into the stream).
    pub fn time_ms(&self) -> f64 {
        (self.frame + 1) as f64 * crate::FRAME_SHIFT_MS as f64
    }
}

/// The detection state machine.
#[derive(Debug, Clone)]
pub struct Detector {
    pub config: DetectorConfig,
    window: VecDeque<[i64; NUM_CLASSES]>,
    sums: [i64; NUM_CLASSES],
    /// arming candidate (NUM_CLASSES = none)
    run_class: usize,
    run_len: u32,
    run_start: u64,
    refractory: u32,
    /// total events emitted (telemetry)
    pub emitted: u64,
}

impl Detector {
    pub fn new(config: DetectorConfig) -> Self {
        // zero window/on_frames are config bugs: assert in debug, clamp
        // to the minimum viable detector in release (frame-path
        // constructors must not abort the twin)
        debug_assert!(config.window > 0 && config.on_frames > 0);
        let mut config = config;
        config.window = config.window.max(1);
        config.on_frames = config.on_frames.max(1);
        // lint:allow(no-alloc-hot-path): construction-time window buffer; len stays within window + 1 = capacity
        let window = VecDeque::with_capacity(config.window + 1);
        Self {
            config,
            window,
            sums: [0; NUM_CLASSES],
            run_class: NUM_CLASSES,
            run_len: 0,
            run_start: 0,
            refractory: 0,
            emitted: 0,
        }
    }

    fn flush_window(&mut self) {
        self.window.clear();
        self.sums = [0; NUM_CLASSES];
    }

    fn disarm(&mut self) {
        self.run_class = NUM_CLASSES;
        self.run_len = 0;
    }

    /// Advance one frame. `gated` marks a VAD-idle frame (logits invalid):
    /// the smoothing window and any arming run are flushed, while the
    /// refractory countdown still elapses.
    pub fn step(
        &mut self,
        index: u64,
        logits: &[i64; NUM_CLASSES],
        gated: bool,
    ) -> Option<DetectionEvent> {
        if gated {
            self.flush_window();
            self.disarm();
            if self.refractory > 0 {
                self.refractory -= 1;
            }
            return None;
        }
        // slide the window
        // lint:allow(no-alloc-hot-path): bounded — pop_front below keeps len within window + 1, the construction capacity; never reallocates
        self.window.push_back(*logits);
        for (s, l) in self.sums.iter_mut().zip(logits.iter()) {
            *s += l;
        }
        if self.window.len() > self.config.window {
            if let Some(old) = self.window.pop_front() {
                for (s, l) in self.sums.iter_mut().zip(old.iter()) {
                    *s -= l;
                }
            } else {
                // unreachable: len > window ≥ 1 implies non-empty
                debug_assert!(false, "window non-empty");
            }
        }
        if self.refractory > 0 {
            self.refractory -= 1;
            self.disarm();
            return None;
        }
        if self.window.len() < self.config.window {
            return None;
        }
        // smoothed top class (first maximum) and runner-up
        let mut best = 0usize;
        for (k, &v) in self.sums.iter().enumerate().skip(1) {
            if v > self.sums[best] {
                best = k;
            }
        }
        let mut second = i64::MIN;
        for (k, &v) in self.sums.iter().enumerate() {
            if k != best && v > second {
                second = v;
            }
        }
        let margin = self.sums[best] - second;
        if best < FIRST_KEYWORD_CLASS || margin < self.config.margin_q {
            self.disarm();
            return None;
        }
        if best == self.run_class {
            self.run_len += 1;
        } else {
            self.run_class = best;
            self.run_len = 1;
            self.run_start = index;
        }
        if self.run_len < self.config.on_frames {
            return None;
        }
        // confirmed: emit, flush, debounce
        let ev = DetectionEvent { class: best, frame: index, onset_frame: self.run_start, margin };
        self.refractory = self.config.refractory_frames;
        self.disarm();
        self.flush_window();
        self.emitted += 1;
        Some(ev)
    }

    /// Restore power-on state (keeps config, clears telemetry).
    pub fn reset(&mut self) {
        self.flush_window();
        self.disarm();
        self.refractory = 0;
        self.emitted = 0;
    }

    /// Heap footprint of the smoothing window — fixed at construction
    /// (`window + 1` slots), independent of how many frames have been
    /// stepped. Folded into
    /// [`StreamPipeline::state_bytes`](crate::stream::StreamPipeline::state_bytes).
    pub fn window_bytes(&self) -> usize {
        self.window.capacity() * std::mem::size_of::<[i64; NUM_CLASSES]>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DetectorConfig {
        DetectorConfig { window: 4, margin_q: 1000, on_frames: 2, refractory_frames: 6 }
    }

    fn logits(class: usize, strength: i64) -> [i64; NUM_CLASSES] {
        let mut l = [0i64; NUM_CLASSES];
        l[class] = strength;
        l
    }

    #[test]
    fn detects_once_then_debounces() {
        let mut det = Detector::new(cfg());
        let mut events = Vec::new();
        for t in 0..12u64 {
            if let Some(e) = det.step(t, &logits(5, 5000), false) {
                events.push(e);
            }
        }
        // window full at t=3, run 1 at t=3, confirmed at t=4; refractory 6
        // blankets t=5..10; window refilled by t=10... second emit later
        assert!(!events.is_empty(), "no detection");
        assert_eq!(events[0].class, 5);
        assert_eq!(events[0].frame, 4);
        assert_eq!(events[0].onset_frame, 3);
        // debounce: no second event within refractory + window refill
        assert!(events.len() <= 2, "debounce failed: {events:?}");
        if events.len() == 2 {
            assert!(events[1].frame >= events[0].frame + 6 + 4);
        }
    }

    #[test]
    fn silence_and_unknown_never_fire() {
        let mut det = Detector::new(cfg());
        for t in 0..20u64 {
            assert!(det.step(t, &logits(0, 9000), false).is_none(), "silence fired");
        }
        det.reset();
        for t in 0..20u64 {
            assert!(det.step(t, &logits(1, 9000), false).is_none(), "unknown fired");
        }
    }

    #[test]
    fn margin_hysteresis_blocks_ambiguous_frames() {
        let mut det = Detector::new(cfg());
        // two classes neck-and-neck: margin stays below margin_q
        let mut l = [0i64; NUM_CLASSES];
        l[4] = 5000;
        l[7] = 4900; // summed margin over 4 frames = 400 < 1000
        for t in 0..20u64 {
            assert!(det.step(t, &l, false).is_none(), "ambiguous frames fired");
        }
    }

    #[test]
    fn class_flip_restarts_the_run() {
        let mut c = cfg();
        c.on_frames = 3;
        let mut det = Detector::new(c);
        // fill window with class 4 (2 qualifying frames), then flip to 9
        for t in 0..5u64 {
            assert!(det.step(t, &logits(4, 5000), false).is_none());
        }
        // flood with class 9: window still mixed, margin favours 9 only
        // once it dominates the sums; run must restart from the flip
        let mut fired_at = None;
        for t in 5..20u64 {
            if let Some(e) = det.step(t, &logits(9, 50_000), false) {
                fired_at = Some((t, e));
                break;
            }
        }
        let (t, e) = fired_at.expect("flip never fired");
        assert_eq!(e.class, 9);
        assert!(e.onset_frame >= 5, "run leaked across the class flip");
        assert!(t >= 7, "on_frames not honoured after flip: t={t}");
    }

    #[test]
    fn gated_frames_flush_the_window() {
        let mut det = Detector::new(cfg());
        det.step(0, &logits(5, 5000), false);
        det.step(1, &logits(5, 5000), false);
        det.step(2, &logits(5, 5000), false);
        // VAD closes: window flushed, so the pending near-detection dies
        assert!(det.step(3, &logits(5, 5000), true).is_none());
        // needs a full window + on_frames again from scratch
        assert!(det.step(4, &logits(5, 5000), false).is_none());
        assert!(det.step(5, &logits(5, 5000), false).is_none());
        assert!(det.step(6, &logits(5, 5000), false).is_none());
        assert!(det.step(7, &logits(5, 5000), false).is_none(), "window not flushed");
        assert!(det.step(8, &logits(5, 5000), false).is_some());
    }

    #[test]
    fn deterministic() {
        let run = || {
            let mut det = Detector::new(DetectorConfig::design_point());
            let mut out = Vec::new();
            for t in 0..200u64 {
                let mut l = [0i64; NUM_CLASSES];
                l[(t % 12) as usize] = (t as i64 * 9973) % 40_000;
                l[6] = if (40..80).contains(&t) { 300_000 } else { 0 };
                if let Some(e) = det.step(t, &l, t % 17 == 0) {
                    out.push(e);
                }
            }
            out
        };
        assert_eq!(run(), run());
    }
}

//! Always-on streaming detection: the continuous-inference subsystem.
//!
//! The chip twin's batch API answers "which keyword is in this 1 s clip?";
//! this module answers the question the silicon was actually built for:
//! "wake up when a keyword occurs in an endless audio stream, and spend
//! (almost) nothing the rest of the time". It layers, over the
//! frame-incremental [`crate::chip::KwsChip`] API:
//!
//! * [`vad`] — an energy-based voice-activity gate that clock-gates the
//!   ΔRNN between utterances (idle frames reach the energy model through
//!   [`crate::energy::ChipActivity::gated_frames`]);
//! * [`detector`] — sliding-window posterior smoothing + a
//!   hysteresis/refractory wakeword state machine emitting
//!   [`detector::DetectionEvent`]s with onset estimates;
//! * [`metrics`] — miss rate, false-accepts/hour and detection latency
//!   against a ground-truth [`crate::audio::track`] schedule.
//!
//! [`StreamPipeline`] is the single-stream composition (one microphone →
//! one chip); [`crate::coordinator::StreamSession`] hosts many of these on
//! the worker pool (pushes there surface typed
//! [`crate::StreamPushError`]s that hand the chunk back). Pools apply a
//! default [`StreamConfig`] to sessions opened without one — a
//! [`crate::coordinator::CoordinatorBuilder::default_stream`] knob.

pub mod detector;
pub mod metrics;
pub mod vad;

use std::sync::Arc;

use crate::accel::gru::QuantParams;
use crate::chip::{ChipConfig, ChipReport, KwsChip};
use crate::energy::ChipActivity;
use crate::error::StreamPushError;
use crate::probe::{ChipProbe, NoProbe};
use detector::{Detector, DetectorConfig, DetectionEvent};
use vad::{Vad, VadConfig};

/// Full streaming-pipeline configuration.
#[derive(Debug, Clone)]
pub struct StreamConfig {
    pub chip: ChipConfig,
    pub vad: VadConfig,
    pub detector: DetectorConfig,
}

impl StreamConfig {
    /// Paper design-point chip + default VAD/detector tuning.
    pub fn design_point() -> Self {
        Self::for_chip(ChipConfig::design_point())
    }

    /// Default VAD/detector tuning over an explicit chip configuration
    /// (pair with [`ChipConfig::builder`](crate::chip::ChipConfig::builder)
    /// for a validated chip).
    pub fn for_chip(chip: ChipConfig) -> Self {
        Self { chip, vad: VadConfig::design_point(), detector: DetectorConfig::design_point() }
    }

    pub fn with_vad(mut self, vad: VadConfig) -> Self {
        self.vad = vad;
        self
    }

    pub fn with_detector(mut self, detector: DetectorConfig) -> Self {
        self.detector = detector;
        self
    }
}

/// One always-on detection pipeline: chip twin + VAD gate + wakeword
/// state machine. Push arbitrary audio chunks, get detection events out;
/// all state persists across calls until [`reset`](Self::reset).
pub struct StreamPipeline {
    pub chip: KwsChip,
    pub vad: Vad,
    pub detector: Detector,
    /// samples consumed since construction/reset
    pub samples_in: u64,
    /// chip activity already handed out via [`take_activity_delta`]
    /// (telemetry shards flush increments; chip counters never reset)
    flushed: ChipActivity,
}

impl StreamPipeline {
    pub fn new(params: QuantParams, config: StreamConfig) -> Self {
        let image = crate::sram::shared_image(&crate::accel::gru::to_sram_image(&params));
        Self::new_shared(Arc::new(params), image, config)
    }

    /// Build against a shared weight table + SRAM image (see
    /// [`KwsChip::new_shared`]): the per-session weight cost is two
    /// pointers, which is what lets a pool park tens of thousands of
    /// idle sessions on the same model without multiplying its memory.
    pub fn new_shared(
        params: Arc<QuantParams>,
        image: Arc<Vec<u16>>,
        config: StreamConfig,
    ) -> Self {
        let StreamConfig { chip, vad, detector } = config;
        Self {
            chip: KwsChip::new_shared(params, image, chip),
            vad: Vad::new(vad),
            detector: Detector::new(detector),
            samples_in: 0,
            flushed: ChipActivity::default(),
        }
    }

    /// Feed a chunk of 12-bit samples; runs every completed frame through
    /// VAD → (poll | skip) → detector and returns the detections this
    /// chunk produced. Chunk sizes up to the chip's staging capacity
    /// ([`crate::chip::PENDING_FRAME_CAP`] frames ≈ 4 s) are arbitrary —
    /// frame boundaries are handled internally and results are invariant
    /// to the chunking.
    ///
    /// A chunk too large for the frame buffer is handed back inside
    /// [`StreamPushError::Backpressure`] with nothing consumed (the
    /// surfaced form of the chip's typed
    /// [`ChipError::FifoOverflow`](crate::error::ChipError::FifoOverflow)
    /// — the old code path panicked instead): split it and push the
    /// pieces. The coordinator's worker does exactly that, so a hostile
    /// chunk can no longer kill a worker thread.
    pub fn push_audio(&mut self, audio12: &[i64]) -> Result<Vec<DetectionEvent>, StreamPushError> {
        self.push_audio_probed(audio12, &mut NoProbe)
    }

    /// [`push_audio`](Self::push_audio) with an instrumentation probe
    /// observing every consumed frame (polled *and* VAD-skipped). The
    /// probe is generic, so `NoProbe` monomorphizes back to the lean
    /// path — `push_audio` above is exactly that instantiation. The
    /// coordinator's flight recorder rides this seam with a
    /// [`RecorderProbe`](crate::obs::RecorderProbe) when enabled.
    pub fn push_audio_probed<P: ChipProbe>(
        &mut self,
        audio12: &[i64],
        probe: &mut P,
    ) -> Result<Vec<DetectionEvent>, StreamPushError> {
        if self.chip.push_samples(audio12).is_err() {
            // the pipeline drains every frame below, so only an oversized
            // single chunk can trip the bound — hand it back intact. The
            // clone is deliberate (and cold): it keeps the error uniform
            // with the session-push contract, where the payload rides the
            // error so a retry needs no second copy of the audio.
            // lint:allow(no-alloc-hot-path): cold rejection path — the payload rides the typed error, by contract
            return Err(StreamPushError::Backpressure(audio12.to_vec()));
        }
        self.samples_in += audio12.len() as u64;
        // lint:allow(no-alloc-hot-path): Vec::new allocates nothing; stays empty until a detection fires
        let mut events = Vec::new();
        while let Some(&feat) = self.chip.peek_frame() {
            let open = self.vad.step(&feat);
            let polled = if open {
                self.chip.poll_frame_probed(probe)
            } else {
                self.chip.skip_frame_probed(probe)
            };
            let Some(out) = polled else {
                // unreachable: peek_frame just returned Some. Stop the
                // drain in release rather than abort the stream.
                debug_assert!(false, "peeked frame must be consumable");
                break;
            };
            if let Some(ev) = self.detector.step(out.index, &out.logits, out.gated) {
                // lint:allow(no-alloc-hot-path): allocation only on the rare wakeword edge, not per frame
                events.push(ev);
            }
        }
        Ok(events)
    }

    /// Bounded per-session state: the heap the pipeline can ever hold,
    /// independent of how much audio has flowed through it (frame staging
    /// buffer + detector smoothing window; the VAD is O(1) scalars). The
    /// soak harness asserts this stays flat on long-lived sessions.
    pub fn state_bytes(&self) -> usize {
        self.chip.pending_bytes() + self.detector.window_bytes()
    }

    /// Epoch-fenced weight hot-swap: install a new weight version on the
    /// live pipeline without dropping a frame. [`push_audio`] drains every
    /// completed frame before returning, so between pushes the chip sits
    /// exactly at a frame boundary — this call is therefore always a
    /// clean fence (old weights drove every polled frame, new weights
    /// drive every following one). VAD and detector state persist: a
    /// detection straddling the fence still resolves.
    pub fn swap_weights(&mut self, params: QuantParams) {
        self.chip.swap_weights(params);
    }

    /// Shared-table variant of [`swap_weights`](Self::swap_weights):
    /// the same frame-boundary fence, installing the version's shared
    /// parameter table and SRAM image by pointer.
    pub fn swap_weights_shared(&mut self, params: Arc<QuantParams>, image: Arc<Vec<u16>>) {
        self.chip.swap_weights_shared(params, image);
    }

    /// Restore power-on state (keeps weights/config; telemetry counters on
    /// the chip keep aggregating, VAD/detector telemetry clears).
    pub fn reset(&mut self) {
        self.chip.reset();
        self.vad.reset();
        self.detector.reset();
        self.samples_in = 0;
    }

    /// Chip metrics over everything processed so far.
    pub fn report(&self) -> ChipReport {
        self.chip.report()
    }

    /// Chip activity accumulated since the last call: the telemetry-shard
    /// flush unit ([`crate::coordinator::telemetry::WorkerShard`] adds
    /// these monotonic deltas with relaxed atomics instead of re-merging
    /// the chip's lifetime counters or resetting them).
    pub fn take_activity_delta(&mut self) -> ChipActivity {
        let act = self.chip.activity();
        let delta = act.delta_since(&self.flushed);
        self.flushed = act;
        delta
    }

    /// Fraction of frames the ΔRNN actually clocked (VAD duty cycle).
    pub fn duty_cycle(&self) -> f64 {
        self.chip.activity().duty_cycle()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::audio::track::{synth_track, TrackConfig};
    use crate::util::prng::Pcg;

    fn rng_quant(seed: u64) -> QuantParams {
        let mut rng = Pcg::new(seed);
        let mut q = QuantParams::zeroed();
        q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
        q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
        q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
        q
    }

    #[test]
    fn pipeline_consumes_all_frames_regardless_of_chunking() {
        let cfg = TrackConfig { duration_s: 4, keywords: 2, fillers: 0, noise: (0.001, 0.002) };
        let (audio12, _) = synth_track(&cfg, 11);
        for chunk in [64usize, 128, 1000] {
            let mut p = StreamPipeline::new(rng_quant(1), StreamConfig::design_point());
            for c in audio12.chunks(chunk) {
                p.push_audio(c).expect("chunk fits");
            }
            let a = p.chip.activity();
            assert_eq!(a.frames, (audio12.len() / 128) as u64, "chunk {chunk}");
            assert_eq!(p.chip.pending_frames(), 0);
        }
    }

    #[test]
    fn vad_gates_silence_and_passes_speech() {
        let cfg = TrackConfig { duration_s: 6, keywords: 2, fillers: 0, noise: (0.001, 0.002) };
        let (audio12, sched) = synth_track(&cfg, 3);
        let mut p = StreamPipeline::new(rng_quant(2), StreamConfig::design_point());
        for c in audio12.chunks(256) {
            p.push_audio(c).expect("chunk fits");
        }
        let a = p.chip.activity();
        assert!(a.gated_frames > 0, "VAD never gated on a mostly-silent track");
        assert!(
            a.gated_frames < a.frames,
            "VAD gated everything including {} keywords",
            sched.len()
        );
        let duty = p.duty_cycle();
        assert!(duty > 0.05 && duty < 0.95, "implausible duty cycle {duty}");
    }

    #[test]
    fn disabled_vad_runs_every_frame() {
        let cfg = TrackConfig { duration_s: 2, keywords: 1, fillers: 0, noise: (0.001, 0.002) };
        let (audio12, _) = synth_track(&cfg, 5);
        let sc = StreamConfig::design_point().with_vad(VadConfig::disabled());
        let mut p = StreamPipeline::new(rng_quant(3), sc);
        for c in audio12.chunks(512) {
            p.push_audio(c).expect("chunk fits");
        }
        assert_eq!(p.chip.activity().gated_frames, 0);
        assert!((p.duty_cycle() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn activity_delta_flushes_each_increment_exactly_once() {
        let mut p = StreamPipeline::new(rng_quant(9), StreamConfig::design_point());
        p.push_audio(&[0i64; 1280]).expect("chunk fits");
        let d1 = p.take_activity_delta();
        assert_eq!(d1.frames, 10);
        let d2 = p.take_activity_delta();
        assert_eq!(d2.frames, 0, "same delta handed out twice");
        p.push_audio(&[0i64; 640]).expect("chunk fits");
        let d3 = p.take_activity_delta();
        assert_eq!(d3.frames, 5);
        let mut total = d1;
        total.merge(&d2);
        total.merge(&d3);
        assert_eq!(total.frames, p.chip.activity().frames);
        assert_eq!(total.fex_visits, p.chip.activity().fex_visits);
    }

    #[test]
    fn oversized_chunk_surfaces_backpressure_with_nothing_consumed() {
        let mut p = StreamPipeline::new(rng_quant(8), StreamConfig::design_point());
        // > PENDING_FRAME_CAP frames in one chunk: typed Backpressure, the
        // chunk handed back intact, no sample consumed (the old path
        // panicked inside the worker thread here)
        let monster = vec![0i64; (crate::chip::PENDING_FRAME_CAP + 1) * crate::FRAME_SAMPLES];
        match p.push_audio(&monster) {
            Err(crate::error::StreamPushError::Backpressure(c)) => {
                assert_eq!(c.len(), monster.len());
            }
            other => panic!("expected Backpressure, got {other:?}"),
        }
        assert_eq!(p.samples_in, 0, "rejected chunk was partially consumed");
        assert_eq!(p.chip.activity().frames, 0);
        // split into sane pieces: every frame flows
        for piece in monster.chunks(1024) {
            p.push_audio(piece).expect("sliced pieces fit");
        }
        assert_eq!(p.chip.activity().frames, (monster.len() / 128) as u64);
    }

    #[test]
    fn session_state_stays_flat_on_long_tracks() {
        // the satellite audit: no per-frame growth survives on a
        // long-lived pipeline — state_bytes after minutes of audio equals
        // state_bytes after the first chunks
        let cfg = TrackConfig { duration_s: 4, keywords: 2, fillers: 1, noise: (0.001, 0.002) };
        let (audio12, _) = synth_track(&cfg, 19);
        let mut p = StreamPipeline::new(rng_quant(9), StreamConfig::design_point());
        for c in audio12.chunks(256).take(8) {
            p.push_audio(c).expect("chunk fits");
        }
        let early = p.state_bytes();
        for _ in 0..8 {
            for c in audio12.chunks(256) {
                p.push_audio(c).expect("chunk fits");
            }
        }
        assert_eq!(p.state_bytes(), early, "per-session memory grew with audio");
        assert!(early > 0);
    }

    #[test]
    fn gating_reduces_average_power() {
        let cfg = TrackConfig { duration_s: 6, keywords: 2, fillers: 0, noise: (0.001, 0.002) };
        let (audio12, _) = synth_track(&cfg, 7);
        let run = |vad: VadConfig| {
            let mut p = StreamPipeline::new(
                rng_quant(4),
                StreamConfig::design_point().with_vad(vad),
            );
            for c in audio12.chunks(256) {
                p.push_audio(c).expect("chunk fits");
            }
            p.report().power.total_uw()
        };
        let gated = run(VadConfig::design_point());
        let always_on = run(VadConfig::disabled());
        assert!(
            gated < always_on,
            "gating must cut average power: {gated} !< {always_on}"
        );
    }
}

//! Continuous-detection scoring: miss rate, false-accepts/hour and
//! detection latency against a ground-truth track schedule.
//!
//! These are the metrics always-on KWS ICs are judged by (and that a
//! per-utterance accuracy number cannot express): a detector that fires
//! constantly has zero misses and is useless. An emitted
//! [`DetectionEvent`] *hits* a scheduled keyword when it lands inside the
//! keyword's placement window (plus a decision-delay tolerance) with the
//! right class; unmatched events — including right-class events at the
//! wrong time and anything triggered by a filler word — are false accepts.

use super::detector::DetectionEvent;
use crate::audio::track::TrackEntry;

/// Default post-window tolerance: the detector needs smoothing-window +
/// confirm frames after the word ends, plus the renderer jitters word
/// onset inside its 1 s placement window.
pub const DEFAULT_TOLERANCE_MS: f64 = 750.0;

/// Samples per millisecond at the 8 kHz front door.
const SAMPLES_PER_MS: f64 = crate::SAMPLE_RATE as f64 / 1000.0;

/// Aggregate detection score for one track.
#[derive(Debug, Clone, Default)]
pub struct TrackScore {
    /// scheduled keywords (ground-truth positives)
    pub keywords: usize,
    pub hits: usize,
    pub misses: usize,
    pub false_accepts: usize,
    /// per-hit latency from the placement-window onset (ms)
    pub latencies_ms: Vec<f64>,
    /// scored track length (s)
    pub duration_s: f64,
}

impl TrackScore {
    pub fn miss_rate(&self) -> f64 {
        if self.keywords == 0 {
            return 0.0;
        }
        self.misses as f64 / self.keywords as f64
    }

    pub fn false_accepts_per_hour(&self) -> f64 {
        if self.duration_s <= 0.0 {
            return 0.0;
        }
        self.false_accepts as f64 / (self.duration_s / 3600.0)
    }

    /// Median hit latency (ms); `None` when nothing was detected. Even
    /// counts average the two middle elements (`v[len/2]` alone is the
    /// *upper* median and overstates the latency).
    pub fn median_latency_ms(&self) -> Option<f64> {
        if self.latencies_ms.is_empty() {
            return None;
        }
        let mut v = self.latencies_ms.clone();
        v.sort_by(|a, b| a.total_cmp(b));
        let mid = v.len() / 2;
        Some(if v.len() % 2 == 0 { (v[mid - 1] + v[mid]) / 2.0 } else { v[mid] })
    }
}

/// Score a detection-event stream against the ground-truth schedule.
///
/// Greedy matching in event order: each event claims the
/// **latest-starting** still-unmatched keyword whose window
/// `[onset, onset + len + tol]` contains the event's confirmation sample
/// and whose class matches (with the post-window tolerance, consecutive
/// same-class windows can overlap; the latest-onset candidate is the one
/// the detector could actually have heard most recently, and attributing
/// to it keeps the latency numbers honest). Duplicate detections of an
/// already-claimed keyword count as false accepts (the debounce is
/// supposed to prevent them).
pub fn score_track(
    sched: &[TrackEntry],
    events: &[DetectionEvent],
    total_samples: u64,
    tolerance_ms: f64,
) -> TrackScore {
    let tol = (tolerance_ms * SAMPLES_PER_MS) as u64;
    let mut matched = vec![false; sched.len()];
    let mut score = TrackScore {
        keywords: sched.iter().filter(|e| e.is_keyword()).count(),
        duration_s: total_samples as f64 / crate::SAMPLE_RATE as f64,
        ..TrackScore::default()
    };
    for ev in events {
        let s = ev.sample();
        // schedule is onset-sorted: reverse scan finds the latest onset
        let hit = sched
            .iter()
            .enumerate()
            .rev()
            .find(|(i, ent)| {
                ent.is_keyword()
                    && !matched[*i]
                    && ev.class == ent.class
                    && s >= ent.onset as u64
                    && s <= ent.onset as u64 + ent.len as u64 + tol
            })
            .map(|(i, _)| i);
        match hit {
            Some(i) => {
                matched[i] = true;
                score.hits += 1;
                score
                    .latencies_ms
                    .push((s - sched[i].onset as u64) as f64 / SAMPLES_PER_MS);
            }
            None => score.false_accepts += 1,
        }
    }
    score.misses = score.keywords - score.hits;
    score
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(class: usize, onset: usize) -> TrackEntry {
        TrackEntry { class, onset, len: 8000 }
    }

    /// Event confirmed at sample `s` (frame = s/128 - 1).
    fn event(class: usize, s: u64) -> DetectionEvent {
        let frame = s / crate::FRAME_SAMPLES as u64 - 1;
        DetectionEvent { class, frame, onset_frame: frame, margin: 1 }
    }

    #[test]
    fn perfect_run_scores_clean() {
        let sched = [entry(5, 0), entry(9, 20_000), entry(3, 40_000)];
        let events =
            [event(5, 7_936), event(9, 28_032), event(3, 47_872)];
        let s = score_track(&sched, &events, 60 * 8000, DEFAULT_TOLERANCE_MS);
        assert_eq!((s.keywords, s.hits, s.misses, s.false_accepts), (3, 3, 0, 0));
        assert_eq!(s.miss_rate(), 0.0);
        assert_eq!(s.false_accepts_per_hour(), 0.0);
        let lat = s.median_latency_ms().unwrap();
        assert!(lat > 900.0 && lat < 1010.0, "latency {lat}");
    }

    #[test]
    fn wrong_class_is_miss_plus_false_accept() {
        let sched = [entry(5, 0)];
        let events = [event(7, 7_936)];
        let s = score_track(&sched, &events, 10 * 8000, DEFAULT_TOLERANCE_MS);
        assert_eq!((s.hits, s.misses, s.false_accepts), (0, 1, 1));
        assert_eq!(s.miss_rate(), 1.0);
    }

    #[test]
    fn out_of_window_event_is_false_accept() {
        let sched = [entry(5, 0)];
        // confirmed 2 s after the window closed
        let events = [event(5, 8000 + 6000 + 16_000)];
        let s = score_track(&sched, &events, 60 * 8000, DEFAULT_TOLERANCE_MS);
        assert_eq!((s.hits, s.false_accepts), (0, 1));
    }

    #[test]
    fn overlapping_same_class_windows_attribute_to_latest_onset() {
        // consecutive same-class windows overlap once the tolerance is
        // added; a fast detection inside the second window must claim the
        // second keyword (short latency), not the missed first one
        let sched = [entry(7, 0), entry(7, 12_000)];
        let events = [event(7, 13_056)]; // 1056 samples after the 2nd onset
        let s = score_track(&sched, &events, 60 * 8000, DEFAULT_TOLERANCE_MS);
        assert_eq!((s.hits, s.misses, s.false_accepts), (1, 1, 0));
        let lat = s.median_latency_ms().unwrap();
        assert!(lat < 200.0, "latency attributed to the wrong window: {lat}");
    }

    #[test]
    fn duplicate_detection_counts_as_false_accept() {
        let sched = [entry(5, 0)];
        let events = [event(5, 7_936), event(5, 8_960)];
        let s = score_track(&sched, &events, 60 * 8000, DEFAULT_TOLERANCE_MS);
        assert_eq!((s.hits, s.false_accepts), (1, 1));
    }

    #[test]
    fn fillers_are_never_positives() {
        let sched = [entry(1, 0), entry(5, 20_000)];
        // detector tricked by the filler word
        let events = [event(4, 7_936)];
        let s = score_track(&sched, &events, 60 * 8000, DEFAULT_TOLERANCE_MS);
        assert_eq!(s.keywords, 1);
        assert_eq!((s.hits, s.misses, s.false_accepts), (0, 1, 1));
    }

    #[test]
    fn even_count_median_averages_the_two_middles() {
        let s = TrackScore {
            keywords: 4,
            hits: 4,
            latencies_ms: vec![40.0, 10.0, 30.0, 20.0],
            duration_s: 60.0,
            ..TrackScore::default()
        };
        // sorted middles are 20 and 30 — the old upper-median returned 30
        assert_eq!(s.median_latency_ms(), Some(25.0));
        // odd counts still return the exact middle element
        let odd = TrackScore {
            keywords: 3,
            hits: 3,
            latencies_ms: vec![9.0, 1.0, 5.0],
            duration_s: 60.0,
            ..TrackScore::default()
        };
        assert_eq!(odd.median_latency_ms(), Some(5.0));
    }

    #[test]
    fn fa_per_hour_scales_with_duration() {
        let sched: [TrackEntry; 0] = [];
        let events = [event(5, 1_024), event(7, 2_048)];
        let s = score_track(&sched, &events, 3600 * 8000, DEFAULT_TOLERANCE_MS);
        assert!((s.false_accepts_per_hour() - 2.0).abs() < 1e-9);
        assert_eq!(s.miss_rate(), 0.0);
        assert!(s.median_latency_ms().is_none());
    }
}

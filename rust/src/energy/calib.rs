//! Calibration constants — the *only* fitted numbers in the energy model.
//!
//! Everything else in `energy`/`fex`/`accel`/`sram` is counted (events,
//! cycles, gates). These constants anchor the counted activity to the
//! paper's measured operating points, and each is derived below from the
//! paper's own numbers; `tests` re-derive the anchors to guard regressions.
//!
//! ## Anchor points (paper Fig. 10/12, Table II)
//!
//! | quantity                        | Δ_TH = 0 | Δ_TH = 0.2 |
//! |---------------------------------|----------|------------|
//! | total power                     | 7.36 µW  | 5.22 µW    |
//! | computing latency               | 16.4 ms  | 6.9 ms     |
//! | energy/decision (= P x latency) | 121.2 nJ | 36.11 nJ   |
//!
//! Block powers at the design point (Fig. 10): FEx 1.22 µW (~25%), ΔRNN
//! ~57% = 2.98 µW, SRAM read 0.93 µW (18%); misc = 5.22 - sum = 0.09 µW.
//!
//! ## Latency model (structural)
//!
//! cycles/frame = `CYCLES_FIXED` + `CYCLES_PER_LANE` x (fired lanes), with
//! `CYCLES_PER_LANE` = 3H / 8 MACs = 24 exactly (each fired delta updates
//! 3H = 192 gate pre-activations spread over 8 MAC lanes), and
//! `CYCLES_FIXED` = 274 from the dense anchor: 16.4 ms x 125 kHz = 2050 =
//! F + 24 x 74 → F = 274 (ΔEncoder pass 74 + NLU/assembler 64 + FC 96 +
//! pipeline fill ~40 — the structural components sum to the fitted value).
//!
//! ## Interpreting the sparse anchor
//!
//! The paper's sparse-point latency (6.9 ms = 862 cycles) implies
//! 24.5 fired lanes/frame (862 = 274 + 24 x 24.5), i.e. **67% lane-level
//! sparsity**, while Fig. 12 reports "87% temporal sparsity". The two are
//! consistent if the 87% figure is the sparsity of the Δ-*input* stream
//! (Δx lanes: 87% silent), with hidden-state lanes firing more often —
//! our twin therefore reports input, hidden and combined sparsity
//! separately, and the energy split below is derived at the
//! 24.5-lanes/frame point.
//!
//! ## Energy split derivation (two-anchor fit)
//!
//! Per-second event counts at 62.5 frames/s, H = 64, 10 input channels,
//! FC = 768 MACs/frame, weight words = 96/lane + 384 FC:
//!   dense:  MACs/s = 62.5 x (74x192 + 768) = 936k ; reads/s = 62.5 x (74x96 + 384) = 468k
//!   sparse: MACs/s = 62.5 x (24.5x192 + 768) = 342k ; reads/s = 62.5 x (24.5x96 + 384) = 171k
//! ΔP = 7.36 - 5.22 = 2.14 µW over ΔMACs = 594k/s and Δreads = 297k/s.
//! Splitting with a 65 nm-plausible 2.0 pJ int8x16b MAC:
//!   594k x 2.0 pJ = 1.19 µW ; remainder 0.95 µW / 297k = 3.2 pJ/word read.
//! (We round to E_MAC = 2.0 pJ, E_WORD = 3.2 pJ; tests verify the anchors
//! reproduce to < 3%.) Then at the design point:
//!   SRAM leak = 0.93 - 171k x 3.2 pJ = 0.38 µW
//!   ΔRNN static = 2.98 - 342k x 2.0 pJ = 2.30 µW (clock tree, ΔEncoder,
//!   FIFOs, NLU at 125 kHz)

/// ---- chip-level anchors (paper) -------------------------------------------

/// Total chip power at the Δ_TH = 0.2 design point (µW).
pub const TOTAL_DESIGN_UW: f64 = 5.22;
/// Total chip power at Δ_TH = 0 (µW).
pub const TOTAL_DENSE_UW: f64 = 7.36;
/// Core clock (Hz).
pub const CLOCK_HZ: f64 = 125_000.0;
/// Frames per second (16 ms frame shift).
pub const FRAMES_PER_S: f64 = 62.5;

/// ---- FEx ------------------------------------------------------------------

/// FEx power at the design point: MixedShift datapath, 10 channels (µW).
pub const FEX_DESIGN_UW: f64 = 1.22;
/// FEx control/sequencer floor (µW). Derived from the paper's "10 instead
/// of 16 channels saves 30%": P16 = 1.22/0.7 = 1.743; linear in active
/// channels → ctrl = (16 x 1.22 - 10 x 1.743) / 6 = 0.349.
pub const FEX_CTRL_UW: f64 = 0.349;
/// Effective 65 nm NAND2-equivalent gate density for the FEx block,
/// anchored so the MixedShift datapath model = 0.084 mm² (paper Table I).
/// (Lower than raw-logic density because it folds in RF/wiring overheads.)
pub const FEX_GATES_PER_MM2: f64 = 287_000.0;

/// ---- ΔRNN accelerator ------------------------------------------------------

/// Energy per int8 x 16b MAC + accumulate, 0.65 V 65 nm (pJ).
pub const E_MAC_PJ: f64 = 2.0;
/// ΔRNN static/clocking power at 125 kHz (µW): clock tree, ΔEncoder,
/// ΔFIFOs, NLU, state assembler.
pub const RNN_STATIC_UW: f64 = 2.30;
/// Cycles per frame independent of sparsity (ΔEncoder pass + NLU/state
/// assembly + FC + pipeline fill). See module docs for the derivation.
pub const CYCLES_FIXED: u64 = 274;
/// Cycles per fired delta lane: 3H MACs / 8 MAC lanes = 24.
pub const CYCLES_PER_LANE: u64 = 24;

/// ---- near-V_TH weight SRAM --------------------------------------------------

/// Energy per 16-bit word read at 0.6 V near-V_TH (pJ).
pub const E_SRAM_WORD_PJ: f64 = 3.2;
/// SRAM leakage at 0.6 V with high-V_TH bitcells (µW).
pub const SRAM_LEAK_UW: f64 = 0.38;
/// Foundry push-rule 6T comparison point (1.2 V): read energy per word.
/// Chosen with `SRAM_LEAK_FOUNDRY_UW` so the total read-power ratio at the
/// design point is the paper's 6.6x (test-asserted).
pub const E_SRAM_WORD_FOUNDRY_PJ: f64 = 17.6;
/// Foundry SRAM leakage (low-V_TH, 1.2 V) (µW).
pub const SRAM_LEAK_FOUNDRY_UW: f64 = 3.1;

/// ---- misc -------------------------------------------------------------------

/// I/O + clock dividers + FIFO CDC (µW), constant.
pub const MISC_UW: f64 = 0.09;

/// ---- areas (paper Fig. 10 anchors, mm²) -------------------------------------

pub const AREA_FEX_MM2: f64 = 0.084;
pub const AREA_RNN_MM2: f64 = 0.319;
pub const AREA_SRAM_MM2: f64 = 0.381;
pub const AREA_TOTAL_MM2: f64 = 0.78;

/// Derived per-second event counts for the two anchor operating points —
/// used by tests and by `exp table2` to sanity-print the calibration.
pub mod anchors {
    /// fired lanes per frame, dense (10 active input channels + 64 hidden).
    pub const DENSE_LANES: f64 = 74.0;
    /// fired lanes per frame at the paper's design point (derived from the
    /// 6.9 ms latency; see module docs).
    pub const DESIGN_LANES: f64 = 24.5;
    /// FC MACs per frame (64 x 12).
    pub const FC_MACS: f64 = 768.0;
    /// weight words read per fired lane (3H int8 / 2 per 16b word).
    pub const WORDS_PER_LANE: f64 = 96.0;
    /// FC weight words per frame.
    pub const FC_WORDS: f64 = 384.0;

    pub fn macs_per_s(lanes: f64) -> f64 {
        super::FRAMES_PER_S * (lanes * 192.0 + FC_MACS)
    }

    pub fn words_per_s(lanes: f64) -> f64 {
        super::FRAMES_PER_S * (lanes * WORDS_PER_LANE + FC_WORDS)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn total_power_uw(lanes: f64) -> f64 {
        FEX_DESIGN_UW
            + RNN_STATIC_UW
            + anchors::macs_per_s(lanes) * E_MAC_PJ * 1e-6
            + SRAM_LEAK_UW
            + anchors::words_per_s(lanes) * E_SRAM_WORD_PJ * 1e-6
            + MISC_UW
    }

    fn latency_ms(lanes: f64) -> f64 {
        (CYCLES_FIXED as f64 + CYCLES_PER_LANE as f64 * lanes) / CLOCK_HZ * 1e3
    }

    #[test]
    fn dense_anchor_reproduces() {
        let p = total_power_uw(anchors::DENSE_LANES);
        assert!((p - TOTAL_DENSE_UW).abs() / TOTAL_DENSE_UW < 0.03, "P_dense = {p}");
        let l = latency_ms(anchors::DENSE_LANES);
        assert!((l - 16.4).abs() < 0.1, "latency {l}");
        let e = p * l; // nJ
        assert!((e - 121.2).abs() / 121.2 < 0.03, "E/dec {e}");
    }

    #[test]
    fn design_anchor_reproduces() {
        let p = total_power_uw(anchors::DESIGN_LANES);
        assert!((p - TOTAL_DESIGN_UW).abs() / TOTAL_DESIGN_UW < 0.03, "P_design = {p}");
        let l = latency_ms(anchors::DESIGN_LANES);
        assert!((l - 6.9).abs() < 0.1, "latency {l}");
        let e = p * l;
        assert!((e - 36.11).abs() / 36.11 < 0.05, "E/dec {e}");
    }

    #[test]
    fn design_point_block_breakdown_matches_fig10() {
        // FEx ~25%, ΔRNN ~57%, SRAM ~18% of 5.22 µW
        let macs = anchors::macs_per_s(anchors::DESIGN_LANES) * E_MAC_PJ * 1e-6;
        let rnn = RNN_STATIC_UW + macs;
        let reads = anchors::words_per_s(anchors::DESIGN_LANES) * E_SRAM_WORD_PJ * 1e-6;
        let sram = SRAM_LEAK_UW + reads;
        let total = total_power_uw(anchors::DESIGN_LANES);
        assert!((FEX_DESIGN_UW / total - 0.25).abs() < 0.05);
        assert!((rnn / total - 0.57).abs() < 0.05, "rnn share {}", rnn / total);
        assert!((sram / total - 0.18).abs() < 0.05, "sram share {}", sram / total);
    }

    #[test]
    fn foundry_sram_ratio_is_6_6x() {
        let reads = anchors::words_per_s(anchors::DESIGN_LANES);
        let near_vth = SRAM_LEAK_UW + reads * E_SRAM_WORD_PJ * 1e-6;
        let foundry = SRAM_LEAK_FOUNDRY_UW + reads * E_SRAM_WORD_FOUNDRY_PJ * 1e-6;
        let ratio = foundry / near_vth;
        assert!((ratio - 6.6).abs() < 0.4, "ratio {ratio}");
    }

    #[test]
    fn block_areas_sum_to_total() {
        let sum = AREA_FEX_MM2 + AREA_RNN_MM2 + AREA_SRAM_MM2;
        assert!((sum - AREA_TOTAL_MM2).abs() < 0.01);
    }

    #[test]
    fn latency_reduction_factor_2_4x() {
        let r = latency_ms(anchors::DENSE_LANES) / latency_ms(anchors::DESIGN_LANES);
        assert!((r - 2.4).abs() < 0.1, "latency ratio {r}");
    }

    #[test]
    fn energy_reduction_factor_3_4x() {
        let e0 = total_power_uw(anchors::DENSE_LANES) * latency_ms(anchors::DENSE_LANES);
        let e1 = total_power_uw(anchors::DESIGN_LANES) * latency_ms(anchors::DESIGN_LANES);
        let r = e0 / e1;
        assert!((r - 3.4).abs() < 0.25, "energy ratio {r}");
    }
}

//! Event-counting energy/power/area accounting for the whole chip.
//!
//! The twins (FEx, ΔRNN accelerator, SRAM) count *events* — MACs, weight
//! word reads, channel visits, cycles. This module converts counted
//! activity into power (µW), energy/decision (nJ) and latency (ms) through
//! the calibrated per-event energies in [`calib`], and gate-count/bitcell
//! models into block areas (mm²).
//!
//! Convention: "energy per decision" follows the paper — total chip power
//! multiplied by the per-frame *computing latency* (the window in which the
//! ΔRNN is actually busy), which is how 7.36 µW x 16.4 ms = 121.2 nJ and
//! 5.22 µW x 6.9 ms = 36.1 nJ arise in Table II.

pub mod calib;

/// Aggregated activity of one simulation run (any number of frames).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChipActivity {
    /// frames processed (including clock-gated ones — the frame clock is
    /// wall time for the power model)
    pub frames: u64,
    /// frames consumed with the ΔRNN clock-gated (VAD idle; no MACs, no
    /// SRAM reads, no cycles)
    pub gated_frames: u64,
    /// ΔRNN MAC operations, including the FC layer
    pub mac_ops: u64,
    /// 16-bit weight words read from the SRAM
    pub sram_word_reads: u64,
    /// ΔRNN compute cycles (for latency)
    pub rnn_cycles: u64,
    /// fired delta lanes (input + hidden), for sparsity reporting
    pub fired_lanes: u64,
    /// total delta lanes examined
    pub total_lanes: u64,
    /// fired input (Δx) lanes / total input lanes
    pub fired_x: u64,
    pub total_x: u64,
    /// fired hidden (Δh) lanes / total hidden lanes
    pub fired_h: u64,
    pub total_h: u64,
    /// FEx active-channel visits
    pub fex_visits: u64,
}

impl ChipActivity {
    pub fn merge(&mut self, other: &ChipActivity) {
        self.frames += other.frames;
        self.gated_frames += other.gated_frames;
        self.mac_ops += other.mac_ops;
        self.sram_word_reads += other.sram_word_reads;
        self.rnn_cycles += other.rnn_cycles;
        self.fired_lanes += other.fired_lanes;
        self.total_lanes += other.total_lanes;
        self.fired_x += other.fired_x;
        self.total_x += other.total_x;
        self.fired_h += other.fired_h;
        self.total_h += other.total_h;
        self.fex_visits += other.fex_visits;
    }

    /// Field-wise difference from an earlier snapshot of the same
    /// counters. All fields are monotonic event counts, so telemetry can
    /// flush increments (`current.delta_since(&last_flushed)`) into a
    /// shared accumulator without ever resetting the source counters.
    pub fn delta_since(&self, prev: &ChipActivity) -> ChipActivity {
        ChipActivity {
            frames: self.frames - prev.frames,
            gated_frames: self.gated_frames - prev.gated_frames,
            mac_ops: self.mac_ops - prev.mac_ops,
            sram_word_reads: self.sram_word_reads - prev.sram_word_reads,
            rnn_cycles: self.rnn_cycles - prev.rnn_cycles,
            fired_lanes: self.fired_lanes - prev.fired_lanes,
            total_lanes: self.total_lanes - prev.total_lanes,
            fired_x: self.fired_x - prev.fired_x,
            total_x: self.total_x - prev.total_x,
            fired_h: self.fired_h - prev.fired_h,
            total_h: self.total_h - prev.total_h,
            fex_visits: self.fex_visits - prev.fex_visits,
        }
    }

    /// ΔRNN duty cycle: fraction of frames where the accelerator actually
    /// clocked (1.0 without VAD gating).
    pub fn duty_cycle(&self) -> f64 {
        if self.frames == 0 {
            return 1.0;
        }
        1.0 - self.gated_frames as f64 / self.frames as f64
    }

    /// Combined temporal sparsity: fraction of silent delta lanes.
    pub fn sparsity(&self) -> f64 {
        if self.total_lanes == 0 {
            return 0.0;
        }
        1.0 - self.fired_lanes as f64 / self.total_lanes as f64
    }

    /// Input-delta (Δx) sparsity — the figure the paper's Fig. 12 tracks.
    pub fn input_sparsity(&self) -> f64 {
        if self.total_x == 0 {
            return 0.0;
        }
        1.0 - self.fired_x as f64 / self.total_x as f64
    }

    /// Hidden-delta (Δh) sparsity.
    pub fn hidden_sparsity(&self) -> f64 {
        if self.total_h == 0 {
            return 0.0;
        }
        1.0 - self.fired_h as f64 / self.total_h as f64
    }

    /// Mean ΔRNN computing latency per frame (ms) at the core clock.
    pub fn avg_latency_ms(&self) -> f64 {
        if self.frames == 0 {
            return 0.0;
        }
        self.rnn_cycles as f64 / self.frames as f64 / calib::CLOCK_HZ * 1e3
    }
}

/// Power breakdown in µW (paper Fig. 10).
#[derive(Debug, Clone, Copy)]
pub struct PowerBreakdown {
    pub fex_uw: f64,
    pub rnn_uw: f64,
    pub sram_uw: f64,
    pub misc_uw: f64,
}

impl PowerBreakdown {
    pub fn total_uw(&self) -> f64 {
        self.fex_uw + self.rnn_uw + self.sram_uw + self.misc_uw
    }
}

/// SRAM flavour for the 6.6x comparison (paper §II-D).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SramKind {
    /// the paper's 0.6 V near-V_TH full-custom macro
    NearVth,
    /// foundry push-rule 6T at nominal voltage
    Foundry,
}

impl SramKind {
    pub fn word_energy_pj(self) -> f64 {
        match self {
            SramKind::NearVth => calib::E_SRAM_WORD_PJ,
            SramKind::Foundry => calib::E_SRAM_WORD_FOUNDRY_PJ,
        }
    }

    pub fn leak_uw(self) -> f64 {
        match self {
            SramKind::NearVth => calib::SRAM_LEAK_UW,
            SramKind::Foundry => calib::SRAM_LEAK_FOUNDRY_UW,
        }
    }
}

/// Convert counted activity into the chip power breakdown.
///
/// `fex_power_uw` comes from [`crate::fex::area::power_uw`] (it depends on
/// the datapath architecture and active channel count, not on audio
/// content — the serial pipeline runs every sample regardless).
pub fn chip_power(activity: &ChipActivity, fex_power_uw: f64, sram: SramKind) -> PowerBreakdown {
    let seconds = activity.frames as f64 / calib::FRAMES_PER_S;
    if seconds == 0.0 {
        return PowerBreakdown { fex_uw: fex_power_uw, rnn_uw: 0.0, sram_uw: 0.0, misc_uw: 0.0 };
    }
    let mac_uw = activity.mac_ops as f64 * calib::E_MAC_PJ * 1e-6 / seconds;
    let read_uw = activity.sram_word_reads as f64 * sram.word_energy_pj() * 1e-6 / seconds;
    PowerBreakdown {
        fex_uw: fex_power_uw,
        rnn_uw: calib::RNN_STATIC_UW + mac_uw,
        sram_uw: sram.leak_uw() + read_uw,
        misc_uw: calib::MISC_UW,
    }
}

/// Energy per decision (nJ), paper convention: total power x mean latency.
pub fn energy_per_decision_nj(power: &PowerBreakdown, activity: &ChipActivity) -> f64 {
    power.total_uw() * activity.avg_latency_ms()
}

/// Chip area report (mm²): FEx from its gate model, ΔRNN from a gate
/// model, SRAM from a bitcell model — each anchored to the paper (Fig. 10).
#[derive(Debug, Clone, Copy)]
pub struct AreaBreakdown {
    pub fex_mm2: f64,
    pub rnn_mm2: f64,
    pub sram_mm2: f64,
}

impl AreaBreakdown {
    pub fn total_mm2(&self) -> f64 {
        self.fex_mm2 + self.rnn_mm2 + self.sram_mm2
    }

    /// The chip as built (design-point architecture).
    pub fn chip() -> Self {
        Self {
            fex_mm2: crate::fex::area::area(crate::fex::biquad::Arch::MixedShift).area_mm2(),
            rnn_mm2: crate::accel::area_mm2(),
            sram_mm2: crate::sram::area_mm2(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic_activity(lanes_per_frame: f64, frames: u64) -> ChipActivity {
        let lanes = (lanes_per_frame * frames as f64) as u64;
        ChipActivity {
            frames,
            gated_frames: 0,
            mac_ops: lanes * 192 + frames * 768,
            sram_word_reads: lanes * 96 + frames * 384,
            rnn_cycles: frames * calib::CYCLES_FIXED + lanes * calib::CYCLES_PER_LANE,
            fired_lanes: lanes,
            total_lanes: frames * 74,
            fired_x: 0,
            total_x: frames * 10,
            fired_h: 0,
            total_h: frames * 64,
            fex_visits: frames * 128 * 10,
        }
    }

    #[test]
    fn dense_point_power_and_energy() {
        let act = synthetic_activity(74.0, 625);
        let p = chip_power(&act, calib::FEX_DESIGN_UW, SramKind::NearVth);
        assert!((p.total_uw() - calib::TOTAL_DENSE_UW).abs() < 0.25, "{}", p.total_uw());
        let e = energy_per_decision_nj(&p, &act);
        assert!((e - 121.2).abs() / 121.2 < 0.05, "{e}");
    }

    #[test]
    fn design_point_power_and_energy() {
        let act = synthetic_activity(24.5, 625);
        let p = chip_power(&act, calib::FEX_DESIGN_UW, SramKind::NearVth);
        assert!((p.total_uw() - calib::TOTAL_DESIGN_UW).abs() < 0.2, "{}", p.total_uw());
        let e = energy_per_decision_nj(&p, &act);
        assert!((e - 36.11).abs() / 36.11 < 0.06, "{e}");
    }

    #[test]
    fn foundry_sram_costs_6_6x() {
        let act = synthetic_activity(24.5, 625);
        let near = chip_power(&act, calib::FEX_DESIGN_UW, SramKind::NearVth).sram_uw;
        let foundry = chip_power(&act, calib::FEX_DESIGN_UW, SramKind::Foundry).sram_uw;
        assert!((foundry / near - 6.6).abs() < 0.5, "{}", foundry / near);
    }

    #[test]
    fn sparsity_accessors() {
        let mut act = synthetic_activity(37.0, 10);
        act.fired_x = 30;
        act.fired_h = 340;
        assert!((act.sparsity() - 0.5).abs() < 0.01);
        assert!((act.input_sparsity() - 0.7).abs() < 0.01);
        assert!((act.hidden_sparsity() - (1.0 - 340.0 / 640.0)).abs() < 0.01);
    }

    #[test]
    fn merge_accumulates() {
        let a = synthetic_activity(10.0, 5);
        let mut b = synthetic_activity(20.0, 7);
        b.merge(&a);
        assert_eq!(b.frames, 12);
        assert_eq!(b.total_lanes, 12 * 74);
    }

    #[test]
    fn delta_since_inverts_merge() {
        let early = synthetic_activity(10.0, 5);
        let mut late = early;
        late.merge(&synthetic_activity(20.0, 7));
        let delta = late.delta_since(&early);
        assert_eq!(delta.frames, 7);
        assert_eq!(delta.total_lanes, 7 * 74);
        let mut rebuilt = early;
        rebuilt.merge(&delta);
        assert_eq!(rebuilt.frames, late.frames);
        assert_eq!(rebuilt.rnn_cycles, late.rnn_cycles);
        assert_eq!(rebuilt.fex_visits, late.fex_visits);
    }

    #[test]
    fn zero_frames_no_panic() {
        let act = ChipActivity::default();
        assert_eq!(act.sparsity(), 0.0);
        assert_eq!(act.avg_latency_ms(), 0.0);
        let p = chip_power(&act, 1.0, SramKind::NearVth);
        assert!(p.total_uw() >= 1.0);
    }
}

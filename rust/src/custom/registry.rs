//! Versioned, content-hashed weight registry.
//!
//! Every weight set the serving stack can run is identified by a
//! [`WeightVersion`] — an FNV-1a hash over the *SRAM word image*
//! ([`gru::to_sram_image`]), i.e. over exactly the bits the chip reads.
//! Content addressing makes enrollment idempotent: re-enrolling the same
//! speaker from the same seed reproduces the same image and therefore the
//! same version id (see the round-trip determinism tests).
//!
//! The [`WeightRegistry`] keeps a bounded LRU of *resident* versions
//! (deserialised [`QuantParams`] behind `Arc`s) plus tombstones for
//! evicted ids, so lookups distinguish "never registered"
//! ([`RegistryError::UnknownVersion`]) from "registered but evicted"
//! ([`RegistryError::Evicted`]). Versions referenced by live stream
//! sessions are *pinned* and never evicted — if every resident is pinned
//! the registry temporarily overflows its capacity rather than pulling
//! weights out from under a session (the bound is on *evictable* versions,
//! documented in DESIGN.md §14).
//!
//! This module is control-plane code: it takes a `Mutex` and allocates.
//! Nothing here runs on the per-frame hot path — the worker resolves a
//! version to an `Arc<QuantParams>` *before* any frame is stepped, and the
//! fence install itself ([`crate::chip::KwsChip::swap_weights`]) touches
//! the registry not at all.

use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, Mutex};

use crate::accel::gru::{self, QuantParams};
use crate::util::hist::{AtomicLogHistogram, LogHistogram};

/// Content hash of a quantised weight set: FNV-1a over the little-endian
/// bytes of the SRAM word image. Two parameter sets compare equal exactly
/// when the chip would read identical weight bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct WeightVersion(u64);

impl WeightVersion {
    /// Hash a parameter set into its version id (pure function of the
    /// serialised image; independent of registry state).
    pub fn of(params: &QuantParams) -> Self {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        for word in gru::to_sram_image(params) {
            for byte in word.to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
        }
        Self(h)
    }

    /// The raw 64-bit hash (stable across runs; used in metrics labels).
    pub fn as_u64(self) -> u64 {
        self.0
    }
}

impl fmt::Display for WeightVersion {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

/// Typed registry failures. Both variants carry the offending version so
/// callers (and the crate [`Error`](crate::Error) tree) preserve the
/// payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RegistryError {
    /// The version was never registered with this registry.
    UnknownVersion(WeightVersion),
    /// The version was registered but evicted from the resident set; the
    /// caller must re-enroll (content addressing makes that reproduce the
    /// same id).
    Evicted(WeightVersion),
}

impl RegistryError {
    /// The version the failed operation referenced.
    pub fn version(&self) -> WeightVersion {
        match self {
            RegistryError::UnknownVersion(v) | RegistryError::Evicted(v) => *v,
        }
    }
}

impl fmt::Display for RegistryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RegistryError::UnknownVersion(v) => write!(f, "unknown weight version {v}"),
            RegistryError::Evicted(v) => write!(f, "weight version {v} was evicted (re-enroll to restore)"),
        }
    }
}

impl std::error::Error for RegistryError {}

/// One resident weight set.
struct Resident {
    params: Arc<QuantParams>,
    /// lazily serialised full-length SRAM image (see
    /// [`crate::sram::shared_image`]), built on the first
    /// [`WeightRegistry::image`] request and shared from then on: every
    /// session and worker chip on this version installs this one
    /// allocation by pointer. Dropped with the resident on eviction;
    /// resurrection rebuilds it on demand.
    image: Option<Arc<Vec<u16>>>,
    parent: Option<WeightVersion>,
    /// live-session pin count: > 0 blocks eviction
    pins: u64,
    /// LRU clock value at last touch (insert/get/pin)
    seq: u64,
}

struct Inner {
    residents: HashMap<WeightVersion, Resident>,
    /// tombstones for evicted versions (value = recorded parent), so
    /// lookups can answer `Evicted` instead of `UnknownVersion` and
    /// lineage survives eviction
    evicted: HashMap<WeightVersion, Option<WeightVersion>>,
    clock: u64,
}

/// Bounded LRU of resident weight versions, shared between the
/// [`Coordinator`](crate::coordinator::Coordinator), its router and its
/// workers behind an `Arc`.
pub struct WeightRegistry {
    inner: Mutex<Inner>,
    capacity: usize,
    /// end-to-end enrollment latency (µs), exposed through
    /// [`Stats`](crate::coordinator::Stats) / `obs::metrics`
    enroll_latency: AtomicLogHistogram,
}

impl WeightRegistry {
    /// Registry bounded to `capacity` *evictable* resident versions
    /// (clamped to ≥ 1). Pinned versions never count against an eviction
    /// decision, so the resident set can transiently exceed `capacity`
    /// when every version is pinned by a live session.
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(Inner {
                residents: HashMap::new(),
                evicted: HashMap::new(),
                clock: 0,
            }),
            capacity: capacity.max(1),
            enroll_latency: AtomicLogHistogram::new(),
        }
    }

    /// Configured resident-set bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Register a weight set, returning its content hash. Idempotent: a
    /// version already resident is just touched (its first-recorded parent
    /// wins); an evicted version is resurrected from the new params. May
    /// evict the least-recently-used *unpinned* resident to stay within
    /// capacity; never fails.
    pub fn insert(&self, params: QuantParams, parent: Option<WeightVersion>) -> WeightVersion {
        let version = WeightVersion::of(&params);
        let mut inner = self.inner.lock().expect("registry mutex poisoned");
        inner.clock += 1;
        let seq = inner.clock;
        if let Some(r) = inner.residents.get_mut(&version) {
            r.seq = seq;
            return version;
        }
        // resurrecting an evicted id keeps the originally recorded parent
        let parent = inner.evicted.remove(&version).unwrap_or(parent);
        inner.residents.insert(
            version,
            Resident { params: Arc::new(params), image: None, parent, pins: 0, seq },
        );
        while inner.residents.len() > self.capacity {
            // never evict the version being inserted: an enroll must hand
            // back an id that is at least momentarily resident/pinnable
            let victim = inner
                .residents
                .iter()
                .filter(|(v, r)| r.pins == 0 && **v != version)
                .min_by_key(|(_, r)| r.seq)
                .map(|(v, _)| *v);
            match victim {
                Some(v) => {
                    let r = inner.residents.remove(&v).expect("victim just found");
                    inner.evicted.insert(v, r.parent);
                }
                // everything pinned: documented overflow, never pull
                // weights out from under a live session
                None => break,
            }
        }
        version
    }

    /// Resolve a version to its parameters (touches the LRU clock).
    pub fn get(&self, version: WeightVersion) -> Result<Arc<QuantParams>, RegistryError> {
        let mut inner = self.inner.lock().expect("registry mutex poisoned");
        inner.clock += 1;
        let seq = inner.clock;
        if let Some(r) = inner.residents.get_mut(&version) {
            r.seq = seq;
            return Ok(Arc::clone(&r.params));
        }
        if inner.evicted.contains_key(&version) {
            return Err(RegistryError::Evicted(version));
        }
        Err(RegistryError::UnknownVersion(version))
    }

    /// Resolve a version to its shared full-length SRAM image, serialising
    /// and caching it on first request (touches the LRU clock). Every
    /// caller gets the same `Arc`, so the image exists once per resident
    /// version however many chips serve it — the allocation the v3
    /// scheduler's 10k-session memory budget leans on.
    pub fn image(&self, version: WeightVersion) -> Result<Arc<Vec<u16>>, RegistryError> {
        let mut inner = self.inner.lock().expect("registry mutex poisoned");
        inner.clock += 1;
        let seq = inner.clock;
        if let Some(r) = inner.residents.get_mut(&version) {
            r.seq = seq;
            let image = r
                .image
                .get_or_insert_with(|| crate::sram::shared_image(&gru::to_sram_image(&r.params)));
            return Ok(Arc::clone(image));
        }
        if inner.evicted.contains_key(&version) {
            return Err(RegistryError::Evicted(version));
        }
        Err(RegistryError::UnknownVersion(version))
    }

    /// Resolve *and* pin: the version is protected from eviction until a
    /// matching [`unpin`](Self::unpin). Sessions pin the version they run.
    pub fn pin(&self, version: WeightVersion) -> Result<Arc<QuantParams>, RegistryError> {
        let mut inner = self.inner.lock().expect("registry mutex poisoned");
        inner.clock += 1;
        let seq = inner.clock;
        if let Some(r) = inner.residents.get_mut(&version) {
            r.pins += 1;
            r.seq = seq;
            return Ok(Arc::clone(&r.params));
        }
        if inner.evicted.contains_key(&version) {
            return Err(RegistryError::Evicted(version));
        }
        Err(RegistryError::UnknownVersion(version))
    }

    /// Release one pin. Saturating and tolerant of an already-evicted or
    /// unknown id — unpin runs on session-teardown paths that must not
    /// fail.
    pub fn unpin(&self, version: WeightVersion) {
        let mut inner = self.inner.lock().expect("registry mutex poisoned");
        if let Some(r) = inner.residents.get_mut(&version) {
            r.pins = r.pins.saturating_sub(1);
        }
    }

    /// Current pin count of a version (0 when absent).
    pub fn pins(&self, version: WeightVersion) -> u64 {
        let inner = self.inner.lock().expect("registry mutex poisoned");
        inner.residents.get(&version).map_or(0, |r| r.pins)
    }

    /// Number of resident (immediately servable) versions — the
    /// `deltakws_resident_weight_versions` gauge.
    pub fn resident_count(&self) -> usize {
        self.inner.lock().expect("registry mutex poisoned").residents.len()
    }

    /// Is `version` resident right now?
    pub fn contains(&self, version: WeightVersion) -> bool {
        self.inner.lock().expect("registry mutex poisoned").residents.contains_key(&version)
    }

    /// Recorded parent of a version (resident or evicted); `None` for a
    /// root version or an id this registry has never seen.
    pub fn parent(&self, version: WeightVersion) -> Option<WeightVersion> {
        let inner = self.inner.lock().expect("registry mutex poisoned");
        if let Some(r) = inner.residents.get(&version) {
            return r.parent;
        }
        inner.evicted.get(&version).copied().flatten()
    }

    /// Ancestry chain starting at `version` (itself first, then parents up
    /// to the root), following recorded lineage through tombstones.
    pub fn lineage(&self, version: WeightVersion) -> Vec<WeightVersion> {
        let inner = self.inner.lock().expect("registry mutex poisoned");
        let mut chain = vec![version];
        let bound = inner.residents.len() + inner.evicted.len() + 1;
        let mut cur = version;
        while chain.len() <= bound {
            let parent = match inner.residents.get(&cur) {
                Some(r) => r.parent,
                None => inner.evicted.get(&cur).copied().flatten(),
            };
            match parent {
                Some(p) => {
                    chain.push(p);
                    cur = p;
                }
                None => break,
            }
        }
        chain
    }

    /// Record one end-to-end enrollment latency sample (µs).
    pub fn record_enroll_us(&self, us: u64) {
        self.enroll_latency.record(us);
    }

    /// Snapshot of the enrollment latency histogram.
    pub fn enroll_latency(&self) -> LogHistogram {
        self.enroll_latency.snapshot()
    }
}

impl fmt::Debug for WeightRegistry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WeightRegistry")
            .field("capacity", &self.capacity)
            .field("resident", &self.resident_count())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;

    fn rng_quant(seed: u64) -> QuantParams {
        let mut rng = Pcg::new(seed);
        let mut q = QuantParams::zeroed();
        q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
        q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
        q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
        q
    }

    #[test]
    fn version_is_content_addressed() {
        let a = WeightVersion::of(&rng_quant(1));
        let b = WeightVersion::of(&rng_quant(1));
        let c = WeightVersion::of(&rng_quant(2));
        assert_eq!(a, b, "same content must hash to the same version");
        assert_ne!(a, c, "different content must not collide");
        assert_eq!(format!("{a}").len(), 16, "display is 16 hex digits");
    }

    #[test]
    fn insert_is_idempotent_and_preserves_lineage() {
        let reg = WeightRegistry::new(4);
        let base = reg.insert(rng_quant(1), None);
        let child = reg.insert(rng_quant(2), Some(base));
        let again = reg.insert(rng_quant(2), None);
        assert_eq!(child, again, "content addressing: same params, same id");
        assert_eq!(reg.parent(child), Some(base), "first-recorded parent wins");
        assert_eq!(reg.lineage(child), vec![child, base]);
        assert_eq!(reg.resident_count(), 2);
    }

    #[test]
    fn lru_evicts_least_recently_used_unpinned() {
        let reg = WeightRegistry::new(2);
        let a = reg.insert(rng_quant(1), None);
        let b = reg.insert(rng_quant(2), None);
        reg.get(a).expect("a resident"); // touch a → b is now LRU
        let c = reg.insert(rng_quant(3), None);
        assert!(reg.contains(a) && reg.contains(c));
        assert!(!reg.contains(b), "LRU victim must be the cold version");
        match reg.get(b) {
            Err(RegistryError::Evicted(v)) => assert_eq!(v, b, "payload preserved"),
            other => panic!("expected Evicted, got {other:?}"),
        }
    }

    #[test]
    fn pinned_versions_survive_eviction_pressure() {
        let reg = WeightRegistry::new(2);
        let a = reg.insert(rng_quant(1), None);
        let b = reg.insert(rng_quant(2), None);
        reg.pin(a).expect("pin a");
        reg.pin(b).expect("pin b");
        // both residents pinned: capacity overflows rather than evicting
        // (and the just-inserted version is never its own victim)
        let c = reg.insert(rng_quant(3), None);
        assert!(reg.contains(a) && reg.contains(b), "pinned versions evicted");
        assert!(reg.contains(c), "fresh insert evicted itself under pin pressure");
        assert_eq!(reg.resident_count(), 3, "documented overflow past capacity");
        reg.unpin(a);
        let d = reg.insert(rng_quant(4), None);
        assert!(!reg.contains(a), "unpinned LRU version must now be evictable");
        assert!(!reg.contains(c), "overflow drains once pins release");
        assert!(reg.contains(b) && reg.contains(d));
        assert_eq!(reg.resident_count(), 2);
    }

    #[test]
    fn unknown_vs_evicted_are_distinct() {
        let reg = WeightRegistry::new(1);
        let ghost = WeightVersion::of(&rng_quant(99));
        match reg.get(ghost) {
            Err(RegistryError::UnknownVersion(v)) => assert_eq!(v, ghost),
            other => panic!("expected UnknownVersion, got {other:?}"),
        }
        let a = reg.insert(rng_quant(1), None);
        let _b = reg.insert(rng_quant(2), None); // evicts a (capacity 1)
        match reg.pin(a) {
            Err(RegistryError::Evicted(v)) => assert_eq!(v, a),
            other => panic!("expected Evicted, got {other:?}"),
        }
    }

    #[test]
    fn resurrection_restores_recorded_parent() {
        let reg = WeightRegistry::new(1);
        let base_params = rng_quant(1);
        let base = WeightVersion::of(&base_params);
        let child_params = rng_quant(2);
        reg.insert(base_params, None);
        let child = reg.insert(child_params.clone(), Some(base)); // evicts base
        let _ = reg.insert(rng_quant(3), None); // evicts child
        assert!(!reg.contains(child));
        let back = reg.insert(child_params, None); // parent arg lost — tombstone has it
        assert_eq!(back, child);
        assert_eq!(reg.parent(child), Some(base), "lineage must survive eviction");
    }

    #[test]
    fn unpin_is_saturating_and_teardown_safe() {
        let reg = WeightRegistry::new(2);
        let a = reg.insert(rng_quant(1), None);
        reg.unpin(a); // never pinned: no-op
        assert_eq!(reg.pins(a), 0);
        reg.unpin(WeightVersion::of(&rng_quant(7))); // unknown: no-op
        reg.pin(a).expect("pin");
        reg.pin(a).expect("pin");
        assert_eq!(reg.pins(a), 2);
        reg.unpin(a);
        assert_eq!(reg.pins(a), 1);
    }

    #[test]
    fn image_is_cached_and_shared() {
        let reg = WeightRegistry::new(2);
        let params = rng_quant(5);
        let want = gru::to_sram_image(&params);
        let v = reg.insert(params, None);
        let a = reg.image(v).expect("resident");
        let b = reg.image(v).expect("resident");
        assert!(Arc::ptr_eq(&a, &b), "image must serialise once and be shared");
        assert_eq!(a.len(), crate::sram::WORDS, "full-length padded image");
        assert_eq!(&a[..want.len()], &want[..], "image bits match the serialiser");
        assert!(a[want.len()..].iter().all(|&w| w == 0), "zero tail");
        let ghost = WeightVersion::of(&rng_quant(77));
        assert!(matches!(reg.image(ghost), Err(RegistryError::UnknownVersion(_))));
    }

    #[test]
    fn enroll_latency_histogram_accumulates() {
        let reg = WeightRegistry::new(2);
        assert_eq!(reg.enroll_latency().count(), 0);
        reg.record_enroll_us(1200);
        reg.record_enroll_us(3400);
        let h = reg.enroll_latency();
        assert_eq!(h.count(), 2);
        assert!(h.mean() > 0.0);
    }
}

//! Few-shot per-user enrollment: FC-head fine-tuning over frozen
//! recurrent weights.
//!
//! The enrollment flow takes a *quantised* base model, dequantises it into
//! the float training ABI, runs a handful of [`Backend::train_step`]s over
//! K ≤ [`MAX_SHOTS`] speaker recordings (plus silence/unknown
//! counter-examples so the FC head keeps rejecting non-target audio), and
//! requantises the result through the exact integer path the base trainer
//! uses ([`gru::quantize_params`]). Only the FC output layer moves: the
//! recurrent parameters (`w_x`, `w_h`, `b`) are restored — values *and*
//! Adam moments — after every step, so the ΔGRU dynamics, and therefore
//! the temporal-sparsity/energy profile the chip was characterised at,
//! are untouched. Chiang et al. (PAPERS.md) motivate exactly this split
//! for on-device KWS customization.
//!
//! Determinism: every input is derived from `(speaker seed, class,
//! index)` via [`SpeakerVoice`], the native backend is bit-deterministic,
//! and quantisation is integer — so enrolling twice from the same seed
//! yields a byte-identical SRAM image and hence the same
//! [`WeightVersion`](crate::custom::WeightVersion) (content addressing).
//!
//! This is control-plane code (allocates, runs float math); nothing here
//! is on the per-frame serving path.

use crate::accel::gru::{self, FloatParams, QuantParams};
use crate::dataset::FeatSeq;
use crate::error::Error;
use crate::runtime::{Backend, IntTensor, Tensor, TrainState};
use crate::train::float_params_from_tensors;

use super::speaker::SpeakerVoice;

/// Maximum number of enrollment shots (paper-scale few-shot budget).
pub const MAX_SHOTS: usize = 8;

/// Enrollment hyper-parameters. `design_point` gives the validated
/// default; all fields are public for experiments.
#[derive(Debug, Clone)]
pub struct EnrollConfig {
    /// Synthetic speaker identity (see [`SpeakerVoice`]).
    pub speaker: u64,
    /// Target keyword class (must be a keyword: `2..NUM_CLASSES`).
    pub target: usize,
    /// Number of target-keyword shots (1..=[`MAX_SHOTS`]).
    pub shots: usize,
    /// Number of silence/unknown counter-examples mixed into the batch.
    pub counter_shots: usize,
    /// Optimisation steps over the (fixed) enrollment batch.
    pub steps: usize,
    /// Adam learning rate for the FC head.
    pub lr: f32,
    /// Delta threshold used during the training forward pass.
    pub delta_th: f32,
}

impl EnrollConfig {
    /// Default enrollment recipe for `(speaker, target)`: 8 shots, 8
    /// counter-examples, 24 steps at the base training rate.
    pub fn design_point(speaker: u64, target: usize) -> Self {
        Self {
            speaker,
            target,
            shots: MAX_SHOTS,
            counter_shots: MAX_SHOTS,
            steps: 24,
            lr: crate::train::BASE_LR,
            delta_th: 0.0,
        }
    }

    /// Validate ranges; surfaces [`crate::Error::InvalidConfig`] so the
    /// serving layer rejects bad enrollments before any training runs.
    pub fn validate(&self) -> Result<(), Error> {
        if !(2..crate::NUM_CLASSES).contains(&self.target) {
            return Err(Error::invalid_config(
                "enroll.target",
                format!("target {} must be a keyword class (2..{})", self.target, crate::NUM_CLASSES),
            ));
        }
        if self.shots == 0 || self.shots > MAX_SHOTS {
            return Err(Error::invalid_config(
                "enroll.shots",
                format!("shots {} outside 1..={MAX_SHOTS}", self.shots),
            ));
        }
        if self.steps == 0 {
            return Err(Error::invalid_config("enroll.steps", "steps must be > 0"));
        }
        if !(self.lr.is_finite() && self.lr > 0.0) {
            return Err(Error::invalid_config("enroll.lr", format!("lr {} must be finite > 0", self.lr)));
        }
        Ok(())
    }
}

/// Result of a few-shot enrollment run.
#[derive(Debug, Clone)]
pub struct Enrolled {
    /// Candidate quantised weight set (register it to get a version id).
    pub params: QuantParams,
    /// Optimisation steps executed.
    pub steps: usize,
    /// Loss after the final step.
    pub final_loss: f32,
}

/// Dequantise chip weights back into the float training ABI (weights at
/// the model's `w_frac`, Q8.8 biases). Inverse of [`gru::quantize_params`]
/// up to the original quantisation error.
pub fn dequantize_params(q: &QuantParams) -> FloatParams {
    let ws = (1u32 << q.w_frac) as f32;
    let bs = 256.0; // Q8.8
    let mut p = FloatParams::zeros();
    for (dst, src) in p.w_x.iter_mut().zip(&q.w_x) {
        for (d, &s) in dst.iter_mut().zip(src.iter()) {
            *d = s as f32 / ws;
        }
    }
    for (dst, src) in p.w_h.iter_mut().zip(&q.w_h) {
        for (d, &s) in dst.iter_mut().zip(src.iter()) {
            *d = s as f32 / ws;
        }
    }
    for (d, &s) in p.b.iter_mut().zip(q.b.iter()) {
        *d = s as f32 / bs;
    }
    for (dst, src) in p.w_fc.iter_mut().zip(&q.w_fc) {
        for (d, &s) in dst.iter_mut().zip(src.iter()) {
            *d = s as f32 / ws;
        }
    }
    for (d, &s) in p.b_fc.iter_mut().zip(q.b_fc.iter()) {
        *d = s as f32 / bs;
    }
    p
}

/// Build a fresh [`TrainState`] (zero Adam moments) from float parameters,
/// flattened in the canonical `[w_x, w_h, b, w_fc, b_fc]` tensor order.
pub fn train_state_from(p: &FloatParams) -> TrainState {
    let c = crate::MAX_CHANNELS;
    let h = crate::HIDDEN;
    let k = crate::NUM_CLASSES;
    let flat = |rows: &[Vec<f32>]| -> Vec<f32> { rows.iter().flatten().copied().collect() };
    let params = vec![
        Tensor::new(vec![c, 3 * h], flat(&p.w_x)),
        Tensor::new(vec![h, 3 * h], flat(&p.w_h)),
        Tensor::new(vec![3 * h], p.b.clone()),
        Tensor::new(vec![h, k], flat(&p.w_fc)),
        Tensor::new(vec![k], p.b_fc.clone()),
    ];
    let zeros: Vec<Tensor> = params.iter().map(|t| Tensor::zeros(&t.shape)).collect();
    TrainState { params, m: zeros.clone(), v: zeros, step: 0.0 }
}

/// Stack feature sequences into the training tensors: feats
/// `[batch, frames, channels]` (Q8.8 → float, same scaling as the base
/// trainer) and labels `[batch]`.
pub fn batch_tensors(seqs: &[FeatSeq]) -> (Tensor, IntTensor) {
    let b = seqs.len();
    let t = seqs.first().map_or(0, |s| s.feats.len());
    let c = crate::MAX_CHANNELS;
    let mut data = Vec::with_capacity(b * t * c);
    for s in seqs {
        debug_assert_eq!(s.feats.len(), t, "ragged enrollment batch");
        for f in &s.feats {
            for &v in f.iter() {
                data.push(v as f32 / 256.0);
            }
        }
    }
    let labels: Vec<i32> = seqs.iter().map(|s| s.label as i32).collect();
    (Tensor::new(vec![b, t, c], data), IntTensor::new(vec![b], labels))
}

/// Run few-shot enrollment: fine-tune the FC head of `base` on
/// `cfg.shots` recordings of `cfg.target` by speaker `cfg.speaker`
/// (plus counter-examples), freezing the recurrent weights, and
/// requantise into a candidate weight set.
pub fn few_shot(backend: &dyn Backend, base: &QuantParams, cfg: &EnrollConfig) -> crate::Result<Enrolled> {
    cfg.validate()?;
    let voice = SpeakerVoice::new(cfg.speaker);
    let mut seqs = voice.features(&voice.enrollment_set(cfg.target, cfg.shots));
    seqs.extend(voice.features(&voice.counter_set(cfg.counter_shots)));
    let (feats, labels) = batch_tensors(&seqs);
    let mut state = train_state_from(&dequantize_params(base));
    // freeze w_x / w_h / b: snapshot once, restore values AND moments
    // after every step so Adam never accumulates drift into them
    let frozen: Vec<Tensor> = state.params[..3].to_vec();
    let mut final_loss = 0.0;
    for _ in 0..cfg.steps {
        final_loss = backend.train_step(&mut state, &feats, &labels, cfg.delta_th, cfg.lr)?;
        for (i, t) in frozen.iter().enumerate() {
            state.params[i] = t.clone();
            state.m[i] = Tensor::zeros(&t.shape);
            state.v[i] = Tensor::zeros(&t.shape);
        }
    }
    let params = gru::quantize_params(&float_params_from_tensors(&state.params));
    Ok(Enrolled { params, steps: cfg.steps, final_loss })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;
    use crate::util::prng::Pcg;

    fn rng_quant(seed: u64) -> QuantParams {
        let mut rng = Pcg::new(seed);
        let mut q = QuantParams::zeroed();
        q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
        q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
        q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
        q
    }

    fn tiny_cfg() -> EnrollConfig {
        EnrollConfig { shots: 2, counter_shots: 2, steps: 2, ..EnrollConfig::design_point(7, 11) }
    }

    #[test]
    fn dequantize_quantize_round_trips_exactly() {
        // integer → float → integer preserves every value exactly (each
        // i8/2^w_frac and Q8.8/256 is representable in f32). quantize_params
        // may re-select a finer w_frac for the same values, so compare in
        // value space; the image is stable from the second trip onward.
        let q = rng_quant(3);
        let rt = gru::quantize_params(&dequantize_params(&q));
        let (a, b) = (dequantize_params(&q), dequantize_params(&rt));
        assert_eq!(a.w_x, b.w_x);
        assert_eq!(a.w_h, b.w_h);
        assert_eq!(a.b, b.b);
        assert_eq!(a.w_fc, b.w_fc);
        assert_eq!(a.b_fc, b.b_fc);
        let rt2 = gru::quantize_params(&dequantize_params(&rt));
        assert_eq!(gru::to_sram_image(&rt2), gru::to_sram_image(&rt));
    }

    #[test]
    fn train_state_matches_canonical_abi() {
        let st = train_state_from(&dequantize_params(&rng_quant(1)));
        let m = crate::runtime::Manifest::native(1);
        assert_eq!(st.params.len(), m.param_order.len());
        for (t, (_, shape)) in st.params.iter().zip(&m.param_shapes) {
            assert_eq!(&t.shape, shape);
        }
        assert_eq!(st.step, 0.0);
    }

    #[test]
    fn config_validation_rejects_bad_ranges() {
        assert!(EnrollConfig::design_point(1, 11).validate().is_ok());
        assert!(EnrollConfig::design_point(1, 0).validate().is_err(), "silence not enrollable");
        assert!(EnrollConfig::design_point(1, 12).validate().is_err());
        let mut c = EnrollConfig::design_point(1, 11);
        c.shots = MAX_SHOTS + 1;
        assert!(c.validate().is_err());
        c.shots = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn enrollment_freezes_recurrent_weights() {
        let backend = NativeBackend::new();
        let base = rng_quant(5);
        let out = few_shot(&backend, &base, &tiny_cfg()).expect("enroll");
        // recurrent params value-identical (w_frac may differ between the
        // images — compare dequantised); FC head moved
        let (a, b) = (dequantize_params(&out.params), dequantize_params(&base));
        assert_eq!(a.w_x, b.w_x, "w_x must stay frozen");
        assert_eq!(a.w_h, b.w_h, "w_h must stay frozen");
        assert_eq!(a.b, b.b, "gate biases must stay frozen");
        assert!(
            a.w_fc != b.w_fc || a.b_fc != b.b_fc,
            "FC head never moved — enrollment was a no-op"
        );
    }

    #[test]
    fn enrollment_is_deterministic_per_seed() {
        let backend = NativeBackend::new();
        let base = rng_quant(5);
        let a = few_shot(&backend, &base, &tiny_cfg()).expect("enroll");
        let b = few_shot(&backend, &base, &tiny_cfg()).expect("enroll");
        assert_eq!(gru::to_sram_image(&a.params), gru::to_sram_image(&b.params));
    }
}

//! Deterministic synthetic speakers for enrollment experiments.
//!
//! The crate's corpus ([`crate::dataset::Dataset`]) hashes `(split,
//! index)` so train/test never overlap; a [`SpeakerVoice`] does the same
//! trick one level up: it derives every utterance from `(speaker seed,
//! class, index)` on a dedicated PRNG stream, giving each synthetic
//! speaker a reproducible, corpus-disjoint set of recordings. Enrollment
//! shots, held-out evaluation clips and counter-examples live in disjoint
//! index ranges, so "train on K shots, evaluate on a held-out track"
//! is deterministic and leak-free by construction.
//!
//! Featurization reuses [`Dataset::features_for`] — the fixed-point FEx
//! twin — so enrollment sees exactly the Q8.8 activations the chip
//! produces at inference (the same train/deploy-gap closure the base
//! trainer relies on).

use crate::audio::{quantize_12b, synth_utterance};
use crate::dataset::{Dataset, FeatSeq, Utterance};
use crate::fex::{Fex, FexConfig};
use crate::util::prng::Pcg;

/// PRNG stream id separating speaker synthesis from the train/test corpus
/// streams (`"SPKR"`).
const SPEAKER_STREAM: u64 = 0x5350_4b52;

/// Index base for held-out evaluation clips (disjoint from enrollment
/// shots at indices `0..k`).
pub const HOLDOUT_BASE: usize = 0x1000;

/// Index base for counter-example clips (silence/unknown fillers mixed
/// into the enrollment batch to keep the FC head from collapsing onto the
/// target class).
pub const COUNTER_BASE: usize = 0x2000;

/// One deterministic synthetic speaker, identified by a seed.
#[derive(Debug, Clone, Copy)]
pub struct SpeakerVoice {
    /// Speaker identity: same seed, same voice, same recordings.
    pub seed: u64,
}

impl SpeakerVoice {
    /// A speaker identified by `seed`.
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }

    /// The `index`-th recording of `class` by this speaker (12-bit audio).
    /// Deterministic and disjoint across `(seed, class, index)`.
    pub fn utterance(&self, class: usize, index: usize) -> Utterance {
        let mix = (class as u64)
            .wrapping_mul(0xA076_1D64_78BD_642F)
            .wrapping_add((index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Pcg::with_stream(self.seed ^ mix, SPEAKER_STREAM);
        let audio = synth_utterance(class, &mut rng);
        Utterance { label: class, audio12: quantize_12b(&audio) }
    }

    /// The K enrollment shots for `class` (indices `0..k`).
    pub fn enrollment_set(&self, class: usize, k: usize) -> Vec<Utterance> {
        (0..k).map(|i| self.utterance(class, i)).collect()
    }

    /// `n` held-out evaluation clips for `class`, disjoint from every
    /// enrollment shot (indices `HOLDOUT_BASE..`).
    pub fn holdout(&self, class: usize, n: usize) -> Vec<Utterance> {
        (0..n).map(|i| self.utterance(class, HOLDOUT_BASE + i)).collect()
    }

    /// `n` counter-example clips alternating silence (class 0) and the
    /// unknown-word pool (class 1), indices `COUNTER_BASE..`.
    pub fn counter_set(&self, n: usize) -> Vec<Utterance> {
        (0..n).map(|i| self.utterance(i % 2, COUNTER_BASE + i)).collect()
    }

    /// Featurize recordings through the fixed-point FEx twin (fresh FEx,
    /// reset between utterances by [`Dataset::features_for`]).
    pub fn features(&self, utts: &[Utterance]) -> Vec<FeatSeq> {
        let ds = Dataset::new(self.seed);
        let mut fex = Fex::new(FexConfig::design_point());
        utts.iter().map(|u| ds.features_for(&mut fex, u)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::Split;

    #[test]
    fn speaker_is_deterministic() {
        let a = SpeakerVoice::new(7).utterance(11, 3);
        let b = SpeakerVoice::new(7).utterance(11, 3);
        assert_eq!(a.audio12, b.audio12);
        assert_eq!(a.label, 11);
    }

    #[test]
    fn speakers_classes_and_indices_are_disjoint() {
        let v = SpeakerVoice::new(7);
        assert_ne!(v.utterance(11, 0).audio12, v.utterance(11, 1).audio12);
        assert_ne!(v.utterance(11, 0).audio12, v.utterance(10, 0).audio12);
        assert_ne!(
            v.utterance(11, 0).audio12,
            SpeakerVoice::new(8).utterance(11, 0).audio12
        );
    }

    #[test]
    fn shots_holdout_and_counters_do_not_overlap() {
        let v = SpeakerVoice::new(3);
        let shots = v.enrollment_set(11, 4);
        let held = v.holdout(11, 4);
        let counters = v.counter_set(4);
        assert_eq!(shots.len(), 4);
        assert_eq!(held.len(), 4);
        for s in &shots {
            for h in &held {
                assert_ne!(s.audio12, h.audio12, "holdout leaked into enrollment");
            }
        }
        assert!(counters.iter().all(|c| c.label <= 1), "counters are silence/unknown");
    }

    #[test]
    fn speaker_clips_are_disjoint_from_the_corpus() {
        let v = SpeakerVoice::new(42);
        let ds = Dataset::new(42);
        let speaker = v.utterance(11, 0);
        for i in 0..24 {
            let corpus = ds.utterance(Split::Train, i);
            if corpus.label == speaker.label {
                assert_ne!(corpus.audio12, speaker.audio12);
            }
        }
    }

    #[test]
    fn features_match_chip_convention() {
        let v = SpeakerVoice::new(5);
        let feats = v.features(&v.enrollment_set(11, 1));
        assert_eq!(feats.len(), 1);
        assert_eq!(feats[0].label, 11);
        assert_eq!(feats[0].feats.len(), crate::FRAMES_PER_DECISION);
        for f in &feats[0].feats {
            for &q in f.iter() {
                assert!((0..512).contains(&(q as i64)), "Q8.8 activation {q} out of range");
            }
        }
    }
}

//! Per-user customization: few-shot enrollment, versioned weights,
//! epoch-fenced hot-swap.
//!
//! The paper's IC ships one fixed model; this subsystem turns the serving
//! stack multi-tenant, following the on-chip-learning customization line
//! of Chiang et al. (PAPERS.md). Three pieces:
//!
//! * [`enroll`] — few-shot enrollment: K ≤ 8 recordings of a synthetic
//!   speaker ([`speaker::SpeakerVoice`]) fine-tune **only the FC output
//!   layer** through [`Backend::train_step`](crate::runtime::Backend)
//!   (recurrent weights frozen, Adam moments restored every step), then
//!   requantise through the chip's integer path. Deterministic end to
//!   end: same seed → byte-identical SRAM image.
//! * [`registry`] — content-hashed [`WeightVersion`] ids over the SRAM
//!   word image, parent lineage, a bounded LRU of resident versions with
//!   live-session pinning, and typed [`RegistryError`]s that feed the
//!   crate [`Error`](crate::Error) tree.
//! * epoch-fenced hot-swap — sessions reference weights by version; the
//!   [`Coordinator`](crate::coordinator::Coordinator) installs a new
//!   version at a **frame boundary** without dropping the stream
//!   ([`crate::coordinator::Coordinator::swap_weights`]). Old weights
//!   drive frame N, new weights frame N+1; the ΔFIFO is empty and no MAC
//!   is in flight between frames, so no torn read is possible (DESIGN.md
//!   §14 explains why the saturating-arith evaluation order makes
//!   *mid-frame* swaps unsafe).
//!
//! The registry and trainer are control-plane code; only the fence
//! install ([`crate::chip::KwsChip::swap_weights`]) touches the frame
//! path, and it runs strictly between frames.

pub mod enroll;
pub mod registry;
pub mod speaker;

pub use enroll::{batch_tensors, dequantize_params, few_shot, train_state_from};
pub use enroll::{EnrollConfig, Enrolled, MAX_SHOTS};
pub use registry::{RegistryError, WeightRegistry, WeightVersion};
pub use speaker::SpeakerVoice;

//! Fixed-point arithmetic substrate shared by the FEx and ΔRNN twins.
//!
//! Everything the chip computes is integer arithmetic on narrow
//! two's-complement words. This module provides the exact primitives the
//! datapaths are built from — width-parametric saturation, rounding shifts,
//! saturating multiply-accumulate — together with [`QFormat`], a descriptor
//! for signed Qm.n formats used to quantise/de-quantise at the float
//! boundary (filter design, feature logging, weight import).
//!
//! Conventions (documented here once, relied on everywhere):
//! * all raw values are `i64` carrying a two's-complement word of
//!   `bits <= 48`; the *format* (position of the binary point) is tracked by
//!   the caller or a [`QFormat`];
//! * right shifts round **half-away-from-zero** (`round_shift`) where the
//!   chip has a rounding stage and **floor** (`>>`, arithmetic) where it
//!   truncates — each call site states which it models;
//! * overflow always saturates (the chip's datapaths clamp; wrap-around
//!   would be a functional bug in silicon too).

pub mod q;

pub use q::QFormat;

/// Largest value representable in a signed word of `bits`.
#[inline]
pub const fn max_val(bits: u32) -> i64 {
    (1i64 << (bits - 1)) - 1
}

/// Smallest (most negative) value representable in a signed word of `bits`.
#[inline]
pub const fn min_val(bits: u32) -> i64 {
    -(1i64 << (bits - 1))
}

/// Saturate `v` into a signed `bits`-wide word.
#[inline]
pub fn sat(v: i64, bits: u32) -> i64 {
    debug_assert!((2..=63).contains(&bits));
    v.clamp(min_val(bits), max_val(bits))
}

/// True iff `v` already fits a signed `bits`-wide word.
#[inline]
pub fn fits(v: i64, bits: u32) -> bool {
    v >= min_val(bits) && v <= max_val(bits)
}

/// Arithmetic right shift with round-half-away-from-zero.
///
/// This is the rounding the chip's post-multiply normalisation stages use:
/// add half an LSB in the direction of the sign, then floor-shift.
///
/// Total over all of `i64`, not just the documented ≤48-bit domain: the
/// negative branch runs on a widened i128 magnitude, because negating an
/// `i64` near `i64::MIN` (the old `-((-v + half) >> sh)`) overflows —
/// a debug panic / release wrap-around for inputs the datapaths can
/// legally produce at the top of the guard-bit range. For `sh >= 1` the
/// result magnitude is at most `2^62 + 1`, so the narrowing cast back is
/// exact.
#[inline]
pub fn round_shift(v: i64, sh: u32) -> i64 {
    debug_assert!(sh <= 63, "round_shift by {sh}");
    if sh == 0 {
        return v;
    }
    let half = 1i128 << (sh - 1);
    let wide = v as i128;
    let r = if wide >= 0 { (wide + half) >> sh } else { -((-wide + half) >> sh) };
    r as i64
}

/// Truncating (floor) arithmetic right shift — what a bare wire-shift does.
#[inline]
pub fn floor_shift(v: i64, sh: u32) -> i64 {
    v >> sh
}

/// Saturating fixed-point multiply: `(a * b) >> sh`, rounded, saturated to
/// `out_bits`. Matches a `wa x wb` hardware multiplier feeding a rounding
/// normaliser and a clamp.
#[inline]
pub fn mul_shift_sat(a: i64, b: i64, sh: u32, out_bits: u32) -> i64 {
    sat(round_shift(a * b, sh), out_bits)
}

/// Saturating add into an `out_bits` accumulator.
#[inline]
pub fn add_sat(a: i64, b: i64, out_bits: u32) -> i64 {
    sat(a + b, out_bits)
}

/// Count of significant magnitude bits (position of MSB), `v > 0`.
/// `msb_pos(1) == 0`, `msb_pos(32768) == 15`.
#[inline]
pub fn msb_pos(v: i64) -> u32 {
    debug_assert!(v > 0);
    63 - v.leading_zeros()
}

/// Hardware log2 via priority encoder + linear mantissa interpolation.
///
/// Input: `v > 0` (integer). Output: `log2(v)` in Q`frac_bits` fixed point.
/// This is the classic LUT-free log the FEx's compression stage uses; the
/// max interpolation error is ~0.086 bits, well under the feature LSB.
#[inline]
pub fn log2_linear(v: i64, frac_bits: u32) -> i64 {
    debug_assert!(v > 0);
    let p = msb_pos(v);
    let mant = v - (1i64 << p); // v - 2^p, in [0, 2^p)
    let frac = if p >= frac_bits {
        mant >> (p - frac_bits)
    } else {
        mant << (frac_bits - p)
    };
    ((p as i64) << frac_bits) + frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_min_val() {
        assert_eq!(max_val(8), 127);
        assert_eq!(min_val(8), -128);
        assert_eq!(max_val(12), 2047);
        assert_eq!(min_val(12), -2048);
        assert_eq!(max_val(16), 32767);
    }

    #[test]
    fn sat_clamps_both_sides() {
        assert_eq!(sat(1000, 8), 127);
        assert_eq!(sat(-1000, 8), -128);
        assert_eq!(sat(100, 8), 100);
        assert_eq!(sat(-128, 8), -128);
        assert_eq!(sat(127, 8), 127);
    }

    #[test]
    fn fits_boundaries() {
        assert!(fits(127, 8));
        assert!(!fits(128, 8));
        assert!(fits(-128, 8));
        assert!(!fits(-129, 8));
    }

    #[test]
    fn round_shift_half_away() {
        assert_eq!(round_shift(5, 1), 3); // 2.5 -> 3
        assert_eq!(round_shift(-5, 1), -3); // -2.5 -> -3
        assert_eq!(round_shift(4, 1), 2);
        assert_eq!(round_shift(-4, 1), -2);
        assert_eq!(round_shift(7, 2), 2); // 1.75 -> 2
        assert_eq!(round_shift(100, 0), 100);
    }

    #[test]
    fn round_shift_total_at_i64_min() {
        // the exact boundary the pre-fix negate-first implementation
        // overflowed on (`-i64::MIN` does not exist): these used to panic
        // in debug builds and wrap in release
        assert_eq!(round_shift(i64::MIN, 0), i64::MIN);
        assert_eq!(round_shift(i64::MIN, 1), -(1i64 << 62));
        assert_eq!(round_shift(i64::MIN + 1, 1), -(1i64 << 62));
        assert_eq!(round_shift(i64::MIN, 8), -(1i64 << 55));
        assert_eq!(round_shift(i64::MIN, 62), -2);
        assert_eq!(round_shift(i64::MIN, 63), -1);
        // positive rail for symmetry: (2^63 - 1 + half) >> sh rounds up
        assert_eq!(round_shift(i64::MAX, 1), 1i64 << 62);
        assert_eq!(round_shift(i64::MAX, 63), 1);
        // documented 48-bit domain edges stay exact
        assert_eq!(round_shift(min_val(48), 14), -(1i64 << 33));
        assert_eq!(round_shift(max_val(48), 14), 1i64 << 33);
    }

    /// Independent i128 reference: round-half-away-from-zero is
    /// sign(v) * floor((|v| + 2^(sh-1)) / 2^sh), computed on unsigned
    /// magnitudes so no edge of `(v, sh)` can overflow.
    fn round_shift_ref(v: i64, sh: u32) -> i64 {
        if sh == 0 {
            return v;
        }
        let mag = (v as i128).unsigned_abs();
        let r = ((mag + (1u128 << (sh - 1))) >> sh) as i64;
        if v < 0 {
            -r
        } else {
            r
        }
    }

    #[test]
    fn round_shift_matches_i128_reference_on_edges() {
        use crate::util::check::forall;
        let edges: [i64; 12] = [
            i64::MIN,
            i64::MIN + 1,
            min_val(48),
            min_val(48) + 1,
            -1,
            0,
            1,
            max_val(48) - 1,
            max_val(48),
            i64::MAX - 1,
            i64::MAX,
            -(1i64 << 33),
        ];
        forall(64, |rng| {
            // half the cases pin v to a domain edge, half draw uniformly;
            // sh sweeps the full legal 0..=63 range either way
            let v = if rng.uniform() < 0.5 {
                edges[rng.below(edges.len() as u64) as usize]
            } else {
                rng.next_u64() as i64
            };
            let sh = rng.below(64) as u32;
            assert_eq!(round_shift(v, sh), round_shift_ref(v, sh), "v={v} sh={sh}");
        });
    }

    #[test]
    fn floor_shift_truncates_toward_neg_inf() {
        assert_eq!(floor_shift(5, 1), 2);
        assert_eq!(floor_shift(-5, 1), -3);
    }

    #[test]
    fn mul_shift_sat_basic() {
        // 0.5 * 0.5 in Q1.14: 8192*8192 >> 14 = 4096
        assert_eq!(mul_shift_sat(8192, 8192, 14, 16), 4096);
        // saturation engages
        assert_eq!(mul_shift_sat(32767, 32767, 14, 16), 32767);
        assert_eq!(mul_shift_sat(-32768, 32767, 14, 16), -32768);
    }

    #[test]
    fn msb_positions() {
        assert_eq!(msb_pos(1), 0);
        assert_eq!(msb_pos(2), 1);
        assert_eq!(msb_pos(3), 1);
        assert_eq!(msb_pos(32768), 15);
        assert_eq!(msb_pos((1 << 27) + 5), 27);
    }

    #[test]
    fn log2_linear_exact_at_powers() {
        for p in 0..40u32 {
            assert_eq!(log2_linear(1i64 << p, 12), (p as i64) << 12);
        }
    }

    #[test]
    fn log2_linear_error_bound() {
        // linear-interp log2 error <= ~0.086 bits
        for v in [3i64, 5, 7, 100, 1000, 12345, 99999, 5_000_000] {
            let approx = log2_linear(v, 12) as f64 / 4096.0;
            let exact = (v as f64).log2();
            assert!((approx - exact).abs() < 0.09, "v={v} {approx} {exact}");
        }
    }

    #[test]
    fn log2_linear_monotone() {
        let mut prev = -1;
        for v in 1..5000i64 {
            let l = log2_linear(v, 12);
            assert!(l >= prev, "non-monotone at {v}");
            prev = l;
        }
    }
}

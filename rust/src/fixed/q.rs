//! Signed Qm.n format descriptor: quantisation at the float boundary.
//!
//! A [`QFormat`] describes a signed fixed-point word of `bits` total bits
//! with `frac` fractional bits (so `int = bits - 1 - frac` integer bits).
//! It is used wherever float values enter or leave the bit-accurate domain:
//! quantising designed filter coefficients, trained weights, and thresholds,
//! and de-quantising features/states for logging and comparison against the
//! float references.

/// A signed fixed-point format: `bits` total, `frac` fractional bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QFormat {
    /// total word width including sign bit (2..=48)
    pub bits: u32,
    /// fractional bits (binary point position)
    pub frac: u32,
}

impl QFormat {
    pub const fn new(bits: u32, frac: u32) -> Self {
        Self { bits, frac }
    }

    /// The resolution (value of one LSB).
    pub fn lsb(&self) -> f64 {
        (self.frac as f64).exp2().recip()
    }

    /// Largest representable real value.
    pub fn max_value(&self) -> f64 {
        super::max_val(self.bits) as f64 * self.lsb()
    }

    /// Smallest representable real value.
    pub fn min_value(&self) -> f64 {
        super::min_val(self.bits) as f64 * self.lsb()
    }

    /// Quantise a float to the nearest representable raw word (saturating).
    pub fn quantize(&self, v: f64) -> i64 {
        let scaled = (v * (self.frac as f64).exp2()).round();
        super::sat(scaled as i64, self.bits)
    }

    /// De-quantise a raw word back to float.
    pub fn dequantize(&self, raw: i64) -> f64 {
        raw as f64 * self.lsb()
    }

    /// Round-trip quantisation error for `v` (absolute).
    pub fn error(&self, v: f64) -> f64 {
        (self.dequantize(self.quantize(v)) - v).abs()
    }

    /// Can `v` be represented without saturating?
    pub fn represents(&self, v: f64) -> bool {
        v <= self.max_value() && v >= self.min_value()
    }

    /// The highest-resolution Q format with `bits` total bits that still
    /// represents ±`max_abs` without saturating (used by the
    /// mixed-precision coefficient search).
    pub fn fit(bits: u32, max_abs: f64) -> Self {
        for frac in (1..bits).rev() {
            let q = Self { bits, frac };
            if q.max_value() >= max_abs {
                return q;
            }
        }
        Self { bits, frac: 0 }
    }
}

/// Chip-wide canonical formats (see DESIGN.md §6).
pub mod formats {
    use super::QFormat;

    /// Audio input: 12-bit signed, Q1.11, [-1, 1).
    pub const AUDIO: QFormat = QFormat::new(12, 11);
    /// FEx internal signal path: 16-bit Q1.15.
    pub const SIGNAL: QFormat = QFormat::new(16, 15);
    /// FEx feature output: 12-bit unsigned-range Q0.12-ish (we keep sign bit).
    pub const FEATURE: QFormat = QFormat::new(13, 12);
    /// Biquad numerator (b) coefficients: 12-bit, Q0.11 (|b0| < 1).
    pub const COEFF_B: QFormat = QFormat::new(12, 11);
    /// Biquad denominator (a) coefficients: 8-bit, Q1.6 (|a1| < 2 strictly,
    /// since |a1| = 2|cos w0| / (1+alpha) < 2 and |a2| < 1).
    pub const COEFF_A: QFormat = QFormat::new(8, 6);
    /// ΔRNN activations / hidden state: 16-bit Q8.8.
    pub const ACT: QFormat = QFormat::new(16, 8);
    /// ΔRNN weights: 8-bit, Q1.6.
    pub const WEIGHT: QFormat = QFormat::new(8, 6);
}

#[cfg(test)]
mod tests {
    use super::formats::*;
    use super::*;

    #[test]
    fn lsb_and_ranges() {
        let q = QFormat::new(12, 11);
        assert_eq!(q.lsb(), 1.0 / 2048.0);
        assert!((q.max_value() - (2047.0 / 2048.0)).abs() < 1e-12);
        assert_eq!(q.min_value(), -1.0);
    }

    #[test]
    fn quantize_roundtrip_within_lsb() {
        let q = QFormat::new(16, 15);
        for v in [-0.999, -0.5, -0.001, 0.0, 0.3333, 0.9999] {
            let err = q.error(v);
            assert!(err <= q.lsb() / 2.0 + 1e-12, "v={v} err={err}");
        }
    }

    #[test]
    fn quantize_saturates() {
        let q = QFormat::new(8, 5); // Q2.5, range [-4, 3.96875]
        assert_eq!(q.quantize(10.0), 127);
        assert_eq!(q.quantize(-10.0), -128);
        assert_eq!(q.dequantize(q.quantize(10.0)), 127.0 / 32.0);
    }

    #[test]
    fn fit_picks_highest_resolution() {
        let q = QFormat::fit(8, 1.93);
        assert_eq!(q.frac, 6); // Q1.6: max 1.984 >= 1.93
        let q = QFormat::fit(12, 0.49);
        assert_eq!(q.frac, 11); // Q0.11: max 0.9995
        let q = QFormat::fit(8, 7.5);
        assert_eq!(q.frac, 4); // Q3.4: max 7.9375
    }

    #[test]
    fn fit_never_underflows_width() {
        let q = QFormat::fit(8, 1e9);
        assert_eq!(q.frac, 0);
    }

    #[test]
    fn canonical_formats_sane() {
        assert!(AUDIO.represents(0.999));
        assert!(!AUDIO.represents(1.01));
        assert!(COEFF_A.represents(-1.99));
        assert!(COEFF_B.represents(0.49));
        assert!(ACT.represents(127.9));
        assert!(WEIGHT.represents(1.98));
        assert_eq!(SIGNAL.quantize(0.5), 16384);
    }

    #[test]
    fn dequantize_matches_manual() {
        assert_eq!(ACT.dequantize(256), 1.0);
        assert_eq!(ACT.quantize(0.2), 51); // the paper's Δ_TH = 0.2 design point
        assert_eq!(WEIGHT.dequantize(64), 1.0);
    }
}

//! Pure-Rust execution backend: the ΔGRU forward and its full training
//! step (BPTT through the delta recurrence) with no external runtime.
//!
//! Mirrors `python/compile/model.py` + `kernels/ref.py` semantics exactly:
//!
//! * forward — per frame, input/hidden deltas are hard-thresholded
//!   (`|d| >= Θ` fires; fired lanes refresh their reference), fired deltas
//!   accumulate into the four gate pre-activation memories, gates use the
//!   reset-after GRU formulation, and the decision is the mean of per-frame
//!   FC logits after [`WARMUP`] frames;
//! * loss — softmax cross-entropy over the averaged logits plus
//!   [`SPARSITY_BETA`] × the mean L1 of the *raw* (pre-threshold) deltas,
//!   the DeltaRNN sparsity regulariser;
//! * backward — reverse-time BPTT with the straight-through estimator
//!   through the threshold (gradient of the masked delta w.r.t. the raw
//!   delta is identity; the firing mask itself is treated as constant,
//!   and reference updates route gradients through the fired branch);
//! * update — Adam with global-norm gradient clipping, matching the
//!   hyper-parameters in `model.py` (`ADAM_B1/B2/EPS`, `GRAD_CLIP`).

use anyhow::bail;

use super::{Backend, ForwardOut, IntTensor, Manifest, Tensor, TrainState};

/// Frames excluded from the posterior average (model.py `WARMUP`).
pub const WARMUP: usize = 4;
/// Weight of the delta-L1 sparsity penalty (model.py `SPARSITY_BETA`).
pub const SPARSITY_BETA: f32 = 2e-4;

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;
const GRAD_CLIP: f32 = 5.0;

/// The native backend. Stateless apart from its manifest; `batch` is only
/// the *nominal* batch (any batch size executes).
pub struct NativeBackend {
    manifest: Manifest,
}

impl NativeBackend {
    pub fn new() -> Self {
        Self::with_batch(16)
    }

    pub fn with_batch(batch: usize) -> Self {
        Self { manifest: Manifest::native(batch) }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

/// Model dimensions derived from the parameter tensors themselves.
#[derive(Debug, Clone, Copy)]
struct Dims {
    c: usize,
    h: usize,
    k: usize,
}

impl Dims {
    fn g(&self) -> usize {
        3 * self.h
    }
}

fn check_params(params: &[Tensor]) -> crate::Result<Dims> {
    if params.len() != 5 {
        bail!("expected 5 parameter tensors (w_x, w_h, b, w_fc, b_fc), got {}", params.len());
    }
    let (w_x, w_h, b, w_fc, b_fc) = (&params[0], &params[1], &params[2], &params[3], &params[4]);
    if w_x.shape.len() != 2 || w_x.shape[1] % 3 != 0 {
        bail!("w_x must be [C, 3H], got {:?}", w_x.shape);
    }
    let c = w_x.shape[0];
    let h = w_x.shape[1] / 3;
    if w_h.shape != vec![h, 3 * h] {
        bail!("w_h must be [{h}, {}], got {:?}", 3 * h, w_h.shape);
    }
    if b.data.len() != 3 * h {
        bail!("b must have {} elements, got {}", 3 * h, b.data.len());
    }
    if w_fc.shape.len() != 2 || w_fc.shape[0] != h {
        bail!("w_fc must be [{h}, K], got {:?}", w_fc.shape);
    }
    let k = w_fc.shape[1];
    if b_fc.data.len() != k {
        bail!("b_fc must have {k} elements, got {}", b_fc.data.len());
    }
    Ok(Dims { c, h, k })
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

fn sign(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// Everything the backward pass needs from one utterance's forward run.
struct Tape {
    /// raw input deltas a_t = x_t - x_ref (flattened [T, C])
    ax: Vec<f32>,
    /// raw hidden deltas e_t = h_{t-1} - h_ref ([T, H])
    eh: Vec<f32>,
    /// firing masks (|d| >= Θ)
    fire_x: Vec<bool>,
    fire_h: Vec<bool>,
    /// gate activations ([T, H] each)
    r: Vec<f32>,
    u: Vec<f32>,
    cv: Vec<f32>,
    /// candidate recurrent memory *after* the step-t update ([T, H])
    m_hc: Vec<f32>,
    /// hidden trajectory: h_all[t*H..] is h_{t-1}; h_all[(t+1)*H..] is h_t
    h_all: Vec<f32>,
    /// mean per-frame raw-delta L1 (the sparsity penalty term)
    raw_l1_mean: f32,
    /// averaged FC logits ([K])
    logits: Vec<f32>,
    /// fraction of silent lanes
    sparsity: f32,
}

/// One utterance forward. `record` controls whether the tape carries the
/// per-step activations the backward pass needs (training) or only the
/// outputs (inference).
fn forward_utt(params: &[Tensor], feats: &[f32], t_frames: usize, d: Dims, delta_th: f32,
               record: bool) -> Tape {
    let (c, h, k, g) = (d.c, d.h, d.k, d.g());
    let w_x = &params[0].data;
    let w_h = &params[1].data;
    let b = &params[2].data;
    let w_fc = &params[3].data;
    let b_fc = &params[4].data;

    let rec = if record { t_frames } else { 0 };
    let mut tape = Tape {
        ax: vec![0.0; rec * c],
        eh: vec![0.0; rec * h],
        fire_x: vec![false; rec * c],
        fire_h: vec![false; rec * h],
        r: vec![0.0; rec * h],
        u: vec![0.0; rec * h],
        cv: vec![0.0; rec * h],
        m_hc: vec![0.0; rec * h],
        h_all: vec![0.0; (rec + 1) * h],
        raw_l1_mean: 0.0,
        logits: vec![0.0; k],
        sparsity: 0.0,
    };

    let mut x_ref = vec![0f32; c];
    let mut h_ref = vec![0f32; h];
    let mut hv = vec![0f32; h];
    // gate pre-activation memories: [m_r | m_u | m_xc | m_hc]
    let mut m = vec![0f32; 4 * h];
    let warmup = WARMUP.min(t_frames.saturating_sub(1));
    let mut fired_frac_sum = 0f64;
    let mut l1_sum = 0f64;
    let mut counted = 0usize;

    for t in 0..t_frames {
        let x = &feats[t * c..(t + 1) * c];
        let mut fired = 0usize;
        // --- Δ-encode + accumulate, input side --------------------------
        for i in 0..c {
            let a = x[i] - x_ref[i];
            l1_sum += a.abs() as f64;
            let fire = a.abs() >= delta_th;
            if record {
                tape.ax[t * c + i] = a;
                tape.fire_x[t * c + i] = fire;
            }
            if fire {
                x_ref[i] = x[i];
                if a != 0.0 {
                    fired += 1;
                    let row = &w_x[i * g..(i + 1) * g];
                    for j in 0..h {
                        m[j] += a * row[j];
                        m[h + j] += a * row[h + j];
                        m[2 * h + j] += a * row[2 * h + j];
                    }
                }
            }
        }
        // --- Δ-encode + accumulate, hidden side -------------------------
        for l in 0..h {
            let e = hv[l] - h_ref[l];
            l1_sum += e.abs() as f64;
            let fire = e.abs() >= delta_th;
            if record {
                tape.eh[t * h + l] = e;
                tape.fire_h[t * h + l] = fire;
            }
            if fire {
                h_ref[l] = hv[l];
                if e != 0.0 {
                    fired += 1;
                    let row = &w_h[l * g..(l + 1) * g];
                    for j in 0..h {
                        m[j] += e * row[j];
                        m[h + j] += e * row[h + j];
                        m[3 * h + j] += e * row[2 * h + j];
                    }
                }
            }
        }
        // --- gates + state update ---------------------------------------
        for j in 0..h {
            let r = sigmoid(m[j] + b[j]);
            let u = sigmoid(m[h + j] + b[h + j]);
            let cv = (m[2 * h + j] + r * m[3 * h + j] + b[2 * h + j]).tanh();
            if record {
                tape.r[t * h + j] = r;
                tape.u[t * h + j] = u;
                tape.cv[t * h + j] = cv;
                tape.m_hc[t * h + j] = m[3 * h + j];
            }
            hv[j] = u * hv[j] + (1.0 - u) * cv;
        }
        if record {
            tape.h_all[(t + 1) * h..(t + 2) * h].copy_from_slice(&hv);
        }
        fired_frac_sum += fired as f64 / (c + h) as f64;
        // --- per-frame FC readout, posterior-averaged -------------------
        if t >= warmup {
            for kk in 0..k {
                let mut l = b_fc[kk];
                for j in 0..h {
                    l += hv[j] * w_fc[j * k + kk];
                }
                tape.logits[kk] += l;
            }
            counted += 1;
        }
    }
    if counted > 0 {
        for l in tape.logits.iter_mut() {
            *l /= counted as f32;
        }
    }
    if t_frames > 0 {
        tape.sparsity = (1.0 - fired_frac_sum / t_frames as f64) as f32;
        tape.raw_l1_mean = (l1_sum / t_frames as f64) as f32;
    }
    tape
}

/// Cross-entropy over averaged logits: returns (loss, softmax probs).
fn softmax_ce(logits: &[f32], label: usize) -> (f32, Vec<f32>) {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| (l - max).exp()).collect();
    let z: f32 = exps.iter().sum();
    let probs: Vec<f32> = exps.iter().map(|&e| e / z).collect();
    let ce = -(probs[label].max(1e-30)).ln();
    (ce, probs)
}

/// Batch loss without gradients (used by the finite-difference tests).
pub(crate) fn batch_loss(
    params: &[Tensor],
    feats: &Tensor,
    labels: &IntTensor,
    delta_th: f32,
) -> crate::Result<f32> {
    let d = check_params(params)?;
    let (bsz, t) = (feats.shape[0], feats.shape[1]);
    let mut ce_sum = 0f32;
    let mut l1_sum = 0f32;
    for bi in 0..bsz {
        let f = &feats.data[bi * t * d.c..(bi + 1) * t * d.c];
        let tape = forward_utt(params, f, t, d, delta_th, false);
        let (ce, _) = softmax_ce(&tape.logits, labels.data[bi] as usize);
        ce_sum += ce;
        l1_sum += tape.raw_l1_mean;
    }
    Ok(ce_sum / bsz as f32 + SPARSITY_BETA * l1_sum / bsz as f32)
}

impl Backend for NativeBackend {
    fn name(&self) -> String {
        "native".to_string()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn supports_batch(&self, b: usize) -> bool {
        b > 0
    }

    fn forward(&self, params: &[Tensor], feats: &Tensor, delta_th: f32)
        -> crate::Result<ForwardOut> {
        let d = check_params(params)?;
        if feats.shape.len() != 3 || feats.shape[2] != d.c {
            bail!("feats must be [B, T, {}], got {:?}", d.c, feats.shape);
        }
        let (bsz, t) = (feats.shape[0], feats.shape[1]);
        let mut logits = vec![0f32; bsz * d.k];
        let mut sparsity = vec![0f32; bsz];
        for bi in 0..bsz {
            let f = &feats.data[bi * t * d.c..(bi + 1) * t * d.c];
            let tape = forward_utt(params, f, t, d, delta_th, false);
            logits[bi * d.k..(bi + 1) * d.k].copy_from_slice(&tape.logits);
            sparsity[bi] = tape.sparsity;
        }
        Ok(ForwardOut {
            logits: Tensor::new(vec![bsz, d.k], logits),
            sparsity: Tensor::new(vec![bsz], sparsity),
        })
    }

    fn train_step(
        &self,
        state: &mut TrainState,
        feats: &Tensor,
        labels: &IntTensor,
        delta_th: f32,
        lr: f32,
    ) -> crate::Result<f32> {
        let d = check_params(&state.params)?;
        if feats.shape.len() != 3 || feats.shape[2] != d.c {
            bail!("feats must be [B, T, {}], got {:?}", d.c, feats.shape);
        }
        let (bsz, t_frames) = (feats.shape[0], feats.shape[1]);
        if labels.data.len() != bsz {
            bail!("labels must have {bsz} entries, got {}", labels.data.len());
        }
        let (c, h, k, g) = (d.c, d.h, d.k, d.g());
        let warmup = WARMUP.min(t_frames.saturating_sub(1));
        let counted = (t_frames - warmup).max(1);
        // β / (B·T): raw_l1 enters the loss as β · mean_b mean_t l1_{b,t}
        let beta_coef = SPARSITY_BETA / (bsz as f32 * t_frames.max(1) as f32);

        // gradient accumulators (canonical parameter order)
        let mut grads: Vec<Vec<f32>> =
            state.params.iter().map(|p| vec![0f32; p.data.len()]).collect();
        let mut loss = 0f32;

        for bi in 0..bsz {
            let f = &feats.data[bi * t_frames * c..(bi + 1) * t_frames * c];
            let tape = forward_utt(&state.params, f, t_frames, d, delta_th, true);
            let label = labels.data[bi] as usize;
            if label >= k {
                bail!("label {label} out of range (K = {k})");
            }
            let (ce, probs) = softmax_ce(&tape.logits, label);
            loss += ce / bsz as f32 + SPARSITY_BETA * tape.raw_l1_mean / bsz as f32;

            // d loss / d averaged-logits, then per counted frame
            let mut glt = vec![0f32; k];
            for kk in 0..k {
                let onehot = if kk == label { 1.0 } else { 0.0 };
                glt[kk] = (probs[kk] - onehot) / (bsz as f32 * counted as f32);
            }
            // readout gradients: glt is constant across counted frames
            let w_fc = &state.params[3].data;
            let mut h_sum = vec![0f32; h];
            for t in warmup..t_frames {
                for j in 0..h {
                    h_sum[j] += tape.h_all[(t + 1) * h + j];
                }
            }
            for j in 0..h {
                for kk in 0..k {
                    grads[3][j * k + kk] += h_sum[j] * glt[kk];
                }
            }
            for kk in 0..k {
                grads[4][kk] += glt[kk] * counted as f32;
            }
            // d loss / d h_t from the readout, identical for all counted t
            let mut gh_read = vec![0f32; h];
            for j in 0..h {
                let mut acc = 0f32;
                for kk in 0..k {
                    acc += glt[kk] * w_fc[j * k + kk];
                }
                gh_read[j] = acc;
            }

            // ---- reverse-time BPTT -------------------------------------
            let w_x = &state.params[0].data;
            let w_h = &state.params[1].data;
            let mut gh = vec![0f32; h]; // grad w.r.t. h_t
            let mut ghr = vec![0f32; h]; // grad w.r.t. h_ref after step t
            let mut gxr = vec![0f32; c]; // grad w.r.t. x_ref after step t
            let mut gm = vec![0f32; 4 * h]; // grads w.r.t. the memories at t
            let mut gdx = vec![0f32; c];
            let mut gdh = vec![0f32; h];
            for t in (0..t_frames).rev() {
                if t >= warmup {
                    for j in 0..h {
                        gh[j] += gh_read[j];
                    }
                }
                let r = &tape.r[t * h..(t + 1) * h];
                let u = &tape.u[t * h..(t + 1) * h];
                let cv = &tape.cv[t * h..(t + 1) * h];
                let m_hc = &tape.m_hc[t * h..(t + 1) * h];
                let h_prev = &tape.h_all[t * h..(t + 1) * h];
                let mut gh_prev = vec![0f32; h];
                // gates backward; accumulate into the carried memory grads
                for j in 0..h {
                    let gu = gh[j] * (h_prev[j] - cv[j]);
                    let gc = gh[j] * (1.0 - u[j]);
                    gh_prev[j] = gh[j] * u[j];
                    let gpre_c = gc * (1.0 - cv[j] * cv[j]);
                    gm[2 * h + j] += gpre_c;
                    let gr = gpre_c * m_hc[j];
                    gm[3 * h + j] += gpre_c * r[j];
                    grads[2][2 * h + j] += gpre_c;
                    let gpre_r = gr * r[j] * (1.0 - r[j]);
                    gm[j] += gpre_r;
                    grads[2][j] += gpre_r;
                    let gpre_u = gu * u[j] * (1.0 - u[j]);
                    gm[h + j] += gpre_u;
                    grads[2][h + j] += gpre_u;
                }
                // delta matvec backward: weight grads + grads on the deltas
                for i in 0..c {
                    let fire = tape.fire_x[t * c + i];
                    let a = tape.ax[t * c + i];
                    let dxi = if fire { a } else { 0.0 };
                    let row = &w_x[i * g..(i + 1) * g];
                    let grow = &mut grads[0][i * g..(i + 1) * g];
                    let mut acc = 0f32;
                    for j in 0..h {
                        acc += gm[j] * row[j] + gm[h + j] * row[h + j]
                            + gm[2 * h + j] * row[2 * h + j];
                        if dxi != 0.0 {
                            grow[j] += dxi * gm[j];
                            grow[h + j] += dxi * gm[h + j];
                            grow[2 * h + j] += dxi * gm[2 * h + j];
                        }
                    }
                    gdx[i] = acc;
                }
                for l in 0..h {
                    let fire = tape.fire_h[t * h + l];
                    let e = tape.eh[t * h + l];
                    let dhl = if fire { e } else { 0.0 };
                    let row = &w_h[l * g..(l + 1) * g];
                    let grow = &mut grads[1][l * g..(l + 1) * g];
                    let mut acc = 0f32;
                    for j in 0..h {
                        acc += gm[j] * row[j] + gm[h + j] * row[h + j]
                            + gm[3 * h + j] * row[2 * h + j];
                        if dhl != 0.0 {
                            grow[j] += dhl * gm[j];
                            grow[h + j] += dhl * gm[h + j];
                            grow[2 * h + j] += dhl * gm[3 * h + j];
                        }
                    }
                    gdh[l] = acc;
                }
                // thresholds + reference updates (STE: d dx / d a = 1; the
                // where() on the reference routes through the fired branch)
                for i in 0..c {
                    let fire = tape.fire_x[t * c + i];
                    let sg = beta_coef * sign(tape.ax[t * c + i]);
                    let keep = if fire { 0.0 } else { gxr[i] };
                    gxr[i] = keep - gdx[i] - sg;
                    // (the fired-branch share of gxr routes to x_t: inputs,
                    // no gradient consumer)
                }
                for l in 0..h {
                    let fire = tape.fire_h[t * h + l];
                    let sg = beta_coef * sign(tape.eh[t * h + l]);
                    let pass = if fire { ghr[l] } else { 0.0 };
                    let keep = if fire { 0.0 } else { ghr[l] };
                    gh_prev[l] += pass + gdh[l] + sg;
                    ghr[l] = keep - gdh[l] - sg;
                }
                gh.copy_from_slice(&gh_prev);
            }
        }

        // ---- global-norm clip + Adam (model.py adam_update) ------------
        let mut sq = 0f64;
        for gten in &grads {
            for &gv in gten {
                sq += (gv as f64) * (gv as f64);
            }
        }
        let gnorm = (sq + 1e-12).sqrt() as f32;
        let scale = (GRAD_CLIP / gnorm).min(1.0);
        let step = state.step + 1.0;
        let bc1 = 1.0 - ADAM_B1.powf(step);
        let bc2 = 1.0 - ADAM_B2.powf(step);
        for p in 0..state.params.len() {
            let gten = &grads[p];
            let params = &mut state.params[p].data;
            let m = &mut state.m[p].data;
            let v = &mut state.v[p].data;
            for i in 0..params.len() {
                let gv = gten[i] * scale;
                m[i] = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * gv;
                v[i] = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * gv * gv;
                params[i] -= lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + ADAM_EPS);
            }
        }
        state.step = step;
        Ok(loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::gru::{self, FloatParams};
    use crate::util::prng::Pcg;

    /// Random full-size parameter tensors + the matching [`FloatParams`].
    fn random_params(seed: u64, scale: f32) -> (Vec<Tensor>, FloatParams) {
        let mut rng = Pcg::new(seed);
        let shapes: [(usize, usize); 5] = [(16, 192), (64, 192), (1, 192), (64, 12), (1, 12)];
        let mut tensors = Vec::new();
        for (r, c) in shapes {
            let data: Vec<f32> =
                (0..r * c).map(|_| (rng.range_f64(-1.0, 1.0) as f32) * scale).collect();
            let shape = if r == 1 { vec![c] } else { vec![r, c] };
            tensors.push(Tensor::new(shape, data));
        }
        let p = crate::train::float_params_from_tensors(&tensors);
        (tensors, p)
    }

    fn smooth_feats(seed: u64, t: usize) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        let mut feats = vec![0f32; t * 16];
        let mut cur = [0.3f32; 16];
        for tt in 0..t {
            for c in 0..16 {
                cur[c] = (cur[c] + (rng.uniform() as f32 - 0.5) * 0.2).clamp(0.0, 0.99);
                feats[tt * 16 + c] = cur[c];
            }
        }
        feats
    }

    #[test]
    fn forward_matches_f64_reference_across_thresholds() {
        // mirror of the old PJRT artifact cross-check, now against the
        // in-crate f64 oracle: the two implement the same math
        let backend = NativeBackend::new();
        let (tensors, p) = random_params(7, 0.15);
        let feats = smooth_feats(8, 62);

        for delta_th in [0.0f32, 0.1, 0.3] {
            let out = backend
                .forward(&tensors, &Tensor::new(vec![1, 62, 16], feats.clone()), delta_th)
                .unwrap();
            assert_eq!(out.logits.shape, vec![1, 12]);
            let sp = out.sparsity.data[0];
            assert!((0.0..=1.0).contains(&sp), "sparsity {sp}");

            let mut st = gru::FloatState::new(16);
            let mut acc = [0.0f64; 12];
            let mut counted = 0;
            for t in 0..62 {
                let x: Vec<f64> = (0..16).map(|c| feats[t * 16 + c] as f64).collect();
                let (hv, _) = gru::float_delta_step(&p, &mut st, &x, delta_th as f64);
                if t >= WARMUP {
                    for k in 0..12 {
                        let mut l = p.b_fc[k] as f64;
                        for j in 0..64 {
                            l += hv[j] * p.w_fc[j][k] as f64;
                        }
                        acc[k] += l;
                    }
                    counted += 1;
                }
            }
            for k in 0..12 {
                acc[k] /= counted as f64;
                let got = out.logits.data[k] as f64;
                assert!(
                    (got - acc[k]).abs() < 2e-3,
                    "th={delta_th} logit[{k}]: native {got} vs f64 ref {}",
                    acc[k]
                );
            }
        }
    }

    #[test]
    fn sparsity_monotone_in_threshold() {
        let backend = NativeBackend::new();
        let (tensors, _) = random_params(9, 0.1);
        let mut rng = Pcg::new(10);
        let feats: Vec<f32> = (0..62 * 16).map(|_| rng.uniform() as f32 * 0.8).collect();
        let mut prev = -1.0f32;
        for th in [0.0f32, 0.05, 0.1, 0.2, 0.4] {
            let out = backend
                .forward(&tensors, &Tensor::new(vec![1, 62, 16], feats.clone()), th)
                .unwrap();
            let sp = out.sparsity.data[0];
            assert!(sp >= prev - 1e-6, "sparsity not monotone: {sp} after {prev} at th={th}");
            prev = sp;
        }
        assert!(prev > 0.5, "high threshold should be mostly sparse, got {prev}");
    }

    #[test]
    fn batched_forward_matches_per_utterance() {
        let backend = NativeBackend::new();
        let (tensors, _) = random_params(11, 0.12);
        let mut rng = Pcg::new(12);
        let feats_b: Vec<f32> = (0..3 * 62 * 16).map(|_| rng.uniform() as f32 * 0.7).collect();
        let out_b = backend
            .forward(&tensors, &Tensor::new(vec![3, 62, 16], feats_b.clone()), 0.1)
            .unwrap();
        for b in 0..3 {
            let single = feats_b[b * 62 * 16..(b + 1) * 62 * 16].to_vec();
            let out_s =
                backend.forward(&tensors, &Tensor::new(vec![1, 62, 16], single), 0.1).unwrap();
            for k in 0..12 {
                assert_eq!(out_b.logits.data[b * 12 + k], out_s.logits.data[k], "b={b} k={k}");
            }
            assert_eq!(out_b.sparsity.data[b], out_s.sparsity.data[0]);
        }
    }

    /// Tiny-model helpers for the finite-difference gradient check.
    fn tiny_params(seed: u64) -> Vec<Tensor> {
        let mut rng = Pcg::new(seed);
        let (c, h, k) = (3usize, 4usize, 2usize);
        let shapes: [Vec<usize>; 5] =
            [vec![c, 3 * h], vec![h, 3 * h], vec![3 * h], vec![h, k], vec![k]];
        shapes
            .into_iter()
            .map(|s| {
                let n: usize = s.iter().product();
                let data: Vec<f32> =
                    (0..n).map(|_| rng.range_f64(-0.4, 0.4) as f32).collect();
                Tensor::new(s, data)
            })
            .collect()
    }

    fn tiny_batch(seed: u64) -> (Tensor, IntTensor) {
        let mut rng = Pcg::new(seed);
        let (bsz, t, c) = (2usize, 6usize, 3usize);
        let feats: Vec<f32> =
            (0..bsz * t * c).map(|_| rng.range_f64(0.0, 1.0) as f32).collect();
        let labels: Vec<i32> = (0..bsz).map(|_| rng.below(2) as i32).collect();
        (Tensor::new(vec![bsz, t, c], feats), IntTensor::new(vec![bsz], labels))
    }

    /// Analytic gradient of one coordinate, extracted by running a single
    /// Adam step from zero moments at a known learning rate: after one step
    /// from m=v=0, the update direction is sign(g), so instead we recover
    /// the raw gradient by differencing the Adam moment: m_1 = (1-β1)·g.
    fn analytic_grads(params: &[Tensor], feats: &Tensor, labels: &IntTensor, th: f32)
        -> Vec<Vec<f32>> {
        let backend = NativeBackend::new();
        let mut state = TrainState {
            params: params.to_vec(),
            m: params.iter().map(|p| Tensor::zeros(&p.shape)).collect(),
            v: params.iter().map(|p| Tensor::zeros(&p.shape)).collect(),
            step: 0.0,
        };
        backend.train_step(&mut state, feats, labels, th, 0.0).unwrap();
        // lr = 0 leaves params untouched; m_1 = (1-β1) · g_clipped. The tiny
        // model's gradient norm is far below GRAD_CLIP, so clipping is a
        // no-op and g = m_1 / (1-β1).
        state
            .m
            .iter()
            .map(|t| t.data.iter().map(|&v| v / (1.0 - ADAM_B1)).collect())
            .collect()
    }

    #[test]
    fn gradients_match_finite_differences_dense() {
        // Θ = 0: every lane fires, the STE is exact, the loss is smooth —
        // finite differences must agree with the analytic BPTT gradients.
        // Only coordinates with |g| > 5e-3 are compared: below that, f32
        // central-difference noise (loss ulp / 2ε) dominates the signal.
        let params = tiny_params(3);
        let (feats, labels) = tiny_batch(4);
        let grads = analytic_grads(&params, &feats, &labels, 0.0);
        let eps = 5e-3f32;
        let mut checked = 0;
        for p in 0..5 {
            for i in 0..params[p].data.len() {
                let ana = grads[p][i];
                if ana.abs() < 5e-3 {
                    continue;
                }
                let mut plus = params.clone();
                plus[p].data[i] += eps;
                let mut minus = params.clone();
                minus[p].data[i] -= eps;
                let lp = batch_loss(&plus, &feats, &labels, 0.0).unwrap();
                let lm = batch_loss(&minus, &feats, &labels, 0.0).unwrap();
                let num = (lp - lm) / (2.0 * eps);
                let denom = ana.abs().max(num.abs());
                assert!(
                    (num - ana).abs() / denom < 0.1,
                    "param {p}[{i}]: numeric {num} vs analytic {ana}"
                );
                checked += 1;
            }
        }
        assert!(checked >= 10, "only {checked} coordinates checked");
    }

    #[test]
    fn readout_gradients_match_finite_differences_thresholded() {
        // Θ > 0: the loss is piecewise smooth in the recurrent weights, but
        // exactly smooth in the readout (w_fc/b_fc never influence firing).
        let params = tiny_params(13);
        let (feats, labels) = tiny_batch(14);
        let th = 0.15f32;
        let grads = analytic_grads(&params, &feats, &labels, th);
        let eps = 5e-3f32;
        let mut checked = 0;
        for p in [3usize, 4] {
            for i in 0..params[p].data.len() {
                let ana = grads[p][i];
                if ana.abs() < 2e-3 {
                    continue; // below f32 finite-difference noise
                }
                let mut plus = params.clone();
                plus[p].data[i] += eps;
                let mut minus = params.clone();
                minus[p].data[i] -= eps;
                let lp = batch_loss(&plus, &feats, &labels, th).unwrap();
                let lm = batch_loss(&minus, &feats, &labels, th).unwrap();
                let num = (lp - lm) / (2.0 * eps);
                let denom = ana.abs().max(num.abs());
                assert!(
                    (num - ana).abs() / denom < 0.1,
                    "param {p}[{i}]: numeric {num} vs analytic {ana}"
                );
                checked += 1;
            }
        }
        assert!(checked >= 4, "only {checked} readout coordinates checked");
    }

    #[test]
    fn train_step_reduces_loss_on_repeated_batch() {
        let backend = NativeBackend::new();
        let params = tiny_params(21);
        let (feats, labels) = tiny_batch(22);
        let mut state = TrainState {
            params,
            m: vec![
                Tensor::zeros(&[3, 12]),
                Tensor::zeros(&[4, 12]),
                Tensor::zeros(&[12]),
                Tensor::zeros(&[4, 2]),
                Tensor::zeros(&[2]),
            ],
            v: vec![
                Tensor::zeros(&[3, 12]),
                Tensor::zeros(&[4, 12]),
                Tensor::zeros(&[12]),
                Tensor::zeros(&[4, 2]),
                Tensor::zeros(&[2]),
            ],
            step: 0.0,
        };
        let mut losses = Vec::new();
        for _ in 0..60 {
            let loss = backend.train_step(&mut state, &feats, &labels, 0.0, 3e-2).unwrap();
            assert!(loss.is_finite());
            losses.push(loss);
        }
        assert_eq!(state.step, 60.0);
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.5),
            "no learning on a repeated batch: first {} last {}",
            losses[0],
            losses.last().unwrap()
        );
    }

    #[test]
    fn train_step_is_deterministic() {
        let backend = NativeBackend::new();
        let run = || {
            let params = tiny_params(31);
            let (feats, labels) = tiny_batch(32);
            let mut state = TrainState {
                m: params.iter().map(|p| Tensor::zeros(&p.shape)).collect(),
                v: params.iter().map(|p| Tensor::zeros(&p.shape)).collect(),
                params,
                step: 0.0,
            };
            let mut out = Vec::new();
            for _ in 0..3 {
                out.push(backend.train_step(&mut state, &feats, &labels, 0.1, 1e-3).unwrap());
            }
            (out, state.params[0].data.clone())
        };
        let (l1, p1) = run();
        let (l2, p2) = run();
        assert_eq!(l1, l2);
        assert_eq!(p1, p2);
    }

    #[test]
    fn rejects_malformed_inputs() {
        let backend = NativeBackend::new();
        let params = tiny_params(41);
        // wrong feature width
        let feats = Tensor::new(vec![1, 4, 5], vec![0.0; 20]);
        assert!(backend.forward(&params, &feats, 0.0).is_err());
        // wrong parameter count
        let feats = Tensor::new(vec![1, 4, 3], vec![0.0; 12]);
        assert!(backend.forward(&params[..4], &feats, 0.0).is_err());
    }
}

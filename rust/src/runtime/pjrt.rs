//! PJRT execution path (feature `pjrt`): loads the AOT-compiled JAX/Pallas
//! artifacts and executes them from Rust. Python never runs on the request
//! path.
//!
//! Interchange format is **HLO text** (see `python/compile/aot.py`): jax
//! >= 0.5 emits HloModuleProtos with 64-bit instruction ids that the
//! crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly. All artifacts are lowered with `return_tuple=True`,
//! so every execution returns a tuple literal which [`Executable::run`]
//! decomposes.
//!
//! The [`Runtime`] owns one PJRT CPU client; [`Executable`]s are compiled
//! once at startup (`make artifacts` must have produced `artifacts/`).
//! [`PjrtBackend`] adapts the two model entry points (`kws_fwd_b16`,
//! `train_step`) to the crate-wide [`Backend`] trait.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use super::{Backend, ForwardOut, IntTensor, Manifest, Tensor, TrainState, Value};

/// Number of parameter tensors in the canonical order (w_x, w_h, b, w_fc, b_fc).
const N_PARAMS: usize = 5;

fn tensor_to_literal(t: &Tensor) -> crate::Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    if t.shape.is_empty() {
        // scalar: reshape to rank-0
        Ok(lit.reshape(&[])?)
    } else {
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

fn tensor_from_literal(lit: &xla::Literal) -> crate::Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    // convert through f32 regardless of source dtype
    let lit32 = lit.convert(xla::PrimitiveType::F32)?;
    Ok(Tensor { shape: dims, data: lit32.to_vec::<f32>()? })
}

fn int_tensor_to_literal(t: &IntTensor) -> crate::Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

fn value_to_literal(v: &Value) -> crate::Result<xla::Literal> {
    match v {
        Value::F32(t) => tensor_to_literal(t),
        Value::I32(t) => int_tensor_to_literal(t),
    }
}

/// A compiled artifact.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with positional inputs; returns the decomposed output tuple
    /// as f32 tensors.
    pub fn run(&self, inputs: &[Value]) -> crate::Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(value_to_literal).collect::<crate::Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let out = result
            .first()
            .and_then(|r| r.first())
            .context("empty execution result")?
            .to_literal_sync()?;
        let parts = out.to_tuple()?;
        parts.iter().map(tensor_from_literal).collect()
    }
}

/// The PJRT runtime: one CPU client + the compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
    pub manifest: Manifest,
}

impl Runtime {
    /// Create a CPU PJRT client and read the artifact manifest.
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> crate::Result<Self> {
        let artifacts_dir = artifacts_dir.into();
        if !artifacts_dir.join("manifest.json").exists() {
            bail!(
                "artifacts not found in {} — run `make artifacts` first",
                artifacts_dir.display()
            );
        }
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, artifacts_dir, manifest })
    }

    /// Default artifacts location: `$CARGO_MANIFEST_DIR/artifacts` when run
    /// in-tree, else `./artifacts`.
    pub fn default_dir() -> PathBuf {
        let local = PathBuf::from("artifacts");
        if local.join("manifest.json").exists() {
            return local;
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, name: &str) -> crate::Result<Executable> {
        let path = self.artifacts_dir.join(name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe, name: name.to_string() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// [`Backend`] adapter over the PJRT runtime: the batched forward and the
/// flat-ABI `train_step` artifact (see `python/compile/model.py` for the
/// 20-argument / 17-result contract).
pub struct PjrtBackend {
    rt: Runtime,
    fwd: Executable,
    train: Executable,
}

impl PjrtBackend {
    pub fn new(artifacts_dir: &Path) -> crate::Result<Self> {
        let rt = Runtime::new(artifacts_dir)?;
        let fwd = rt.load("kws_fwd_b16.hlo.txt")?;
        let train = rt.load("train_step.hlo.txt")?;
        Ok(Self { rt, fwd, train })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> String {
        format!("pjrt:{}", self.rt.platform())
    }

    fn manifest(&self) -> &Manifest {
        &self.rt.manifest
    }

    fn forward(&self, params: &[Tensor], feats: &Tensor, delta_th: f32)
        -> crate::Result<ForwardOut> {
        let mut inputs: Vec<Value> = params.iter().map(|t| Value::from(t.clone())).collect();
        inputs.push(feats.clone().into());
        inputs.push(Tensor::scalar(delta_th).into());
        let mut out = self.fwd.run(&inputs)?;
        if out.len() != 2 {
            bail!("kws_fwd_b16 returned {} tensors, expected 2", out.len());
        }
        let sparsity = out.pop().unwrap();
        let logits = out.pop().unwrap();
        Ok(ForwardOut { logits, sparsity })
    }

    fn train_step(
        &self,
        state: &mut TrainState,
        feats: &Tensor,
        labels: &IntTensor,
        delta_th: f32,
        lr: f32,
    ) -> crate::Result<f32> {
        let mut inputs: Vec<Value> = Vec::with_capacity(20);
        for t in &state.params {
            inputs.push(t.clone().into());
        }
        for t in &state.m {
            inputs.push(t.clone().into());
        }
        for t in &state.v {
            inputs.push(t.clone().into());
        }
        inputs.push(Tensor::scalar(state.step).into());
        inputs.push(feats.clone().into());
        inputs.push(labels.clone().into());
        inputs.push(Tensor::scalar(delta_th).into());
        inputs.push(Tensor::scalar(lr).into());

        let out = self.train.run(&inputs)?;
        if out.len() != 3 * N_PARAMS + 2 {
            bail!("train_step returned {} tensors, expected {}", out.len(), 3 * N_PARAMS + 2);
        }
        state.params = out[..N_PARAMS].to_vec();
        state.m = out[N_PARAMS..2 * N_PARAMS].to_vec();
        state.v = out[2 * N_PARAMS..3 * N_PARAMS].to_vec();
        state.step = out[3 * N_PARAMS].data[0];
        Ok(out[3 * N_PARAMS + 1].data[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_loads_if_present() {
        let dir = Runtime::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.frames, 62);
        assert_eq!(m.channels, 16);
        assert_eq!(m.hidden, 64);
        assert_eq!(m.classes, 12);
        assert_eq!(m.param_order.len(), 5);
        assert_eq!(m.param_shapes[0].1, vec![16, 192]);
    }

    // Full execute-path tests live in rust/tests/runtime_integration.rs —
    // they need the PJRT client, which is slow to spin up per unit test.
}

//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts and executes
//! them from Rust. Python never runs on the request path.
//!
//! Interchange format is **HLO text** (see `python/compile/aot.py`): jax
//! >= 0.5 emits HloModuleProtos with 64-bit instruction ids that the
//! crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly. All artifacts are lowered with `return_tuple=True`,
//! so every execution returns a tuple literal which [`Executable::run`]
//! decomposes.
//!
//! The [`Runtime`] owns one PJRT CPU client; [`Executable`]s are compiled
//! once at startup (`make artifacts` must have produced `artifacts/`).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::util::json::{self, Json};

/// Tensor of f32s with shape — the runtime's host-side value type.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape, data }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    fn to_literal(&self) -> crate::Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // scalar: reshape to rank-0
            Ok(lit.reshape(&[])?)
        } else {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }
    }

    fn from_literal(lit: &xla::Literal) -> crate::Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        // convert through f32 regardless of source dtype
        let lit32 = lit.convert(xla::PrimitiveType::F32)?;
        Ok(Self { shape: dims, data: lit32.to_vec::<f32>()? })
    }
}

/// Integer tensor (labels). Converted to s32 literals.
#[derive(Debug, Clone)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape, data }
    }

    fn to_literal(&self) -> crate::Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

/// Host value passed to an executable.
#[derive(Debug, Clone)]
pub enum Value {
    F32(Tensor),
    I32(IntTensor),
}

impl Value {
    fn to_literal(&self) -> crate::Result<xla::Literal> {
        match self {
            Value::F32(t) => t.to_literal(),
            Value::I32(t) => t.to_literal(),
        }
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Self {
        Value::F32(t)
    }
}

impl From<IntTensor> for Value {
    fn from(t: IntTensor) -> Self {
        Value::I32(t)
    }
}

/// A compiled artifact.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with positional inputs; returns the decomposed output tuple
    /// as f32 tensors.
    pub fn run(&self, inputs: &[Value]) -> crate::Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<crate::Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let out = result
            .first()
            .and_then(|r| r.first())
            .context("empty execution result")?
            .to_literal_sync()?;
        let parts = out.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }
}

/// Artifact manifest (written by aot.py).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub frames: usize,
    pub channels: usize,
    pub hidden: usize,
    pub classes: usize,
    pub batch: usize,
    pub audio_samples: usize,
    pub param_order: Vec<String>,
    pub param_shapes: Vec<(String, Vec<usize>)>,
}

impl Manifest {
    pub fn load(dir: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .context("manifest.json missing — run `make artifacts` first")?;
        let j = json::parse(&text).map_err(anyhow::Error::msg)?;
        let get = |k: &str| -> crate::Result<usize> {
            j.get(k).and_then(Json::as_usize).with_context(|| format!("manifest field {k}"))
        };
        let order: Vec<String> = j
            .get("param_order")
            .and_then(Json::as_arr)
            .context("param_order")?
            .iter()
            .filter_map(|v| v.as_str().map(str::to_string))
            .collect();
        let shapes_obj = j.get("param_shapes").context("param_shapes")?;
        let mut param_shapes = Vec::new();
        for name in &order {
            let dims: Vec<usize> = shapes_obj
                .get(name)
                .and_then(Json::as_arr)
                .with_context(|| format!("shape of {name}"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            param_shapes.push((name.clone(), dims));
        }
        Ok(Self {
            frames: get("frames")?,
            channels: get("channels")?,
            hidden: get("hidden")?,
            classes: get("classes")?,
            batch: get("batch")?,
            audio_samples: get("audio_samples")?,
            param_order: order,
            param_shapes,
        })
    }
}

/// The PJRT runtime: one CPU client + the compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
    pub manifest: Manifest,
}

impl Runtime {
    /// Create a CPU PJRT client and read the artifact manifest.
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> crate::Result<Self> {
        let artifacts_dir = artifacts_dir.into();
        if !artifacts_dir.join("manifest.json").exists() {
            bail!(
                "artifacts not found in {} — run `make artifacts` first",
                artifacts_dir.display()
            );
        }
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, artifacts_dir, manifest })
    }

    /// Default artifacts location: `$CARGO_MANIFEST_DIR/artifacts` when run
    /// in-tree, else `./artifacts`.
    pub fn default_dir() -> PathBuf {
        let local = PathBuf::from("artifacts");
        if local.join("manifest.json").exists() {
            return local;
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, name: &str) -> crate::Result<Executable> {
        let path = self.artifacts_dir.join(name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe, name: name.to_string() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<PathBuf> {
        let dir = Runtime::default_dir();
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        let z = Tensor::zeros(&[4, 5]);
        assert_eq!(z.data.len(), 20);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn manifest_loads_if_present() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.frames, 62);
        assert_eq!(m.channels, 16);
        assert_eq!(m.hidden, 64);
        assert_eq!(m.classes, 12);
        assert_eq!(m.param_order.len(), 5);
        assert_eq!(m.param_shapes[0].1, vec![16, 192]);
    }

    // Full execute-path tests live in rust/tests/runtime_integration.rs —
    // they need the PJRT client, which is slow to spin up per unit test.
}

//! Pluggable execution backend: where the float ΔGRU forward/backward runs.
//!
//! The crate separates *what* is computed (the delta-aware KWS network and
//! its training step, ABI fixed by `python/compile/model.py`) from *where*
//! it runs, behind the [`Backend`] trait:
//!
//! * [`native::NativeBackend`] — pure-Rust implementation of the batched
//!   ΔGRU forward and the full BPTT training step (straight-through
//!   threshold gradient + Adam). Zero external dependencies; the default.
//! * `pjrt::PjrtBackend` (feature `pjrt`) — the original path: loads the
//!   AOT-compiled JAX/Pallas artifacts (HLO text, `make artifacts`) and
//!   executes them through a PJRT CPU client. Python is never on the
//!   request path.
//!
//! [`backend_for`] picks PJRT when the feature is enabled *and* artifacts
//! are present, otherwise the native backend — so `cargo build && cargo
//! test` work fully offline, and the PJRT path remains a drop-in swap.

use std::path::Path;

use anyhow::Context;

use crate::util::json::{self, Json};
use crate::util::prng::Pcg;

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, Runtime};

/// Tensor of f32s with shape — the runtime's host-side value type.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape, data }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Integer tensor (labels).
#[derive(Debug, Clone)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape, data }
    }
}

/// Host value passed to an executable (PJRT argument lists mix both).
#[derive(Debug, Clone)]
pub enum Value {
    F32(Tensor),
    I32(IntTensor),
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Self {
        Value::F32(t)
    }
}

impl From<IntTensor> for Value {
    fn from(t: IntTensor) -> Self {
        Value::I32(t)
    }
}

/// Model geometry + canonical parameter list. For the PJRT backend this is
/// read from `artifacts/manifest.json` (written by aot.py); the native
/// backend synthesises the identical manifest from the crate constants.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub frames: usize,
    pub channels: usize,
    pub hidden: usize,
    pub classes: usize,
    pub batch: usize,
    pub audio_samples: usize,
    pub param_order: Vec<String>,
    pub param_shapes: Vec<(String, Vec<usize>)>,
}

impl Manifest {
    /// The in-crate model geometry (`python/compile/model.PARAM_SHAPES`).
    pub fn native(batch: usize) -> Self {
        let c = crate::MAX_CHANNELS;
        let h = crate::HIDDEN;
        let k = crate::NUM_CLASSES;
        let order = ["w_x", "w_h", "b", "w_fc", "b_fc"];
        let shapes: [Vec<usize>; 5] =
            [vec![c, 3 * h], vec![h, 3 * h], vec![3 * h], vec![h, k], vec![k]];
        Self {
            frames: crate::FRAMES_PER_DECISION,
            channels: c,
            hidden: h,
            classes: k,
            batch,
            audio_samples: crate::FRAMES_PER_DECISION * crate::FRAME_SAMPLES,
            param_order: order.iter().map(|s| s.to_string()).collect(),
            param_shapes: order.iter().map(|s| s.to_string()).zip(shapes).collect(),
        }
    }

    pub fn load(dir: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .context("manifest.json missing — run `make artifacts` first")?;
        let j = json::parse(&text).map_err(anyhow::Error::msg)?;
        let get = |k: &str| -> crate::Result<usize> {
            j.get(k).and_then(Json::as_usize).with_context(|| format!("manifest field {k}"))
        };
        let order: Vec<String> = j
            .get("param_order")
            .and_then(Json::as_arr)
            .context("param_order")?
            .iter()
            .filter_map(|v| v.as_str().map(str::to_string))
            .collect();
        let shapes_obj = j.get("param_shapes").context("param_shapes")?;
        let mut param_shapes = Vec::new();
        for name in &order {
            let dims: Vec<usize> = shapes_obj
                .get(name)
                .and_then(Json::as_arr)
                .with_context(|| format!("shape of {name}"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            param_shapes.push((name.clone(), dims));
        }
        Ok(Self {
            frames: get("frames")?,
            channels: get("channels")?,
            hidden: get("hidden")?,
            classes: get("classes")?,
            batch: get("batch")?,
            audio_samples: get("audio_samples")?,
            param_order: order,
            param_shapes,
        })
    }
}

/// Float training state: parameters + Adam moments, host-side mirrors of
/// the (device, for PJRT) tensors.
#[derive(Debug, Clone)]
pub struct TrainState {
    pub params: Vec<Tensor>,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub step: f32,
}

impl TrainState {
    /// Glorot-uniform init matching `python/compile/model.init_params`
    /// (update-gate bias +1).
    pub fn init(manifest: &Manifest, seed: u64) -> Self {
        let mut rng = Pcg::new(seed);
        let mut params = Vec::with_capacity(manifest.param_shapes.len());
        for (name, shape) in &manifest.param_shapes {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = if name == "b" {
                // zero biases, +1 on the update-gate block
                let h = manifest.hidden;
                (0..n).map(|i| if i >= h && i < 2 * h { 1.0 } else { 0.0 }).collect()
            } else if name.starts_with('b') {
                vec![0.0; n]
            } else {
                let (fan_in, fan_out) = (shape[0] as f64, shape[1] as f64);
                let lim = (6.0 / (fan_in + fan_out)).sqrt();
                (0..n).map(|_| rng.range_f64(-lim, lim) as f32).collect()
            };
            params.push(Tensor::new(shape.clone(), data));
        }
        let zeros: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        Self { params, m: zeros.clone(), v: zeros, step: 0.0 }
    }
}

/// Batched forward result: logits `[B, classes]` + per-utterance temporal
/// sparsity `[B]`.
#[derive(Debug, Clone)]
pub struct ForwardOut {
    pub logits: Tensor,
    pub sparsity: Tensor,
}

/// Where the float network runs. Implementations must agree on the ABI of
/// `python/compile/model.py`: the canonical 5-tensor parameter list, the
/// delta-thresholded forward with posterior averaging after the warmup
/// frames, and the Adam training step with straight-through thresholding.
pub trait Backend: Send + Sync {
    /// Human-readable backend identity (e.g. `native`, `pjrt:Host`).
    fn name(&self) -> String;

    /// Model geometry and canonical parameter order/shapes.
    fn manifest(&self) -> &Manifest;

    /// Can this backend run batches of size `b`? (PJRT artifacts are lowered
    /// at a fixed batch; the native backend takes any.)
    fn supports_batch(&self, b: usize) -> bool {
        b == self.manifest().batch
    }

    /// Batched utterance forward at threshold `delta_th`:
    /// feats `[B, T, C]` -> logits `[B, classes]` + sparsity `[B]`.
    fn forward(&self, params: &[Tensor], feats: &Tensor, delta_th: f32)
        -> crate::Result<ForwardOut>;

    /// One Adam optimisation step (delta-aware loss = cross-entropy +
    /// sparsity L1 penalty). Mutates `state` in place; returns the loss.
    fn train_step(
        &self,
        state: &mut TrainState,
        feats: &Tensor,
        labels: &IntTensor,
        delta_th: f32,
        lr: f32,
    ) -> crate::Result<f32>;
}

/// Pick an execution backend. With the `pjrt` feature enabled and AOT
/// artifacts present under `artifacts_dir`, the PJRT path is used; in every
/// other case (the default build) the pure-Rust native backend runs.
pub fn backend_for(artifacts_dir: &str) -> crate::Result<Box<dyn Backend>> {
    #[cfg(feature = "pjrt")]
    {
        let dir = Path::new(artifacts_dir);
        if dir.join("manifest.json").exists() {
            match pjrt::PjrtBackend::new(dir) {
                Ok(b) => return Ok(Box::new(b)),
                Err(e) => {
                    eprintln!("pjrt backend unavailable ({e:#}); falling back to native");
                }
            }
        }
    }
    #[cfg(not(feature = "pjrt"))]
    let _ = artifacts_dir;
    Ok(Box::new(NativeBackend::new()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert!(!t.is_empty());
        let z = Tensor::zeros(&[4, 5]);
        assert_eq!(z.data.len(), 20);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn native_manifest_geometry() {
        let m = Manifest::native(16);
        assert_eq!(m.frames, 62);
        assert_eq!(m.channels, 16);
        assert_eq!(m.hidden, 64);
        assert_eq!(m.classes, 12);
        assert_eq!(m.batch, 16);
        assert_eq!(m.audio_samples, 62 * 128);
        assert_eq!(m.param_order.len(), 5);
        assert_eq!(m.param_shapes[0].1, vec![16, 192]);
        assert_eq!(m.param_shapes[3].1, vec![64, 12]);
    }

    #[test]
    fn train_state_init_shapes_and_update_gate_bias() {
        let m = Manifest::native(16);
        let st = TrainState::init(&m, 42);
        assert_eq!(st.params.len(), 5);
        assert_eq!(st.m.len(), 5);
        assert_eq!(st.v.len(), 5);
        assert_eq!(st.step, 0.0);
        // b: zero except +1 on the update-gate block [H, 2H)
        let b = &st.params[2].data;
        assert_eq!(b.len(), 192);
        assert!(b[..64].iter().all(|&v| v == 0.0));
        assert!(b[64..128].iter().all(|&v| v == 1.0));
        assert!(b[128..].iter().all(|&v| v == 0.0));
        // moments start at zero
        assert!(st.m.iter().all(|t| t.data.iter().all(|&v| v == 0.0)));
    }

    #[test]
    fn train_state_init_is_deterministic_per_seed() {
        let m = Manifest::native(16);
        let a = TrainState::init(&m, 7);
        let b = TrainState::init(&m, 7);
        let c = TrainState::init(&m, 8);
        assert_eq!(a.params[0].data, b.params[0].data);
        assert_ne!(a.params[0].data, c.params[0].data);
    }

    #[test]
    fn backend_factory_defaults_to_native() {
        // without artifacts the factory must always fall back to native,
        // whatever the feature set
        let b = backend_for("this/dir/does/not/exist").unwrap();
        assert!(b.name().contains("native"), "{}", b.name());
        assert!(b.supports_batch(1) && b.supports_batch(64));
    }
}

//! Contention-free telemetry shards for the worker pool.
//!
//! PR 2's coordinator pushed every completed utterance into an unbounded
//! `Vec<u64>` behind one global `Mutex<Stats>` and re-took a second
//! `reports` lock to store a freshly recomputed `chip.report()` — two
//! cross-worker lock acquisitions plus a float rollup *per request*, and
//! memory linear in the request count. This module replaces that with one
//! [`WorkerShard`] per worker: plain relaxed counters, a fixed-size
//! log-bucketed latency histogram ([`crate::util::hist`]), and an atomic
//! mirror of [`ChipActivity`]'s monotonic event counts. Workers touch only
//! their own shard with relaxed atomics (no locks, no allocation, O(1)
//! memory); [`super::Coordinator::stats`] folds all shards on demand, the
//! same read-time-fold discipline the lock-free spill/chunk routing
//! counters already established.
//!
//! Chip reports (power/energy rollups — float math) are *pull-based*: each
//! worker publishes a [`ChipReport`] snapshot into its shard's report slot
//! when the pool goes idle under it, every [`REPORT_EPOCH`] runnables under
//! sustained load, and on an explicit [`super::Coordinator::reports`]
//! request — never per utterance. The slot is a `Mutex`, but it is taken
//! once per epoch, not once per request, and only ever contended by a
//! concurrent reader.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::chip::ChipReport;
use crate::energy::ChipActivity;
use crate::util::hist::AtomicLogHistogram;

/// Default jobs between periodic report publications under sustained
/// load (the idle-lane publish keeps reports fresh whenever a worker
/// catches up, so this only bounds staleness while a lane never drains).
/// Tunable per pool via
/// [`CoordinatorBuilder::report_epoch`](super::builder::CoordinatorBuilder::report_epoch).
pub const REPORT_EPOCH: u64 = 64;

/// Atomic mirror of [`ChipActivity`]: one relaxed counter per field.
/// Writers add monotonic deltas; readers fold a snapshot.
#[derive(Default)]
pub struct AtomicActivity {
    frames: AtomicU64,
    gated_frames: AtomicU64,
    mac_ops: AtomicU64,
    sram_word_reads: AtomicU64,
    rnn_cycles: AtomicU64,
    fired_lanes: AtomicU64,
    total_lanes: AtomicU64,
    fired_x: AtomicU64,
    total_x: AtomicU64,
    fired_h: AtomicU64,
    total_h: AtomicU64,
    fex_visits: AtomicU64,
}

impl AtomicActivity {
    pub fn add(&self, d: &ChipActivity) {
        self.frames.fetch_add(d.frames, Ordering::Relaxed);
        self.gated_frames.fetch_add(d.gated_frames, Ordering::Relaxed);
        self.mac_ops.fetch_add(d.mac_ops, Ordering::Relaxed);
        self.sram_word_reads.fetch_add(d.sram_word_reads, Ordering::Relaxed);
        self.rnn_cycles.fetch_add(d.rnn_cycles, Ordering::Relaxed);
        self.fired_lanes.fetch_add(d.fired_lanes, Ordering::Relaxed);
        self.total_lanes.fetch_add(d.total_lanes, Ordering::Relaxed);
        self.fired_x.fetch_add(d.fired_x, Ordering::Relaxed);
        self.total_x.fetch_add(d.total_x, Ordering::Relaxed);
        self.fired_h.fetch_add(d.fired_h, Ordering::Relaxed);
        self.total_h.fetch_add(d.total_h, Ordering::Relaxed);
        self.fex_visits.fetch_add(d.fex_visits, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> ChipActivity {
        ChipActivity {
            frames: self.frames.load(Ordering::Relaxed),
            gated_frames: self.gated_frames.load(Ordering::Relaxed),
            mac_ops: self.mac_ops.load(Ordering::Relaxed),
            sram_word_reads: self.sram_word_reads.load(Ordering::Relaxed),
            rnn_cycles: self.rnn_cycles.load(Ordering::Relaxed),
            fired_lanes: self.fired_lanes.load(Ordering::Relaxed),
            total_lanes: self.total_lanes.load(Ordering::Relaxed),
            fired_x: self.fired_x.load(Ordering::Relaxed),
            total_x: self.total_x.load(Ordering::Relaxed),
            fired_h: self.fired_h.load(Ordering::Relaxed),
            total_h: self.total_h.load(Ordering::Relaxed),
            fex_visits: self.fex_visits.load(Ordering::Relaxed),
        }
    }
}

/// One worker's telemetry shard: everything the worker's hot loop records,
/// single-writer (the owning worker; plus session teardown on the same
/// thread), many-reader. Fixed size — nothing here grows with traffic.
#[derive(Default)]
pub struct WorkerShard {
    /// utterance requests completed
    pub completed: AtomicU64,
    /// completed requests that carried a ground-truth label
    pub labelled: AtomicU64,
    /// labelled requests answered correctly
    pub correct: AtomicU64,
    /// streaming audio chunks processed by this worker's sessions
    pub stream_chunks: AtomicU64,
    /// fused request groups served through the batched-chip path (each
    /// group's requests are also counted individually in `completed`)
    pub fused_batches: AtomicU64,
    /// stream events dropped because a session's bounded event channel
    /// was full (a client that never drains its receiver; detections are
    /// shed newest-first rather than growing worker-side memory)
    pub events_dropped: AtomicU64,
    /// epoch-fenced weight swaps installed on this worker's live stream
    /// sessions (see [`super::Coordinator::swap_weights`])
    pub weight_swaps: AtomicU64,
    /// runnables this worker popped from another worker's local queue
    /// (the work-stealing path — scheduler-health signal: a high rate
    /// means load is imbalanced and thieves are draining backlogs)
    pub steals: AtomicU64,
    /// runnable → parked transitions this worker performed (a session
    /// drained its inbox and left the hot set: the serving-layer
    /// clock-gate closing)
    pub park_transitions: AtomicU64,
    /// wall-clock utterance service time (queue + simulation), µs
    pub latency: AtomicLogHistogram,
    /// wall-clock stream-chunk service time (queue + simulation), µs
    pub chunk_latency: AtomicLogHistogram,
    /// wake-to-poll scheduling latency, µs: from a push re-arming a
    /// parked session to a worker polling its first message of the wake
    pub sched_latency: AtomicLogHistogram,
    /// chip activity folded in as monotonic deltas (utterances + sessions)
    pub activity: AtomicActivity,
    /// epoch-published chip report snapshot (utterance chip, cumulative);
    /// locked once per epoch / idle transition / reports() pull
    pub report: Mutex<Option<ChipReport>>,
}

impl WorkerShard {
    /// Fixed heap footprint of this shard's telemetry (histogram buckets).
    pub fn heap_bytes(&self) -> usize {
        // all three histograms have the same constant bucket-array size
        3 * crate::util::hist::N_BUCKETS * std::mem::size_of::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomic_activity_add_snapshot_roundtrip() {
        let acc = AtomicActivity::default();
        let a = ChipActivity {
            frames: 3,
            gated_frames: 1,
            mac_ops: 100,
            sram_word_reads: 50,
            rnn_cycles: 900,
            fired_lanes: 7,
            total_lanes: 74,
            fired_x: 2,
            total_x: 10,
            fired_h: 5,
            total_h: 64,
            fex_visits: 3840,
        };
        acc.add(&a);
        acc.add(&a);
        let s = acc.snapshot();
        assert_eq!(s.frames, 6);
        assert_eq!(s.mac_ops, 200);
        assert_eq!(s.fex_visits, 7680);
    }

    #[test]
    fn shard_heap_footprint_is_constant() {
        let shard = WorkerShard::default();
        let before = shard.heap_bytes();
        for i in 0..10_000u64 {
            shard.completed.fetch_add(1, Ordering::Relaxed);
            shard.latency.record(i);
            shard.chunk_latency.record(i * 3);
        }
        assert_eq!(shard.heap_bytes(), before, "telemetry grew with traffic");
    }
}

//! Sustained-load soak harness for the serving coordinator.
//!
//! Drives N producer threads of mixed work — per-utterance [`Request`]s
//! plus long-lived [`StreamSession`]s pushing audio chunks — through one
//! [`Coordinator`] for minutes of *simulated* audio, and validates the
//! telemetry guarantees the sharded refactor makes:
//!
//! * **flat memory** — the [`Stats`] snapshot footprint is identical at
//!   10% of the run and at the end (O(1) telemetry in the request count;
//!   asserted, not just reported);
//! * **accurate histograms** — the harness records every response's exact
//!   service time on the *caller* side (its memory, its choice) and
//!   cross-checks the log-bucketed histogram's p50/p99 against exact
//!   percentiles of that sample;
//! * **sustained throughput** — decisions/sec over the whole run, the
//!   number later scaling PRs are judged against.
//!
//! [`SoakConfig::emulate_legacy_telemetry`] adds an A/B baseline: extra
//! threads re-impose the pre-refactor per-utterance telemetry cost (one
//! global mutex push into an unbounded `Vec` plus a float power-rollup per
//! completion, at the pool's completion rate — the pattern the old
//! `Mutex<Stats>` + `reports` locks created). It is an *emulation*: the
//! old code path itself is gone, so the tax is applied by dedicated
//! contender threads rather than inside the workers.
//!
//! Entry points: [`run_soak`] (library), `examples/soak.rs` (CLI),
//! `benches/soak_bench.rs` (smoke-sized A/B in the bench matrix).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::{percentile, Coordinator, Request, Stats, StatsDelta, StreamEvent, Ticket};
use crate::accel::gru::QuantParams;
use crate::audio::track::{synth_track, TrackConfig};
use crate::chip::{ChipConfig, KwsChip};
use crate::error::{SubmitError, WaitError};
use crate::stream::detector::DetectionEvent;
use crate::stream::{StreamConfig, StreamPipeline};
use crate::util::prng::Pcg;

/// Soak-run shape. `acceptance()` is the ISSUE-3 acceptance workload;
/// `quick()` keeps integration tests and bench smoke mode fast.
#[derive(Debug, Clone)]
pub struct SoakConfig {
    pub workers: usize,
    pub producers: usize,
    /// total utterance requests across all producers
    pub utterances: u64,
    /// concurrent long-lived stream sessions
    pub streams: usize,
    /// audio chunks each stream session pushes
    pub chunks_per_stream: u64,
    /// samples per stream chunk
    pub chunk_samples: usize,
    /// samples per utterance (sub-second keeps wall-clock sane while the
    /// *simulated* audio still adds up to hours)
    pub utterance_samples: usize,
    pub queue_depth: usize,
    pub seed: u64,
    /// run the pre-refactor telemetry-cost emulation alongside (A/B)
    pub emulate_legacy_telemetry: bool,
}

impl SoakConfig {
    /// ≥50k mixed jobs across ≥4 workers — the acceptance workload.
    pub fn acceptance() -> Self {
        Self {
            workers: 4,
            producers: 4,
            utterances: 50_000,
            streams: 4,
            chunks_per_stream: 2_000,
            chunk_samples: 256,
            utterance_samples: 2_048,
            queue_depth: 16,
            seed: 0x50AC,
            emulate_legacy_telemetry: false,
        }
    }

    /// Small but still genuinely mixed/concurrent (integration tests).
    pub fn quick() -> Self {
        Self {
            workers: 4,
            producers: 2,
            utterances: 1_200,
            streams: 2,
            chunks_per_stream: 150,
            chunk_samples: 256,
            utterance_samples: 1_024,
            queue_depth: 8,
            seed: 0x50AC,
            emulate_legacy_telemetry: false,
        }
    }
}

/// Everything a soak run measured.
#[derive(Debug)]
pub struct SoakReport {
    pub utterances_done: u64,
    pub chunks_done: u64,
    /// simulated audio fed through the pool (utterances + streams), seconds
    pub simulated_audio_s: f64,
    pub wall: Duration,
    /// sustained utterance decisions per wall-clock second
    pub decisions_per_sec: f64,
    /// histogram-answered percentiles (what [`Stats`] serves)
    pub p50_us: u64,
    pub p99_us: u64,
    /// exact percentiles from the harness-recorded sample
    pub exact_p50_us: u64,
    pub exact_p99_us: u64,
    /// telemetry snapshot footprint at ~10% of the run and at the end
    pub telemetry_bytes_early: usize,
    pub telemetry_bytes_final: usize,
    /// live per-session pipeline memory observed at the ~10% checkpoint
    /// (bounded: frame staging buffer + detector window per session)
    pub session_bytes_early: u64,
    /// per-session memory after every session closed — must be 0
    pub session_bytes_final: u64,
    pub producer_retries: u64,
    /// counter movement from the ~10% checkpoint to the end of the run
    /// ([`Stats::delta_since`]): the *steady-state* rates window, excluding
    /// pool spin-up — `steady.decisions_per_sec()` is the warmed-up
    /// throughput figure the metrics exposition reports
    pub steady: StatsDelta,
    pub final_stats: Stats,
}

impl SoakReport {
    /// Relative disagreement between histogram and exact percentiles
    /// (the acceptance bound is 5%; the bucket math guarantees ≤ ~1.6%).
    pub fn percentile_rel_err(&self) -> f64 {
        let err = |approx: u64, exact: u64| {
            if exact == 0 {
                0.0
            } else {
                (approx as f64 - exact as f64).abs() / exact as f64
            }
        };
        err(self.p50_us, self.exact_p50_us).max(err(self.p99_us, self.exact_p99_us))
    }
}

/// The emulated pre-refactor telemetry cost, per completion: one global
/// mutex acquisition pushing into an unbounded `Vec` + a float
/// power/energy rollup (what `chip.report()` recomputed per utterance).
fn legacy_telemetry_tax(sink: &Mutex<Vec<u64>>, i: u64) {
    let mut g = sink.lock().unwrap();
    g.push(i);
    let frames = std::hint::black_box(g.len() as f64);
    let mut acc = 0.0f64;
    for k in 0..16 {
        acc += (frames * 0.37 + k as f64).sqrt() * 1e-6 / (frames + 1.0);
    }
    std::hint::black_box(acc);
}

/// Claim one soak ticket (bounded), publishing the completion for the
/// legacy-telemetry emulation and returning the exact service time.
fn resolve(ticket: Ticket, completed_pub: &AtomicU64) -> u64 {
    match ticket.wait_timeout(Duration::from_secs(1800)) {
        Ok(resp) => {
            completed_pub.fetch_add(1, Ordering::Release);
            resp.service.as_micros() as u64
        }
        Err(WaitError::Timeout(_)) => panic!("soak lost responses: pool wedged or timed out"),
        Err(WaitError::Closed) => panic!("pool died mid-soak"),
    }
}

/// Run a soak: spawn the pool, drive the mixed load, fold the report.
/// Panics (harness contract) if responses are lost, the run times out, or
/// the telemetry snapshot footprint grows with the request count.
pub fn run_soak(params: QuantParams, chip: ChipConfig, cfg: &SoakConfig) -> SoakReport {
    assert!(cfg.workers > 0 && cfg.producers > 0 && cfg.utterances > 0);
    let coord = Coordinator::builder(params, chip)
        .workers(cfg.workers)
        .queue_depth(cfg.queue_depth)
        .build()
        .expect("valid soak pool configuration");

    // pre-rendered utterance pool (audio synthesis off the timed path)
    let pool: Vec<(Vec<i64>, usize)> = (0..16u64)
        .map(|i| {
            let label = (i % crate::NUM_CLASSES as u64) as usize;
            let mut rng = Pcg::with_stream(cfg.seed, 100 + i);
            let wave = crate::audio::synth_utterance(label, &mut rng);
            let mut audio12 = crate::audio::quantize_12b(&wave);
            audio12.truncate(cfg.utterance_samples);
            (audio12, label)
        })
        .collect();
    // one shared track buffer the stream sessions loop over
    let track_cfg =
        TrackConfig { duration_s: 4, keywords: 2, fillers: 1, noise: (0.001, 0.002) };
    let (track_audio, _) = synth_track(&track_cfg, cfg.seed);

    let retries = AtomicU64::new(0);
    let chunks_done = AtomicU64::new(0);
    // consumer-published completion count (drives the legacy emulation)
    let completed_pub = AtomicU64::new(0);
    let done = AtomicBool::new(false);
    let legacy_sink: Mutex<Vec<u64>> = Mutex::new(Vec::new());

    let mut exact_us: Vec<u64> = Vec::with_capacity(cfg.utterances as usize);
    let mut telemetry_bytes_early = 0usize;
    let mut session_bytes_early = 0u64;
    // full checkpoint snapshot: the steady-rate window's left edge
    let mut early_stats = Stats::default();
    let checkpoint = (cfg.utterances / 10).max(1);
    // stamped once the producers have claimed their last ticket (stream
    // teardown after the final utterance must not dilute the throughput
    // figure)
    let mut wall = Duration::ZERO;

    let t0 = Instant::now();
    std::thread::scope(|s| {
        // stream sessions: one pusher thread per session
        for st in 0..cfg.streams {
            let sess = coord.open_stream(st as u64).expect("soak opens under the high-water mark");
            let track = &track_audio;
            let chunks_done = &chunks_done;
            let n = cfg.chunks_per_stream;
            let chunk = cfg.chunk_samples;
            s.spawn(move || {
                let mut off = 0usize;
                for _ in 0..n {
                    let end = (off + chunk).min(track.len());
                    sess.push_blocking(track[off..end].to_vec()).expect("pool alive");
                    chunks_done.fetch_add(1, Ordering::Relaxed);
                    off = if end == track.len() { 0 } else { end };
                }
                sess.close();
            });
        }
        // utterance producers: each owns a Client (its own completion
        // mailbox) and a sliding window of in-flight tickets — responses
        // are claimed ticket-by-ticket, never through a shared FIFO, so
        // the exact-sample cross-check below also exercises the v2
        // multi-client isolation contract at soak scale
        let window_cap = (cfg.workers * cfg.queue_depth).max(8);
        let mut producer_handles = Vec::with_capacity(cfg.producers);
        for p in 0..cfg.producers {
            let client = coord.client();
            let pool = &pool;
            let retries = &retries;
            let completed_pub = &completed_pub;
            let share = cfg.utterances / cfg.producers as u64
                + u64::from((p as u64) < cfg.utterances % cfg.producers as u64);
            let streams_span = (cfg.workers * 2) as u64;
            let p = p as u64;
            producer_handles.push(s.spawn(move || {
                let mut window: VecDeque<Ticket> = VecDeque::with_capacity(window_cap);
                let mut samples: Vec<u64> = Vec::with_capacity(share as usize);
                for i in 0..share {
                    let (audio12, label) = &pool[((p * 7 + i) % 16) as usize];
                    let mut req = Request {
                        id: 0,
                        stream: (p * 3 + i) % streams_span,
                        audio12: audio12.clone(),
                        label: Some(*label),
                        trace: false,
                        weights: None,
                    };
                    loop {
                        match client.submit(req) {
                            Ok(t) => {
                                window.push_back(t);
                                break;
                            }
                            Err(SubmitError::QueueFull(r)) => {
                                retries.fetch_add(1, Ordering::Relaxed);
                                req = r;
                                std::thread::sleep(Duration::from_micros(200));
                            }
                            Err(e) => panic!("pool died mid-soak: {e}"),
                        }
                    }
                    if window.len() >= window_cap {
                        let t = window.pop_front().expect("non-empty window");
                        samples.push(resolve(t, completed_pub));
                    }
                }
                for t in window {
                    samples.push(resolve(t, completed_pub));
                }
                samples
            }));
        }
        // pre-refactor telemetry-cost emulation (A/B baseline)
        if cfg.emulate_legacy_telemetry {
            for c in 0..cfg.workers as u64 {
                let completed_pub = &completed_pub;
                let done = &done;
                let sink = &legacy_sink;
                let contenders = cfg.workers as u64;
                s.spawn(move || {
                    let mut seen = 0u64;
                    loop {
                        let n = completed_pub.load(Ordering::Acquire);
                        for i in seen..n {
                            if i % contenders == c {
                                legacy_telemetry_tax(sink, i);
                            }
                        }
                        seen = n;
                        if done.load(Ordering::Acquire)
                            && seen == completed_pub.load(Ordering::Acquire)
                        {
                            break;
                        }
                        std::thread::sleep(Duration::from_micros(100));
                    }
                });
            }
        }
        // telemetry checkpoint at ~10% of the run: the snapshot footprint
        // must already be at its final (flat) size
        let poll_deadline = Instant::now() + Duration::from_secs(1800);
        loop {
            let snap = coord.stats();
            if snap.completed >= checkpoint {
                telemetry_bytes_early = snap.telemetry_bytes();
                session_bytes_early = snap.session_bytes;
                early_stats = snap;
                break;
            }
            assert!(
                Instant::now() < poll_deadline,
                "soak stalled before the 10% checkpoint"
            );
            std::thread::sleep(Duration::from_millis(2));
        }
        // join the producers (each has claimed all of its own tickets);
        // the wall stamp excludes stream-session teardown, as before
        for h in producer_handles {
            exact_us.extend(h.join().expect("soak producer panicked"));
        }
        wall = t0.elapsed();
        done.store(true, Ordering::Release);
    });
    assert_eq!(
        exact_us.len() as u64,
        cfg.utterances,
        "producers claimed a different number of responses than submitted"
    );

    let final_stats = coord.stats();
    assert_eq!(final_stats.completed, cfg.utterances, "completion counter drifted");
    let telemetry_bytes_final = final_stats.telemetry_bytes();
    assert_eq!(
        telemetry_bytes_early, telemetry_bytes_final,
        "telemetry memory grew with request count"
    );
    // per-session memory is bounded by construction (frame staging buffer
    // + detector window), never by how much audio flowed through; once
    // every session closed the gauge must be back to zero
    assert!(
        session_bytes_early <= cfg.streams as u64 * MAX_SESSION_STATE_BYTES,
        "per-session memory grew past its bound: {session_bytes_early} bytes for {} streams",
        cfg.streams
    );
    let session_bytes_final = final_stats.session_bytes;
    assert_eq!(
        session_bytes_final, 0,
        "closed sessions left state on the workers"
    );

    let simulated_audio_s = (cfg.utterances * cfg.utterance_samples as u64
        + cfg.streams as u64 * cfg.chunks_per_stream * cfg.chunk_samples as u64)
        as f64
        / crate::SAMPLE_RATE as f64;
    SoakReport {
        utterances_done: cfg.utterances,
        chunks_done: chunks_done.load(Ordering::Relaxed),
        simulated_audio_s,
        wall,
        decisions_per_sec: cfg.utterances as f64 / wall.as_secs_f64(),
        p50_us: final_stats.p50_us(),
        p99_us: final_stats.p99_us(),
        exact_p50_us: percentile(&exact_us, 0.50),
        exact_p99_us: percentile(&exact_us, 0.99),
        telemetry_bytes_early,
        telemetry_bytes_final,
        session_bytes_early,
        session_bytes_final,
        producer_retries: retries.load(Ordering::Relaxed),
        steady: final_stats.delta_since(&early_stats),
        final_stats,
    }
}

/// Generous per-session memory ceiling the soak asserts against: the
/// frame staging buffer ([`crate::chip::PENDING_FRAME_CAP`] frames, with
/// `VecDeque` growth slack) plus the detector window, rounded way up.
pub const MAX_SESSION_STATE_BYTES: u64 = 256 * 1024;

/// Shape of one [`run_scale_soak`] cell: N live sessions, most of them
/// VAD-idle (parked), a small active set pushing audio in rounds, plus a
/// bit-exactness oracle on both the utterance and the streaming path.
#[derive(Debug, Clone)]
pub struct ScaleSoakConfig {
    pub workers: usize,
    /// live sessions to open (also the admission high-water mark)
    pub sessions: usize,
    /// percentage of sessions that never receive audio — they sit parked
    /// for the whole run, the serving-layer analog of VAD clock-gating
    pub idle_pct: u8,
    /// push rounds over the active set (one chunk per active session per
    /// round); the flat-memory checkpoint lands after the first ~10%
    pub rounds: u64,
    /// samples per pushed chunk
    pub chunk_samples: usize,
    pub queue_depth: usize,
    pub seed: u64,
    /// solo utterances cross-checked bit-for-bit against a direct
    /// [`KwsChip`] oracle after the streaming rounds
    pub oracle_utterances: usize,
}

impl ScaleSoakConfig {
    /// One acceptance-matrix cell at `sessions` scale (90% idle).
    pub fn with_sessions(sessions: usize) -> Self {
        Self {
            workers: 4,
            sessions,
            idle_pct: 90,
            rounds: 10,
            chunk_samples: 256,
            queue_depth: 16,
            seed: 0x5CA1E,
            oracle_utterances: 100,
        }
    }

    /// The CI `soak-scale` smoke cell: 2k sessions, 90% idle — small
    /// enough to be a blocking gate, big enough that parking is load-
    /// bearing (200 runnable sessions over 4 workers).
    pub fn smoke() -> Self {
        Self { rounds: 4, oracle_utterances: 16, ..Self::with_sessions(2_000) }
    }

    /// The 10k / 50k / 100k acceptance matrix (README scaling table).
    pub fn matrix() -> [Self; 3] {
        [
            Self::with_sessions(10_000),
            Self::with_sessions(50_000),
            Self::with_sessions(100_000),
        ]
    }
}

/// Everything one scale-soak cell measured and proved.
#[derive(Debug)]
pub struct ScaleSoakReport {
    pub sessions: usize,
    pub active_sessions: usize,
    pub workers: usize,
    pub sessions_per_core: f64,
    pub rounds: u64,
    pub chunks_done: u64,
    pub wall: Duration,
    /// parked-session gauge at the quiesced ~10% checkpoint (must cover
    /// every session — the whole point of parking)
    pub parked_at_checkpoint: u64,
    /// session memory at the quiesced ~10% checkpoint vs the end:
    /// asserted equal (flat memory at scale)
    pub session_bytes_early: u64,
    pub session_bytes_late: u64,
    pub telemetry_bytes: usize,
    pub chunk_p50_us: u64,
    pub chunk_p99_us: u64,
    pub sched_p50_us: u64,
    pub sched_p99_us: u64,
    pub steals: u64,
    pub park_transitions: u64,
    /// typed admission rejections observed (the harness provokes one)
    pub shed_overloaded: u64,
    /// solo utterances that matched the direct-chip oracle bit-for-bit
    pub oracle_checked: u64,
    /// witness-stream detections that matched the single-threaded
    /// [`StreamPipeline`] oracle bit-for-bit
    pub witness_detections: u64,
    pub final_stats: Stats,
}

/// Poll until the pool has fully drained: every session parked, nothing
/// runnable, and exactly `chunks` stream chunks processed.
fn quiesce(coord: &Coordinator, total_sessions: u64, chunks: u64) -> Stats {
    let deadline = Instant::now() + Duration::from_secs(1800);
    loop {
        let s = coord.stats();
        if s.sessions_parked == total_sessions
            && s.sessions_runnable == 0
            && s.stream_chunks() == chunks
        {
            return s;
        }
        assert!(
            Instant::now() < deadline,
            "scale soak stalled: parked {}/{total_sessions}, chunks {}/{chunks}",
            s.sessions_parked,
            s.stream_chunks()
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Run one scale-soak cell: open `sessions` streams (90% of which stay
/// parked), push audio rounds over the active set, and prove the v3
/// scheduler claims — flat memory between quiesced checkpoints, parked
/// gauge covering the idle mass, typed `Overloaded` shedding past the
/// high-water mark, and per-decision bit-exactness against single-
/// threaded oracles on both the utterance and the streaming path.
/// Panics (harness contract) on any violated invariant.
pub fn run_scale_soak(
    params: QuantParams,
    chip: ChipConfig,
    cfg: &ScaleSoakConfig,
) -> ScaleSoakReport {
    assert!(cfg.workers > 0 && cfg.sessions > 1 && cfg.rounds > 0);
    assert!(cfg.idle_pct < 100, "at least one session must be active");
    let coord = Coordinator::builder(params.clone(), chip.clone())
        .workers(cfg.workers)
        .queue_depth(cfg.queue_depth)
        .max_sessions(cfg.sessions)
        .build()
        .expect("valid scale-soak pool");
    let active = (cfg.sessions * (100 - cfg.idle_pct as usize) / 100).max(1);

    let t0 = Instant::now();
    // open the whole population; every session starts parked
    let mut sessions = Vec::with_capacity(cfg.sessions);
    for i in 0..cfg.sessions {
        sessions.push(coord.open_stream(i as u64).expect("under the high-water mark"));
    }
    // admission control: one past the mark is a typed load-shed
    match coord.open_stream(cfg.sessions as u64) {
        Err(SubmitError::Overloaded { live, high_water }) => {
            assert_eq!(live, cfg.sessions as u64);
            assert_eq!(high_water, cfg.sessions as u64);
        }
        Err(e) => panic!("expected Overloaded past the mark, got {e}"),
        Ok(_) => panic!("admission let a session past the high-water mark"),
    }

    // keyword-bearing track the active set loops over
    let track_cfg =
        TrackConfig { duration_s: 4, keywords: 2, fillers: 1, noise: (0.001, 0.002) };
    let (track, _) = synth_track(&track_cfg, cfg.seed);
    // session 0 is the witness: its exact chunk sequence is re-run
    // single-threaded afterwards and must detect identically
    let mut witness_chunks: Vec<Vec<i64>> = Vec::new();
    let mut witness_events: Vec<DetectionEvent> = Vec::new();

    let checkpoint_round = (cfg.rounds / 10).max(1);
    let mut early: Option<Stats> = None;
    let mut chunks_pushed = 0u64;
    for round in 0..cfg.rounds {
        for (i, sess) in sessions[..active].iter().enumerate() {
            // per-session offset pattern so neighbours don't run in
            // lockstep through the same samples
            let off = ((i as u64 * 1_031 + round * cfg.chunk_samples as u64) as usize)
                % (track.len() - cfg.chunk_samples);
            let chunk = track[off..off + cfg.chunk_samples].to_vec();
            if i == 0 {
                witness_chunks.push(chunk.clone());
            }
            sess.push_blocking(chunk).expect("pool alive");
            chunks_pushed += 1;
        }
        // drain the witness's event channel every round (bounded channel;
        // the oracle comparison needs every event)
        witness_events.extend(sessions[0].try_events().into_iter().filter_map(|e| match e {
            StreamEvent::Detection { event, .. } => Some(event),
            _ => None,
        }));
        if round + 1 == checkpoint_round {
            early = Some(quiesce(&coord, cfg.sessions as u64, chunks_pushed));
        }
    }
    let late = quiesce(&coord, cfg.sessions as u64, chunks_pushed);
    let early = early.expect("checkpoint round ran");

    // flat memory: the quiesced ~10% checkpoint and the quiesced end of
    // the run book identical session memory AND identical telemetry
    assert_eq!(
        early.session_bytes, late.session_bytes,
        "session memory grew between quiesced checkpoints"
    );
    assert_eq!(
        early.telemetry_bytes(),
        late.telemetry_bytes(),
        "telemetry memory grew with chunk count"
    );
    assert_eq!(
        early.sessions_parked, cfg.sessions as u64,
        "parking must cover every drained session"
    );
    // every active session has drained and re-parked at least once (a
    // fast producer can coalesce rounds, so ≥ active is the firm floor)
    assert!(
        late.park_transitions >= active as u64,
        "active sessions never re-parked: {} transitions",
        late.park_transitions
    );
    // bounded scheduling: wake → dispatch p99 under a generous ceiling
    // (the gate is against runaway queueing, not a wall-clock benchmark)
    let sched_p99 = late.sched_latency.percentile(0.99);
    assert!(sched_p99 < 10_000_000, "sched p99 unbounded: {sched_p99} µs");

    // utterance oracle: the pool's decisions vs a direct chip, bit for bit
    let utter_pool: Vec<(Vec<i64>, usize)> = (0..16u64)
        .map(|i| {
            let label = (i % crate::NUM_CLASSES as u64) as usize;
            let mut rng = Pcg::with_stream(cfg.seed, 100 + i);
            let wave = crate::audio::synth_utterance(label, &mut rng);
            (crate::audio::quantize_12b(&wave), label)
        })
        .collect();
    let mut oracle_chip = KwsChip::new(params.clone(), chip.clone());
    let mut oracle_checked = 0u64;
    for k in 0..cfg.oracle_utterances {
        let (audio12, label) = &utter_pool[k % 16];
        let resp = coord
            .submit(Request {
                id: 0,
                stream: k as u64,
                audio12: audio12.clone(),
                label: Some(*label),
                trace: false,
                weights: None,
            })
            .expect("oracle submit")
            .wait_timeout(Duration::from_secs(1800))
            .expect("oracle response");
        let want = oracle_chip.process_utterance(audio12);
        assert_eq!(resp.class, want.class, "oracle {k}: class diverged");
        assert_eq!(resp.logits, want.logits, "oracle {k}: logits diverged");
        assert_eq!(resp.counted_frames, want.counted_frames, "oracle {k}");
        assert_eq!(resp.chip_cycles, want.total_cycles, "oracle {k}: cycles diverged");
        oracle_checked += 1;
    }

    // close the witness first and fold its remaining events
    let mut sessions = sessions.into_iter();
    let witness = sessions.next().expect("witness session");
    witness_events.extend(witness.close().into_iter().filter_map(|e| match e {
        StreamEvent::Detection { event, .. } => Some(event),
        _ => None,
    }));
    // streaming oracle: the same chunks through a fresh single-threaded
    // pipeline must produce the identical detection sequence
    let mut oracle_pipe =
        StreamPipeline::new(params.clone(), StreamConfig::for_chip(chip.clone()));
    let mut oracle_events: Vec<DetectionEvent> = Vec::new();
    for chunk in &witness_chunks {
        oracle_events
            .extend(oracle_pipe.push_audio(chunk).expect("oracle pipeline accepts chunks"));
    }
    assert_eq!(
        witness_events, oracle_events,
        "scheduled witness stream diverged from the single-threaded oracle"
    );

    // graceful teardown: close the rest (mostly parked) and verify every
    // gauge lands on zero
    for sess in sessions {
        sess.close();
    }
    let final_stats = coord.stats();
    assert_eq!(final_stats.session_bytes, 0, "closed sessions left memory booked");
    assert_eq!(final_stats.sessions_parked, 0);
    assert_eq!(final_stats.sessions_runnable, 0);
    assert!(final_stats.shed_overloaded >= 1, "the provoked shed went uncounted");
    let wall = t0.elapsed();

    ScaleSoakReport {
        sessions: cfg.sessions,
        active_sessions: active,
        workers: cfg.workers,
        sessions_per_core: cfg.sessions as f64 / cfg.workers as f64,
        rounds: cfg.rounds,
        chunks_done: final_stats.stream_chunks(),
        wall,
        parked_at_checkpoint: early.sessions_parked,
        session_bytes_early: early.session_bytes,
        session_bytes_late: late.session_bytes,
        telemetry_bytes: late.telemetry_bytes(),
        chunk_p50_us: late.chunk_latency.percentile(0.50),
        chunk_p99_us: late.chunk_latency.percentile(0.99),
        sched_p50_us: late.sched_latency.percentile(0.50),
        sched_p99_us: late.sched_latency.percentile(0.99),
        steals: final_stats.steals,
        park_transitions: final_stats.park_transitions,
        shed_overloaded: final_stats.shed_overloaded,
        oracle_checked,
        witness_detections: witness_events.len() as u64,
        final_stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng_quant(seed: u64) -> QuantParams {
        let mut rng = Pcg::new(seed);
        let mut q = QuantParams::zeroed();
        q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
        q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
        q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
        q
    }

    #[test]
    fn tiny_soak_completes_and_cross_checks() {
        let cfg = SoakConfig {
            utterances: 120,
            chunks_per_stream: 20,
            workers: 2,
            producers: 2,
            streams: 1,
            ..SoakConfig::quick()
        };
        let report = run_soak(rng_quant(1), ChipConfig::design_point(), &cfg);
        assert_eq!(report.utterances_done, 120);
        assert_eq!(report.chunks_done, 20);
        assert!(report.decisions_per_sec > 0.0);
        assert!(report.steady.decisions_per_sec() > 0.0, "steady-rate window empty");
        assert!(report.steady.completed <= report.utterances_done);
        assert!(report.percentile_rel_err() <= 0.05, "err {}", report.percentile_rel_err());
        assert_eq!(report.telemetry_bytes_early, report.telemetry_bytes_final);
        assert!(report.session_bytes_early <= MAX_SESSION_STATE_BYTES);
        assert_eq!(report.session_bytes_final, 0);
        assert!(report.simulated_audio_s > 15.0);
    }

    #[test]
    fn tiny_scale_soak_parks_sheds_and_stays_bit_exact() {
        let cfg = ScaleSoakConfig {
            workers: 2,
            sessions: 48,
            idle_pct: 75,
            rounds: 3,
            oracle_utterances: 4,
            ..ScaleSoakConfig::smoke()
        };
        let report = run_scale_soak(rng_quant(2), ChipConfig::design_point(), &cfg);
        assert_eq!(report.sessions, 48);
        assert_eq!(report.active_sessions, 12);
        assert_eq!(report.parked_at_checkpoint, 48);
        assert_eq!(report.session_bytes_early, report.session_bytes_late);
        assert_eq!(report.chunks_done, 12 * 3);
        assert_eq!(report.oracle_checked, 4);
        assert!(report.shed_overloaded >= 1);
        assert!(report.park_transitions >= 12);
        assert_eq!(report.final_stats.sessions_parked, 0);
    }
}

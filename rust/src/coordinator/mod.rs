//! Streaming serving coordinator: the "host side" of the system.
//!
//! The paper's chip sits behind an SPI link fed by a host (their MiniZed
//! FPGA). This module is that host, generalised into a small serving
//! runtime a deployment would actually use: audio streams are routed to a
//! pool of chip-twin workers over bounded queues (backpressure = the SPI
//! handshake), results and chip telemetry aggregate centrally, and the
//! router tolerates slow/stalled workers by spilling to the least-loaded
//! healthy queue.
//!
//! Threading: std threads + mpsc (the vendored dependency set has no
//! tokio); one thread per worker, one router, callers submit through the
//! [`Coordinator`] directly or concurrently through cloneable [`Client`]
//! handles. Ordering within a stream is preserved by pinning each stream id
//! to a worker (consistent hashing), which also keeps the per-utterance
//! recurrent state meaningful; the spill path trades that ordering for
//! availability when the pinned queue is saturated.
//!
//! Two kinds of work share the worker lanes:
//!
//! * per-utterance [`Request`]s — stateless between requests, spillable;
//! * long-lived [`StreamSession`]s — open a stream, push audio chunks of
//!   any size, receive [`StreamEvent`]s asynchronously. A session's
//!   [`crate::stream::StreamPipeline`] (chip + VAD + wakeword state
//!   machine) lives on the stream's *pinned* worker for its whole life:
//!   chunks never spill (the recurrent state is there), so a full pinned
//!   queue surfaces as backpressure to the producer instead.
//!
//! Telemetry is contention-free and bounded: the worker hot loop records
//! only into its own [`telemetry::WorkerShard`] (relaxed counters + a
//! fixed-size log-bucketed latency histogram — no locks, no allocation,
//! O(1) memory in the request count), [`Coordinator::stats`] folds the
//! shards on demand, and chip power/energy reports are published per
//! epoch / on [`Coordinator::reports`] pull, never per utterance. The
//! [`soak`] harness drives sustained mixed load against exactly these
//! guarantees.

pub mod soak;
pub mod telemetry;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{
    sync_channel, Receiver, Sender, SyncSender, TryRecvError, TrySendError,
};
use std::sync::{Arc, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::accel::gru::QuantParams;
use crate::chip::{ChipConfig, ChipReport, KwsChip};
use crate::energy::ChipActivity;
use crate::stream::detector::DetectionEvent;
use crate::stream::{StreamConfig, StreamPipeline};
use crate::util::hist::LogHistogram;
use telemetry::{WorkerShard, REPORT_EPOCH};

/// One inference request: a 1 s utterance on a logical stream.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// logical stream (microphone); pins the request to a worker
    pub stream: u64,
    pub audio12: Vec<i64>,
    /// optional ground truth for online accuracy accounting
    pub label: Option<usize>,
}

/// Inference result.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub stream: u64,
    pub class: usize,
    pub correct: Option<bool>,
    /// simulated chip computing latency for this utterance (ms)
    pub chip_latency_ms: f64,
    /// wall-clock service time (queue + simulation)
    pub service: Duration,
    pub worker: usize,
}

/// Per-worker serving counters (the per-lane view of routing health:
/// a worker with high `pinned_full` is a stall hot-spot; high `spilled_in`
/// means it absorbs other lanes' overflow).
#[derive(Debug, Default, Clone, Copy)]
pub struct LaneStats {
    /// utterance requests this worker completed
    pub completed: u64,
    /// requests that arrived here by spilling off a full pinned lane
    pub spilled_in: u64,
    /// submissions that found this worker's queue full while it was the
    /// pinned target (each one either spilled elsewhere or was rejected)
    pub pinned_full: u64,
    /// streaming audio chunks processed by this worker's sessions
    pub stream_chunks: u64,
}

/// Aggregate serving statistics: a point-in-time fold of the per-worker
/// telemetry shards and the lock-free routing counters. Every field is
/// fixed-size — the snapshot's memory footprint is independent of how many
/// requests the pool has served (see [`Stats::telemetry_bytes`]).
#[derive(Debug, Clone, Default)]
pub struct Stats {
    pub completed: u64,
    pub correct: u64,
    pub labelled: u64,
    pub rejected: u64,
    /// requests accepted by a non-pinned worker (pinned queue was full);
    /// folded from per-lane atomics by [`Coordinator::stats`]
    pub spilled: u64,
    /// wall-clock utterance service-time distribution (µs), log-bucketed
    pub latency: LogHistogram,
    /// wall-clock stream-chunk service-time distribution (µs)
    pub chunk_latency: LogHistogram,
    /// merged chip activity across workers
    pub activity: ChipActivity,
    /// per-worker routing/serving counters (indexed by worker; folded
    /// from lane atomics + telemetry shards by [`Coordinator::stats`])
    pub per_worker: Vec<LaneStats>,
}

impl Stats {
    pub fn accuracy(&self) -> f64 {
        if self.labelled == 0 {
            0.0
        } else {
            self.correct as f64 / self.labelled as f64
        }
    }

    pub fn p50_us(&self) -> u64 {
        self.latency.percentile(0.50)
    }

    pub fn p99_us(&self) -> u64 {
        self.latency.percentile(0.99)
    }

    /// Heap footprint of this telemetry snapshot — constant in the request
    /// count by construction (histogram bucket arrays + per-worker lane
    /// table). The soak harness asserts it stays flat under load.
    pub fn telemetry_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.latency.heap_bytes()
            + self.chunk_latency.heap_bytes()
            + self.per_worker.len() * std::mem::size_of::<LaneStats>()
    }
}

/// Exact percentile of a sample by the exclusive nearest-rank rule with a
/// round-half-up rank: `rank = ⌊p·(n+1) + ½⌋` clamped to `[1, n]`, 1-based
/// into the sorted data. p99 of 100 samples is the 100th order statistic —
/// the previous truncating index `⌊(n-1)·p⌋` returned the 99th, i.e. the
/// p98 sample. [`LogHistogram::percentile`] uses the same rank rule, so
/// the two agree to within one bucket's representative-value rounding.
pub fn percentile(xs: &[u64], p: f64) -> u64 {
    if xs.is_empty() {
        return 0;
    }
    let mut v = xs.to_vec();
    v.sort_unstable();
    let n = v.len();
    let rank = ((p * (n as f64 + 1.0)) + 0.5).floor() as usize;
    v[rank.clamp(1, n) - 1]
}

/// One unit of work on a worker lane. Stream jobs are keyed by a unique
/// *session* id (the stream id only picks the pinned lane), so two
/// sessions opened on the same stream id coexist instead of clobbering
/// each other's worker state.
enum Job {
    /// a per-utterance inference request (spillable)
    Utterance(Request, Instant),
    /// open a streaming session pinned to this worker (`config`: per-
    /// session VAD/detector tuning, `None` = worker default; `alive` is
    /// cleared by the client handle so the worker can GC sessions whose
    /// Close was never deliverable)
    StreamOpen {
        session: u64,
        config: Option<StreamConfig>,
        events: Sender<StreamEvent>,
        alive: Arc<AtomicBool>,
    },
    /// an audio chunk for an open session
    StreamData { session: u64, chunk: Vec<i64>, enqueued: Instant },
    /// close a session (flushes telemetry, emits [`StreamEvent::Closed`])
    StreamClose { session: u64 },
    /// publish a fresh chip-report snapshot into the telemetry shard and
    /// acknowledge (the pull half of [`Coordinator::reports`])
    PublishReport { ack: Sender<()> },
}

/// Asynchronous output of a [`StreamSession`].
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// the wakeword state machine confirmed a detection
    Detection(DetectionEvent),
    /// final telemetry, emitted exactly once when the session closes
    Closed { frames: u64, gated_frames: u64 },
}

/// One worker's request lane (the submit-side view).
struct Lane {
    tx: SyncSender<Job>,
    depth: Arc<AtomicU64>,
    /// failure-injection: worker refuses work while true (tests)
    stalled: Arc<AtomicBool>,
    /// lock-free routing counters, folded into [`Stats::per_worker`] at
    /// read time — the submit hot path must not take any lock
    pinned_full: AtomicU64,
    spilled_in: AtomicU64,
}

/// Shared routing state: what [`Coordinator::submit`] and every [`Client`]
/// operate on. Dropping the coordinator drops the lanes' senders, which is
/// what tells workers to drain and exit.
struct Router {
    lanes: Vec<Lane>,
    /// per-worker telemetry shards (worker w writes shards[w] only)
    shards: Vec<Arc<WorkerShard>>,
    /// submissions rejected with every queue saturated (lock-free; the
    /// old code took the stats mutex on this path)
    rejected: AtomicU64,
    next_id: AtomicU64,
    /// unique ids for [`StreamSession`]s (stream ids may repeat)
    next_session: AtomicU64,
}

impl Router {
    fn pinned_lane(&self, stream: u64) -> usize {
        (stream as usize) % self.lanes.len()
    }

    /// Routing: the stream's pinned worker unless its queue is full, then
    /// least-loaded spill; `Err` when every queue is saturated (global
    /// backpressure — caller must retry/shed).
    fn submit(&self, mut req: Request) -> Result<u64, Request> {
        req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let id = req.id;
        let now = Instant::now();
        let pinned = self.pinned_lane(req.stream);
        let mut req = match self.try_lane(pinned, req, now) {
            Ok(()) => return Ok(id),
            Err(r) => {
                self.lanes[pinned].pinned_full.fetch_add(1, Ordering::Relaxed);
                r
            }
        };
        // spill: least-loaded first
        let mut order: Vec<usize> = (0..self.lanes.len()).filter(|&w| w != pinned).collect();
        order.sort_by_key(|&w| self.lanes[w].depth.load(Ordering::Relaxed));
        for w in order {
            req = match self.try_lane(w, req, now) {
                Ok(()) => {
                    self.lanes[w].spilled_in.fetch_add(1, Ordering::Relaxed);
                    return Ok(id);
                }
                Err(r) => r,
            };
        }
        self.rejected.fetch_add(1, Ordering::Relaxed);
        Err(req)
    }

    fn try_lane(&self, w: usize, req: Request, t: Instant) -> Result<(), Request> {
        match self.lanes[w].tx.try_send(Job::Utterance(req, t)) {
            Ok(()) => {
                self.lanes[w].depth.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(
                TrySendError::Full(Job::Utterance(r, _))
                | TrySendError::Disconnected(Job::Utterance(r, _)),
            ) => Err(r),
            Err(_) => unreachable!("utterance job came back as a different variant"),
        }
    }

    /// Non-blocking stream-job delivery to the stream's pinned lane (no
    /// spill: the session state lives there). `Err` hands the job back.
    fn try_stream_job(&self, stream: u64, job: Job) -> Result<(), Job> {
        let lane = self.pinned_lane(stream);
        match self.lanes[lane].tx.try_send(job) {
            Ok(()) => {
                self.lanes[lane].depth.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(TrySendError::Full(j) | TrySendError::Disconnected(j)) => Err(j),
        }
    }

    /// Blocking stream-job delivery (control messages: open/close). `Err`
    /// only when the worker pool is gone.
    fn send_stream_job(&self, stream: u64, job: Job) -> Result<(), Job> {
        let lane = self.pinned_lane(stream);
        match self.lanes[lane].tx.send(job) {
            Ok(()) => {
                self.lanes[lane].depth.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(e) => Err(e.0),
        }
    }
}

/// Cloneable, thread-safe submission handle. Holds only a weak reference:
/// once the owning [`Coordinator`] is dropped, submissions fail cleanly
/// (the request is handed back) instead of keeping dead workers alive.
#[derive(Clone)]
pub struct Client {
    router: Weak<Router>,
}

impl Client {
    /// Submit a request (same routing/backpressure contract as
    /// [`Coordinator::submit`]). `Err` means either transient backpressure
    /// or a dropped pool — retry loops must check [`Client::is_closed`]
    /// to tell the two apart, or they will spin forever after shutdown.
    pub fn submit(&self, req: Request) -> Result<u64, Request> {
        match self.router.upgrade() {
            Some(router) => router.submit(req),
            None => Err(req),
        }
    }

    /// True once the owning [`Coordinator`] has been dropped: every further
    /// submit will fail, so a retrying producer should stop.
    pub fn is_closed(&self) -> bool {
        self.router.strong_count() == 0
    }
}

/// A long-lived streaming session: the client half of one always-on
/// detection pipeline living on the stream's pinned worker.
///
/// Push 12-bit audio chunks of any size with [`push`](Self::push)
/// (non-blocking, backpressured) or [`push_blocking`](Self::push_blocking);
/// detections arrive asynchronously on [`events`](Self::events). Dropping
/// the session (or calling [`close`](Self::close)) tears down the worker
/// state and flushes its chip telemetry into the pool [`Stats`].
pub struct StreamSession {
    stream: u64,
    /// unique id keying the worker-side state (stream ids may repeat)
    session: u64,
    router: Weak<Router>,
    /// asynchronous session output ([`StreamEvent`])
    pub events: Receiver<StreamEvent>,
    closed: bool,
    /// cleared on close/drop; the worker GCs sessions with a dead flag
    alive: Arc<AtomicBool>,
}

impl StreamSession {
    pub fn stream_id(&self) -> u64 {
        self.stream
    }

    /// Submit an audio chunk (non-blocking). `Err` hands the chunk back:
    /// the pinned worker's queue is full (backpressure — pace the
    /// producer) or the pool is gone.
    pub fn push(&self, audio12: Vec<i64>) -> Result<(), Vec<i64>> {
        let Some(router) = self.router.upgrade() else {
            return Err(audio12);
        };
        router
            .try_stream_job(
                self.stream,
                Job::StreamData {
                    session: self.session,
                    chunk: audio12,
                    enqueued: Instant::now(),
                },
            )
            .map_err(|j| match j {
                Job::StreamData { chunk, .. } => chunk,
                _ => unreachable!("data job came back as a different variant"),
            })
    }

    /// Submit an audio chunk, blocking while the pinned queue is full.
    /// `Err` only when the pool is gone.
    pub fn push_blocking(&self, audio12: Vec<i64>) -> Result<(), Vec<i64>> {
        let Some(router) = self.router.upgrade() else {
            return Err(audio12);
        };
        router
            .send_stream_job(
                self.stream,
                Job::StreamData {
                    session: self.session,
                    chunk: audio12,
                    enqueued: Instant::now(),
                },
            )
            .map_err(|j| match j {
                Job::StreamData { chunk, .. } => chunk,
                _ => unreachable!("data job came back as a different variant"),
            })
    }

    /// Collect whatever events have arrived so far (non-blocking).
    pub fn try_events(&self) -> Vec<StreamEvent> {
        self.events.try_iter().collect()
    }

    /// Close the session and collect every remaining event, including the
    /// final [`StreamEvent::Closed`] telemetry marker. Waits (bounded) for
    /// the worker to acknowledge; use `drop` for a fire-and-forget close.
    pub fn close(mut self) -> Vec<StreamEvent> {
        self.send_close(true);
        let mut out = Vec::new();
        while let Ok(ev) = self.events.recv_timeout(Duration::from_secs(60)) {
            let done = matches!(ev, StreamEvent::Closed { .. });
            out.push(ev);
            if done {
                break;
            }
        }
        out
    }

    /// `blocking` = wait for lane space (explicit [`close`](Self::close));
    /// the Drop path must never hang, so it retries briefly and then gives
    /// up — the worker GCs the session when it notices the event channel
    /// is disconnected (or at pool shutdown).
    fn send_close(&mut self, blocking: bool) {
        if self.closed {
            return;
        }
        self.closed = true;
        // even if the Close below cannot be delivered, the cleared flag
        // lets the worker GC the session on a later job
        self.alive.store(false, Ordering::Relaxed);
        let Some(router) = self.router.upgrade() else {
            return;
        };
        let mut job = Job::StreamClose { session: self.session };
        if blocking {
            let _ = router.send_stream_job(self.stream, job);
            return;
        }
        for _ in 0..20 {
            job = match router.try_stream_job(self.stream, job) {
                Ok(()) => return,
                Err(j) => j,
            };
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

impl Drop for StreamSession {
    fn drop(&mut self) {
        // non-blocking: a wedged lane must not hang a destructor; an
        // undelivered Close is flushed by the worker's shutdown drain
        self.send_close(false);
    }
}

/// The coordinator: worker pool + router state + telemetry shards.
pub struct Coordinator {
    /// `Some` until drop; taken first so lane senders close before joining
    router: Option<Arc<Router>>,
    handles: Vec<JoinHandle<()>>,
    /// kept alive so the response channel survives worker churn
    #[allow(dead_code)]
    resp_tx: SyncSender<Response>,
    pub resp_rx: Receiver<Response>,
}

impl Coordinator {
    /// Spawn `n_workers` chip twins, each with its own weight copy.
    pub fn new(params: QuantParams, config: ChipConfig, n_workers: usize, queue_depth: usize) -> Self {
        assert!(n_workers > 0);
        let (resp_tx, resp_rx) = sync_channel::<Response>(n_workers * queue_depth.max(4) * 4);
        let mut lanes = Vec::with_capacity(n_workers);
        let mut shards = Vec::with_capacity(n_workers);
        let mut handles = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (tx, rx) = sync_channel::<Job>(queue_depth);
            let stalled = Arc::new(AtomicBool::new(false));
            let depth = Arc::new(AtomicU64::new(0));
            let shard = Arc::new(WorkerShard::default());
            let handle = {
                let params = params.clone();
                let config = config.clone();
                let resp_tx = resp_tx.clone();
                let stalled = Arc::clone(&stalled);
                let depth = Arc::clone(&depth);
                let shard = Arc::clone(&shard);
                std::thread::Builder::new()
                    .name(format!("chip-worker-{w}"))
                    .spawn(move || worker_loop(w, params, config, rx, resp_tx, shard, stalled, depth))
                    .expect("spawn worker")
            };
            lanes.push(Lane {
                tx,
                depth,
                stalled,
                pinned_full: AtomicU64::new(0),
                spilled_in: AtomicU64::new(0),
            });
            shards.push(shard);
            handles.push(handle);
        }
        let router = Arc::new(Router {
            lanes,
            shards,
            rejected: AtomicU64::new(0),
            next_id: AtomicU64::new(0),
            next_session: AtomicU64::new(0),
        });
        Self { router: Some(router), handles, resp_tx, resp_rx }
    }

    fn router(&self) -> &Router {
        self.router.as_ref().expect("router alive until drop")
    }

    /// Submit a request. Routing: the stream's pinned worker unless its
    /// queue is full, then least-loaded healthy spill; `Err` when every
    /// queue is saturated (global backpressure — caller must retry/shed).
    pub fn submit(&self, req: Request) -> Result<u64, Request> {
        self.router().submit(req)
    }

    /// A cloneable submission handle for concurrent producers.
    pub fn client(&self) -> Client {
        Client { router: Arc::downgrade(self.router.as_ref().expect("router alive")) }
    }

    /// Open a long-lived streaming session on `stream`'s pinned worker:
    /// an always-on detection pipeline (chip + VAD + wakeword state
    /// machine) whose recurrent state persists until the session closes.
    /// Stream ids may be reused — each call creates an independent
    /// session (internally keyed by a unique session id).
    ///
    /// Delivery of the open is a control message on the pinned lane: if
    /// that worker's queue is momentarily full, this call blocks until
    /// space frees (it does not fail on transient backpressure). If the
    /// pinned worker has *died* (its lane is disconnected), the returned
    /// session is already dead: pushes hand the chunk back and the event
    /// channel is empty — the same recoverable contract as
    /// [`Client::submit`] after shutdown, instead of a panic.
    pub fn open_stream(&self, stream: u64) -> StreamSession {
        self.open_stream_inner(stream, None)
    }

    /// [`open_stream`](Self::open_stream) with per-session VAD/detector
    /// tuning (e.g. [`crate::stream::vad::VadConfig::disabled`] for an
    /// energy A/B stream, or per-microphone detector thresholds).
    pub fn open_stream_with(&self, stream: u64, config: StreamConfig) -> StreamSession {
        self.open_stream_inner(stream, Some(config))
    }

    fn open_stream_inner(&self, stream: u64, config: Option<StreamConfig>) -> StreamSession {
        let (tx, rx) = std::sync::mpsc::channel();
        let router = self.router.as_ref().expect("router alive");
        let session = router.next_session.fetch_add(1, Ordering::Relaxed);
        let alive = Arc::new(AtomicBool::new(true));
        let job =
            Job::StreamOpen { session, config, events: tx, alive: Arc::clone(&alive) };
        if router.send_stream_job(stream, job).is_err() {
            return StreamSession {
                stream,
                session,
                router: Weak::new(),
                events: rx,
                closed: true,
                alive,
            };
        }
        StreamSession {
            stream,
            session,
            router: Arc::downgrade(router),
            events: rx,
            closed: false,
            alive,
        }
    }

    /// Block until `n` responses have been collected (helper for batch runs).
    pub fn collect(&self, n: usize, timeout: Duration) -> Vec<Response> {
        let deadline = Instant::now() + timeout;
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break;
            }
            match self.resp_rx.recv_timeout(remaining) {
                Ok(r) => out.push(r),
                Err(_) => break,
            }
        }
        out
    }

    /// Aggregate statistics snapshot: folds the per-worker telemetry
    /// shards (counters, latency histograms, chip activity) and the
    /// lock-free routing counters. Pure read — no worker is interrupted
    /// and no lock on any hot path is taken.
    pub fn stats(&self) -> Stats {
        let router = self.router();
        let mut s = Stats {
            per_worker: Vec::with_capacity(router.lanes.len()),
            ..Stats::default()
        };
        let mut spilled = 0;
        for (lane, shard) in router.lanes.iter().zip(router.shards.iter()) {
            let completed = shard.completed.load(Ordering::Relaxed);
            s.completed += completed;
            s.labelled += shard.labelled.load(Ordering::Relaxed);
            s.correct += shard.correct.load(Ordering::Relaxed);
            s.latency.merge(&shard.latency.snapshot());
            s.chunk_latency.merge(&shard.chunk_latency.snapshot());
            s.activity.merge(&shard.activity.snapshot());
            let sp = lane.spilled_in.load(Ordering::Relaxed);
            spilled += sp;
            s.per_worker.push(LaneStats {
                completed,
                spilled_in: sp,
                pinned_full: lane.pinned_full.load(Ordering::Relaxed),
                stream_chunks: shard.stream_chunks.load(Ordering::Relaxed),
            });
        }
        s.spilled = spilled;
        s.rejected = router.rejected.load(Ordering::Relaxed);
        s
    }

    /// Latest per-worker chip reports (power/energy telemetry),
    /// *pull-based*: a publish request is enqueued on every reachable lane
    /// and acknowledged snapshots are read back (bounded wait). Lanes that
    /// are full or stalled fall back to their last epoch/idle snapshot —
    /// reports are never computed on the per-utterance hot path.
    pub fn reports(&self) -> HashMap<usize, ChipReport> {
        let router = self.router();
        let (ack_tx, ack_rx) = std::sync::mpsc::channel();
        let mut pending = 0usize;
        for lane in &router.lanes {
            if lane.tx.try_send(Job::PublishReport { ack: ack_tx.clone() }).is_ok() {
                lane.depth.fetch_add(1, Ordering::Relaxed);
                pending += 1;
            }
        }
        drop(ack_tx);
        let deadline = Instant::now() + Duration::from_secs(5);
        while pending > 0 {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() || ack_rx.recv_timeout(remaining).is_err() {
                break;
            }
            pending -= 1;
        }
        let mut out = HashMap::new();
        for (w, shard) in router.shards.iter().enumerate() {
            if let Some(r) = *shard.report.lock().unwrap() {
                out.insert(w, r);
            }
        }
        out
    }

    /// Failure injection: stall/unstall a worker (its queue still accepts
    /// work until full; the router then spills around it).
    pub fn set_stalled(&self, worker: usize, stalled: bool) {
        self.router().lanes[worker].stalled.store(stalled, Ordering::SeqCst);
    }

    pub fn n_workers(&self) -> usize {
        self.router().lanes.len()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // close request queues (clients only hold weak refs); workers drain
        // their queues and exit, then join
        self.router.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Worker-side state of one open streaming session.
struct WorkerSession {
    pipeline: StreamPipeline,
    events: Sender<StreamEvent>,
    /// cleared by the client handle on close/drop
    alive: Arc<AtomicBool>,
}

impl WorkerSession {
    /// Flush final telemetry into the worker's shard and notify the client.
    fn finish(mut self, shard: &WorkerShard) {
        shard.activity.add(&self.pipeline.take_activity_delta());
        let activity = self.pipeline.chip.activity();
        let _ = self.events.send(StreamEvent::Closed {
            frames: activity.frames,
            gated_frames: activity.gated_frames,
        });
    }
}

/// Publish a fresh cumulative chip report into the shard's pull slot
/// (only once the chip has actually processed something — an idle worker
/// stays absent from [`Coordinator::reports`], as before).
fn publish_report(shard: &WorkerShard, chip: &KwsChip) {
    if chip.activity().frames > 0 {
        *shard.report.lock().unwrap() = Some(chip.report());
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    index: usize,
    params: QuantParams,
    config: ChipConfig,
    rx: Receiver<Job>,
    resp_tx: SyncSender<Response>,
    shard: Arc<WorkerShard>,
    stalled: Arc<AtomicBool>,
    depth: Arc<AtomicU64>,
) {
    let mut chip = KwsChip::new(params.clone(), config.clone());
    let mut sessions: HashMap<u64, WorkerSession> = HashMap::new();
    // chip activity is flushed into the shard as monotonic deltas — the
    // chip's own counters are never reset, so its cumulative report stays
    // meaningful and nothing is double-counted
    let mut flushed = ChipActivity::default();
    let mut jobs_since_report = 0u64;
    'outer: loop {
        let job = match rx.try_recv() {
            Ok(j) => j,
            Err(TryRecvError::Empty) => {
                // lane drained: publish a fresh report before blocking, so
                // pull-side reads are never staler than the last idle moment
                publish_report(&shard, &chip);
                jobs_since_report = 0;
                match rx.recv() {
                    Ok(j) => j,
                    Err(_) => break 'outer,
                }
            }
            Err(TryRecvError::Disconnected) => break 'outer,
        };
        while stalled.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        depth.fetch_sub(1, Ordering::Relaxed);
        match job {
            Job::Utterance(req, enqueued) => {
                let decision = chip.process_utterance(&req.audio12);
                let lat_ms = decision.frame_cycles.iter().sum::<u64>() as f64
                    / decision.frame_cycles.len().max(1) as f64
                    / crate::energy::calib::CLOCK_HZ
                    * 1e3;
                let correct = req.label.map(|l| l == decision.class);
                let resp = Response {
                    id: req.id,
                    stream: req.stream,
                    class: decision.class,
                    correct,
                    chip_latency_ms: lat_ms,
                    service: enqueued.elapsed(),
                    worker: index,
                };
                // hot path: relaxed adds on this worker's own shard — no
                // lock, no allocation, no report rollup
                shard.completed.fetch_add(1, Ordering::Relaxed);
                if let Some(c) = correct {
                    shard.labelled.fetch_add(1, Ordering::Relaxed);
                    if c {
                        shard.correct.fetch_add(1, Ordering::Relaxed);
                    }
                }
                shard.latency.record(resp.service.as_micros() as u64);
                let act = chip.activity();
                shard.activity.add(&act.delta_since(&flushed));
                flushed = act;
                if resp_tx.send(resp).is_err() {
                    break;
                }
            }
            Job::StreamOpen { session, config: stream_cfg, events, alive } => {
                let cfg =
                    stream_cfg.unwrap_or_else(|| StreamConfig::for_chip(config.clone()));
                let pipeline = StreamPipeline::new(params.clone(), cfg);
                // session ids are unique; a collision would be a router bug,
                // but never leak the old session's telemetry silently
                if let Some(old) =
                    sessions.insert(session, WorkerSession { pipeline, events, alive })
                {
                    old.finish(&shard);
                }
            }
            Job::StreamData { session, chunk, enqueued } => {
                // chunks for unknown/closed sessions are dropped (a late
                // push after close is not an error)
                if let Some(sess) = sessions.get_mut(&session) {
                    let detections = sess.pipeline.push_audio(&chunk);
                    shard.stream_chunks.fetch_add(1, Ordering::Relaxed);
                    shard.chunk_latency.record(enqueued.elapsed().as_micros() as u64);
                    shard.activity.add(&sess.pipeline.take_activity_delta());
                    for d in detections {
                        let _ = sess.events.send(StreamEvent::Detection(d));
                    }
                }
            }
            Job::StreamClose { session } => {
                if let Some(sess) = sessions.remove(&session) {
                    sess.finish(&shard);
                }
            }
            Job::PublishReport { ack } => {
                publish_report(&shard, &chip);
                jobs_since_report = 0;
                let _ = ack.send(());
            }
        }
        // bound report staleness under sustained load (a lane that never
        // drains still publishes every REPORT_EPOCH jobs)
        jobs_since_report += 1;
        if jobs_since_report >= REPORT_EPOCH {
            publish_report(&shard, &chip);
            jobs_since_report = 0;
        }
        // GC sessions whose client vanished without a deliverable Close
        // (StreamSession::drop on a saturated lane clears `alive` and
        // gives up) — otherwise their pipelines would live until pool
        // shutdown
        if !sessions.is_empty() {
            let dead: Vec<u64> = sessions
                .iter()
                .filter(|(_, s)| !s.alive.load(Ordering::Relaxed))
                .map(|(&k, _)| k)
                .collect();
            for k in dead {
                if let Some(sess) = sessions.remove(&k) {
                    sess.finish(&shard);
                }
            }
        }
    }
    // pool shutdown with sessions still open: flush their telemetry
    for (_, sess) in sessions.drain() {
        sess.finish(&shard);
    }
    publish_report(&shard, &chip);
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::util::prng::Pcg;

    fn rng_quant(seed: u64) -> QuantParams {
        let mut rng = Pcg::new(seed);
        let mut q = QuantParams::zeroed();
        q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
        q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
        q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
        q
    }

    fn request(stream: u64, seed: u64) -> Request {
        let mut rng = Pcg::new(seed);
        let label = (seed % 12) as usize;
        let audio = crate::audio::synth_utterance(label, &mut rng);
        Request { id: 0, stream, audio12: crate::audio::quantize_12b(&audio), label: Some(label) }
    }

    #[test]
    fn percentile_uses_round_half_up_rank() {
        let v: Vec<u64> = (1..=100).collect();
        // the old truncating index returned v[98] = 99 (the p98 sample)
        assert_eq!(percentile(&v, 0.99), 100);
        assert_eq!(percentile(&v, 0.50), 51);
        assert_eq!(percentile(&v, 1.0), 100);
        assert_eq!(percentile(&v, 0.0), 1);
        // exact small-N: median of an odd-length sample is the middle
        assert_eq!(percentile(&[5, 1, 3], 0.50), 3);
        assert_eq!(percentile(&[1, 2, 3, 4, 5], 0.50), 3);
        assert_eq!(percentile(&[42], 0.99), 42);
        assert_eq!(percentile(&[], 0.99), 0);
    }

    #[test]
    fn histogram_percentile_within_one_bucket_of_exact() {
        // same rank rule => the histogram lands in exactly the bucket
        // holding the exact order statistic, so the answers differ only by
        // the bucket's midpoint rounding (≤ 1/64 relative)
        let mut rng = Pcg::new(9);
        let mut hist = LogHistogram::new();
        let mut sample = Vec::new();
        for _ in 0..5000 {
            let v = (rng.below(1 << 16) as u64 + 1) * (1 + rng.below(64) as u64);
            sample.push(v);
            hist.record(v);
        }
        for p in [0.50, 0.90, 0.99] {
            let exact = percentile(&sample, p);
            let approx = hist.percentile(p);
            assert_eq!(
                crate::util::hist::bucket_index(exact),
                crate::util::hist::bucket_index(approx),
                "p{p}: exact {exact} vs hist {approx} landed in different buckets"
            );
            let rel = (approx as f64 - exact as f64).abs() / exact as f64;
            assert!(rel <= 1.0 / 64.0 + 1e-12, "p{p}: rel err {rel}");
        }
    }

    #[test]
    fn serves_requests_and_aggregates() {
        let coord =
            Coordinator::new(rng_quant(1), ChipConfig::design_point(), 2, 8);
        let n = 6;
        for i in 0..n {
            coord.submit(request(i as u64, i as u64)).expect("submit");
        }
        let responses = coord.collect(n, Duration::from_secs(60));
        assert_eq!(responses.len(), n);
        let stats = coord.stats();
        assert_eq!(stats.completed, n as u64);
        assert_eq!(stats.labelled, n as u64);
        assert_eq!(stats.latency.count(), n as u64);
        assert!(stats.activity.frames >= (n * 62) as u64);
        // no request lost or duplicated
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n);
    }

    #[test]
    fn stream_pinning_is_stable() {
        let coord = Coordinator::new(rng_quant(2), ChipConfig::design_point(), 3, 8);
        for _ in 0..4 {
            coord.submit(request(7, 1)).unwrap();
        }
        let responses = coord.collect(4, Duration::from_secs(60));
        let workers: std::collections::HashSet<usize> =
            responses.iter().map(|r| r.worker).collect();
        assert_eq!(workers.len(), 1, "stream 7 must stay on its pinned worker");
    }

    #[test]
    fn spills_around_stalled_worker() {
        let coord = Coordinator::new(rng_quant(3), ChipConfig::design_point(), 2, 1);
        // stall worker 0 (stream 0 pins there), saturate its queue of 1,
        // further submissions must spill to worker 1 and still complete
        coord.set_stalled(0, true);
        let mut accepted = 0;
        for i in 0..4 {
            if coord.submit(request(0, 10 + i)).is_ok() {
                accepted += 1;
            }
        }
        assert!(accepted >= 2, "spill path dead: {accepted}");
        coord.set_stalled(0, false);
        let responses = coord.collect(accepted, Duration::from_secs(60));
        assert_eq!(responses.len(), accepted);
    }

    #[test]
    fn backpressure_rejects_when_saturated() {
        let coord = Coordinator::new(rng_quant(4), ChipConfig::design_point(), 1, 1);
        coord.set_stalled(0, true);
        let mut rejected = 0;
        for i in 0..6 {
            if coord.submit(request(i, i)).is_err() {
                rejected += 1;
            }
        }
        assert!(rejected >= 3, "backpressure missing: only {rejected} rejected");
        assert!(coord.stats().rejected >= 3);
        coord.set_stalled(0, false);
    }

    #[test]
    fn accuracy_accounting() {
        let coord = Coordinator::new(rng_quant(5), ChipConfig::design_point(), 2, 8);
        for i in 0..4 {
            coord.submit(request(i, i)).unwrap();
        }
        coord.collect(4, Duration::from_secs(60));
        let s = coord.stats();
        assert_eq!(s.labelled, 4);
        assert!(s.accuracy() >= 0.0 && s.accuracy() <= 1.0);
        assert!(s.p50_us() > 0);
        assert!(s.p99_us() >= s.p50_us());
    }

    #[test]
    fn stats_memory_is_independent_of_request_count() {
        let coord = Coordinator::new(rng_quant(13), ChipConfig::design_point(), 2, 8);
        coord.submit(request(0, 1)).unwrap();
        coord.collect(1, Duration::from_secs(60));
        let before = coord.stats().telemetry_bytes();
        for i in 0..12 {
            coord.submit(request(i % 3, 60 + i)).unwrap();
        }
        coord.collect(12, Duration::from_secs(60));
        let after = coord.stats();
        assert_eq!(after.completed, 13);
        assert_eq!(after.telemetry_bytes(), before, "telemetry grew with requests");
    }

    #[test]
    fn reports_are_pull_based_and_fresh() {
        let coord = Coordinator::new(rng_quant(14), ChipConfig::design_point(), 2, 8);
        // an idle pool has no reports (no chip has processed anything)
        assert!(coord.reports().is_empty(), "idle workers must not report");
        for i in 0..4 {
            coord.submit(request(i, i)).unwrap();
        }
        coord.collect(4, Duration::from_secs(60));
        let reports = coord.reports();
        assert!(!reports.is_empty(), "pull returned nothing after work");
        let frames: u64 = reports.values().map(|r| r.frames).sum();
        assert_eq!(frames, 4 * 62, "reports must reflect cumulative work");
        for r in reports.values() {
            assert!(r.power.total_uw() > 0.0);
            assert!(r.latency_ms > 0.0, "report computed on zeroed activity");
        }
    }

    #[test]
    fn per_worker_counters_track_spill_and_rejection() {
        let coord = Coordinator::new(rng_quant(7), ChipConfig::design_point(), 2, 1);
        coord.set_stalled(0, true);
        let mut accepted = 0;
        for i in 0..6 {
            if coord.submit(request(0, 40 + i)).is_ok() {
                accepted += 1;
            }
        }
        coord.set_stalled(0, false);
        let responses = coord.collect(accepted, Duration::from_secs(60));
        assert_eq!(responses.len(), accepted);
        let s = coord.stats();
        assert_eq!(s.per_worker.len(), 2);
        assert!(s.per_worker[0].pinned_full >= 1, "pinned-full stalls not visible");
        assert!(s.spilled >= 1, "no spill counted");
        assert_eq!(s.spilled, s.per_worker[1].spilled_in, "spill target mismatch");
        let done: u64 = s.per_worker.iter().map(|w| w.completed).sum();
        assert_eq!(done, s.completed, "per-worker completions don't sum up");
    }

    #[test]
    fn stream_session_lifecycle_and_telemetry() {
        let coord = Coordinator::new(rng_quant(8), ChipConfig::design_point(), 2, 8);
        let sess = coord.open_stream(3);
        let cfg = crate::audio::track::TrackConfig {
            duration_s: 4,
            keywords: 2,
            fillers: 0,
            noise: (0.001, 0.002),
        };
        let (audio12, _) = crate::audio::track::synth_track(&cfg, 9);
        let n_chunks = audio12.chunks(512).count() as u64;
        for c in audio12.chunks(512) {
            sess.push_blocking(c.to_vec()).expect("pool alive");
        }
        let events = sess.close();
        let closed_frames = events.iter().find_map(|e| match e {
            StreamEvent::Closed { frames, .. } => Some(*frames),
            _ => None,
        });
        assert_eq!(
            closed_frames,
            Some((audio12.len() / crate::FRAME_SAMPLES) as u64),
            "session lost frames"
        );
        let s = coord.stats();
        let chunks: u64 = s.per_worker.iter().map(|w| w.stream_chunks).sum();
        assert_eq!(chunks, n_chunks);
        assert_eq!(s.chunk_latency.count(), n_chunks);
        assert!(s.activity.frames >= (audio12.len() / crate::FRAME_SAMPLES) as u64);
    }

    #[test]
    fn sessions_and_requests_share_the_pool() {
        let coord = Coordinator::new(rng_quant(9), ChipConfig::design_point(), 2, 8);
        let sess = coord.open_stream(0);
        for i in 0..4 {
            coord.submit(request(i, i)).unwrap();
        }
        sess.push_blocking(vec![0i64; 1280]).unwrap();
        let responses = coord.collect(4, Duration::from_secs(60));
        assert_eq!(responses.len(), 4);
        let events = sess.close();
        assert!(
            events.iter().any(|e| matches!(e, StreamEvent::Closed { .. })),
            "no Closed marker"
        );
    }

    #[test]
    fn open_stream_with_applies_custom_vad_config() {
        let coord = Coordinator::new(rng_quant(12), ChipConfig::design_point(), 2, 8);
        let sess = coord.open_stream_with(
            4,
            StreamConfig::for_chip(ChipConfig::design_point())
                .with_vad(crate::stream::vad::VadConfig::disabled()),
        );
        // pure silence: the default VAD would gate every frame, a disabled
        // one must clock the ΔRNN on all 10
        sess.push_blocking(vec![0i64; 1280]).unwrap();
        let events = sess.close();
        let closed = events.iter().find_map(|e| match e {
            StreamEvent::Closed { frames, gated_frames } => Some((*frames, *gated_frames)),
            _ => None,
        });
        assert_eq!(closed, Some((10, 0)), "disabled VAD must never gate");
    }

    #[test]
    fn duplicate_stream_ids_are_independent_sessions() {
        let coord = Coordinator::new(rng_quant(11), ChipConfig::design_point(), 2, 8);
        let a = coord.open_stream(5);
        let b = coord.open_stream(5);
        a.push_blocking(vec![0i64; 256]).unwrap();
        b.push_blocking(vec![0i64; 512]).unwrap();
        let ea = a.close();
        // closing `a` must not tear down `b`'s worker state
        b.push_blocking(vec![0i64; 256]).unwrap();
        let eb = b.close();
        let frames = |evs: &[StreamEvent]| {
            evs.iter().find_map(|e| match e {
                StreamEvent::Closed { frames, .. } => Some(*frames),
                _ => None,
            })
        };
        assert_eq!(frames(&ea), Some(2), "session a lost frames");
        assert_eq!(frames(&eb), Some(6), "session b died with a, or lost frames");
    }

    #[test]
    fn session_outlives_coordinator_safely() {
        let coord = Coordinator::new(rng_quant(10), ChipConfig::design_point(), 1, 4);
        let sess = coord.open_stream(1);
        sess.push_blocking(vec![0i64; 256]).unwrap();
        drop(coord);
        // pool gone: pushes fail cleanly and hand the chunk back
        let chunk = vec![1i64; 128];
        assert_eq!(sess.push(chunk.clone()), Err(chunk));
        // the worker flushed a Closed marker during shutdown
        let events: Vec<StreamEvent> = sess.events.try_iter().collect();
        assert!(events.iter().any(|e| matches!(e, StreamEvent::Closed { .. })));
    }

    #[test]
    fn client_submits_and_outlives_coordinator_safely() {
        let coord = Coordinator::new(rng_quant(6), ChipConfig::design_point(), 2, 8);
        let client = coord.client();
        client.submit(request(1, 1)).expect("client submit");
        let responses = coord.collect(1, Duration::from_secs(60));
        assert_eq!(responses.len(), 1);
        assert!(!client.is_closed());
        drop(coord);
        // the weak handle fails cleanly after the pool is gone, and the
        // closure is observable so retry loops can stop
        assert!(client.is_closed());
        assert!(client.submit(request(1, 2)).is_err());
    }
}

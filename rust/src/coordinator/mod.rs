//! Streaming serving coordinator: the "host side" of the system.
//!
//! The paper's chip sits behind an SPI link fed by a host (their MiniZed
//! FPGA). This module is that host, generalised into a small serving
//! runtime a deployment would actually use: audio streams are routed to a
//! pool of chip-twin workers over bounded queues (backpressure = the SPI
//! handshake), results and chip telemetry aggregate centrally, and the
//! router tolerates slow/stalled workers by spilling to the least-loaded
//! healthy queue.
//!
//! **Serving API v2** (see DESIGN.md §9): construction goes through the
//! validating [`Coordinator::builder`], submission returns a completion
//! [`Ticket`] delivered through the submitting client's own mailbox
//! (responses are routed by request id — two concurrent producers can
//! never steal each other's results), and every failure is a typed error
//! that still hands the payload back ([`crate::SubmitError`],
//! [`crate::StreamPushError`], [`crate::WaitError`]). The v1 global
//! response FIFO survives only as the deprecated
//! [`Coordinator::collect`] shim over the coordinator's default mailbox.
//!
//! Threading: std threads + mpsc (the vendored dependency set has no
//! tokio); one thread per worker, one router, callers submit through the
//! [`Coordinator`] directly or concurrently through cloneable [`Client`]
//! handles. Ordering within a stream is preserved by pinning each stream id
//! to a worker (consistent hashing), which also keeps the per-utterance
//! recurrent state meaningful; the spill path trades that ordering for
//! availability when the pinned queue is saturated.
//!
//! Three kinds of work share the worker lanes:
//!
//! * per-utterance [`Request`]s — stateless between requests, spillable;
//! * *fused* request groups ([`Client::submit_fused`]) — a whole batch of
//!   independent utterances routed to ONE worker as a single job, served
//!   through the batched-chip path
//!   ([`crate::accel::DeltaRnnAccel::step_frames_batched`]): every fired
//!   weight row is fetched once per frame for the whole group instead of
//!   once per request. Deliberately ignores stream pinning — co-locating
//!   the group is the point — and always runs the lean (untraced) path;
//! * long-lived [`StreamSession`]s — open a stream, push audio chunks of
//!   any size, receive [`StreamEvent`]s asynchronously. A session's
//!   [`crate::stream::StreamPipeline`] (chip + VAD + wakeword state
//!   machine) lives on the stream's *pinned* worker for its whole life:
//!   chunks never spill (the recurrent state is there), so a full pinned
//!   queue surfaces as backpressure to the producer instead.
//!
//! Telemetry is contention-free and bounded: the worker hot loop records
//! only into its own [`telemetry::WorkerShard`] (relaxed counters + a
//! fixed-size log-bucketed latency histogram — no locks, no allocation,
//! O(1) memory in the request count), [`Coordinator::stats`] folds the
//! shards on demand, and chip power/energy reports are published per
//! epoch / on [`Coordinator::reports`] pull, never per utterance. The
//! [`soak`] harness drives sustained mixed load against exactly these
//! guarantees.

pub mod builder;
pub mod soak;
pub mod telemetry;
pub mod ticket;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError, TrySendError};
use std::sync::{Arc, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::accel::batch::BatchSession;
use crate::accel::gru::QuantParams;
use crate::chip::{
    ChipConfig, ChipReport, DecisionAccum, FrameOut, KwsChip, SAFE_CHUNK_SAMPLES,
};
use crate::custom::{EnrollConfig, WeightRegistry, WeightVersion};
use crate::energy::ChipActivity;
use crate::error::{StreamPushError, SubmitError};
use crate::runtime::NativeBackend;
use crate::obs::metrics::{MetricsRegistry, MetricsSnapshot};
use crate::obs::recorder::{
    EventKind, FlightDump, FlightRecorder, RecorderConfig, RecorderProbe, RecorderStats,
};
use crate::obs::TraceId;
use crate::probe::DecisionTrace;
use crate::stream::detector::DetectionEvent;
use crate::stream::{StreamConfig, StreamPipeline};
use crate::util::hist::LogHistogram;
use telemetry::WorkerShard;
use ticket::Mailbox;

/// Bound on each stream session's event channel (detections + the final
/// `Closed` marker). A client that never drains its receiver sheds the
/// newest detections (counted in [`Stats::stream_events_dropped`]) instead
/// of growing worker-side memory without limit.
pub const STREAM_EVENT_CAP: usize = 256;

pub use builder::CoordinatorBuilder;
pub use ticket::{Batch, Ticket};

/// One inference request: a 1 s utterance on a logical stream.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// logical stream (microphone); pins the request to a worker
    pub stream: u64,
    pub audio12: Vec<i64>,
    /// optional ground truth for online accuracy accounting
    pub label: Option<usize>,
    /// opt this submission into the [`TraceProbe`](crate::probe::TraceProbe)
    /// instrumentation path: the worker reconstructs the full per-frame
    /// diagnostics (Fig. 11 cycle/fired/feature traces) and returns them
    /// in [`Response::trace`]. Default `false` — the worker runs the lean
    /// [`NoProbe`](crate::probe::NoProbe) hot path and the response stays
    /// fixed-size.
    pub trace: bool,
    /// serve this request with a specific registered
    /// [`WeightVersion`] (e.g. a per-user enrolled head from
    /// [`Coordinator::enroll`]). `None` = the pool's base weights. The
    /// version is resolved against the registry at submit time —
    /// an unknown or evicted version is rejected up front with
    /// [`SubmitError::UnknownWeights`], never half-served.
    pub weights: Option<WeightVersion>,
}

/// Inference result. Lean by default: summed logits, class, counted
/// frames and cycle totals — fixed-size, nothing per-frame. Per-frame
/// traces ride along in [`trace`](Self::trace) only when the request
/// opted in with [`Request::trace`].
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub stream: u64,
    pub class: usize,
    pub correct: Option<bool>,
    /// summed posterior logits over the counted frames (argmax = `class`)
    pub logits: [i64; crate::NUM_CLASSES],
    /// ungated post-warmup frames behind the posterior (0 = no evidence)
    pub counted_frames: u64,
    /// total ΔRNN cycles this utterance cost on the chip twin
    pub chip_cycles: u64,
    /// simulated chip computing latency for this utterance (ms)
    pub chip_latency_ms: f64,
    /// wall-clock service time (queue + simulation)
    pub service: Duration,
    pub worker: usize,
    /// per-worker completion sequence number: two responses from the
    /// same worker completed in `worker_seq` order (lets callers verify
    /// pinned-stream FIFO ordering without a global collection point)
    pub worker_seq: u64,
    /// per-frame diagnostics, present only for `Request { trace: true, … }`
    pub trace: Option<DecisionTrace>,
    /// request-scoped trace id minted at submit — matches the flight
    /// recorder's events for this utterance (see [`crate::obs`])
    pub trace_id: TraceId,
    /// the [`WeightVersion`] that actually served this request (the
    /// pool's base version unless the request asked for another)
    pub weights: WeightVersion,
}

/// Per-worker serving counters (the per-lane view of routing health:
/// a worker with high `pinned_full` is a stall hot-spot; high `spilled_in`
/// means it absorbs other lanes' overflow).
#[derive(Debug, Default, Clone, Copy)]
pub struct LaneStats {
    /// utterance requests this worker completed
    pub completed: u64,
    /// requests that arrived here by spilling off a full pinned lane
    pub spilled_in: u64,
    /// submissions that found this worker's queue full while it was the
    /// pinned target (each one either spilled elsewhere or was rejected)
    pub pinned_full: u64,
    /// streaming audio chunks processed by this worker's sessions
    pub stream_chunks: u64,
}

/// Aggregate serving statistics: a point-in-time fold of the per-worker
/// telemetry shards and the lock-free routing counters. Every field is
/// fixed-size — the snapshot's memory footprint is independent of how many
/// requests the pool has served (see [`Stats::telemetry_bytes`]).
#[derive(Debug, Clone, Default)]
pub struct Stats {
    pub completed: u64,
    pub correct: u64,
    pub labelled: u64,
    /// submissions rejected with every queue saturated (transient
    /// backpressure — the producer saw [`SubmitError::QueueFull`] and
    /// can retry)
    pub rejected_full: u64,
    /// submissions rejected with every reachable lane disconnected
    /// (shutdown race — the producer saw [`SubmitError::Closed`]).
    /// Post-shutdown rejections from [`Client`] handles outliving the
    /// pool are only observable by the caller: there is no router left
    /// to count them.
    pub rejected_closed: u64,
    /// requests accepted by a non-pinned worker (pinned queue was full);
    /// folded from per-lane atomics by [`Coordinator::stats`]
    pub spilled: u64,
    /// wall-clock utterance service-time distribution (µs), log-bucketed
    pub latency: LogHistogram,
    /// wall-clock stream-chunk service-time distribution (µs)
    pub chunk_latency: LogHistogram,
    /// merged chip activity across workers
    pub activity: ChipActivity,
    /// fused request groups served through the batched-chip path
    /// (their member requests are counted individually in `completed`)
    pub fused_batches: u64,
    /// stream events shed on full session event channels (clients that
    /// never drain their receivers; see [`STREAM_EVENT_CAP`])
    pub stream_events_dropped: u64,
    /// gauge: live per-session pipeline state across all workers, bytes
    /// (bounded by construction — frame staging buffer + detector window
    /// per session; 0 once every session is closed)
    pub session_bytes: u64,
    /// epoch-fenced weight hot-swaps applied to live streaming sessions
    /// ([`Coordinator::swap_weights`]), folded from the worker shards
    pub weight_swaps: u64,
    /// gauge: weight versions currently resident in the registry
    /// (bounded by the registry's LRU capacity)
    pub resident_versions: u64,
    /// enrollment wall-clock latency distribution (µs), recorded once per
    /// [`Coordinator::enroll`] call — control path, never per frame
    pub enroll_latency: LogHistogram,
    /// per-worker routing/serving counters (indexed by worker; folded
    /// from lane atomics + telemetry shards by [`Coordinator::stats`])
    pub per_worker: Vec<LaneStats>,
    /// monotonic capture timestamp ([`crate::obs::monotonic_us`]), stamped
    /// by [`Coordinator::stats`]; what makes two snapshots comparable via
    /// [`Stats::delta_since`]
    pub captured_us: u64,
}

impl Stats {
    pub fn accuracy(&self) -> f64 {
        if self.labelled == 0 {
            0.0
        } else {
            self.correct as f64 / self.labelled as f64
        }
    }

    /// All rejections regardless of cause (backpressure + shutdown).
    pub fn rejected_total(&self) -> u64 {
        self.rejected_full + self.rejected_closed
    }

    pub fn p50_us(&self) -> u64 {
        self.latency.percentile(0.50)
    }

    pub fn p99_us(&self) -> u64 {
        self.latency.percentile(0.99)
    }

    /// Heap footprint of this telemetry snapshot — constant in the request
    /// count by construction (histogram bucket arrays + per-worker lane
    /// table). The soak harness asserts it stays flat under load.
    pub fn telemetry_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.latency.heap_bytes()
            + self.chunk_latency.heap_bytes()
            + self.enroll_latency.heap_bytes()
            + self.per_worker.len() * std::mem::size_of::<LaneStats>()
    }

    /// Streaming audio chunks processed pool-wide (folded from the
    /// per-worker lanes).
    pub fn stream_chunks(&self) -> u64 {
        self.per_worker.iter().map(|w| w.stream_chunks).sum()
    }

    /// Counter movement between an earlier snapshot (`prev`) and this one,
    /// for rate computation — decisions/sec, drops/sec — without
    /// re-deriving rates by hand from wall clocks. Counters use saturating
    /// subtraction, so comparing snapshots from different pools degrades
    /// to zeros instead of underflowing.
    pub fn delta_since(&self, prev: &Stats) -> StatsDelta {
        StatsDelta {
            elapsed_us: self.captured_us.saturating_sub(prev.captured_us),
            completed: self.completed.saturating_sub(prev.completed),
            rejected_full: self.rejected_full.saturating_sub(prev.rejected_full),
            rejected_closed: self.rejected_closed.saturating_sub(prev.rejected_closed),
            spilled: self.spilled.saturating_sub(prev.spilled),
            fused_batches: self.fused_batches.saturating_sub(prev.fused_batches),
            stream_events_dropped: self
                .stream_events_dropped
                .saturating_sub(prev.stream_events_dropped),
            stream_chunks: self.stream_chunks().saturating_sub(prev.stream_chunks()),
            frames: self.activity.frames.saturating_sub(prev.activity.frames),
        }
    }
}

/// Counter movement between two [`Stats`] snapshots
/// ([`Stats::delta_since`]): the rates window the metrics exposition
/// reports, and what the soak harness uses for its steady-state
/// decisions/sec figure.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatsDelta {
    /// wall-clock span between the two captures, µs (0 ⇒ every rate is 0)
    pub elapsed_us: u64,
    /// utterance decisions completed in the window
    pub completed: u64,
    /// backpressure rejections in the window
    pub rejected_full: u64,
    /// closed-pool rejections in the window
    pub rejected_closed: u64,
    /// spilled submissions in the window
    pub spilled: u64,
    /// fused batches served in the window
    pub fused_batches: u64,
    /// stream events shed in the window
    pub stream_events_dropped: u64,
    /// stream chunks processed in the window
    pub stream_chunks: u64,
    /// chip frames consumed in the window
    pub frames: u64,
}

impl StatsDelta {
    fn per_sec(count: u64, elapsed_us: u64) -> f64 {
        if elapsed_us == 0 {
            0.0
        } else {
            count as f64 * 1e6 / elapsed_us as f64
        }
    }

    /// Utterance decisions per second over the window.
    pub fn decisions_per_sec(&self) -> f64 {
        Self::per_sec(self.completed, self.elapsed_us)
    }

    /// Losses per second: rejections (both causes) + shed stream events.
    pub fn drops_per_sec(&self) -> f64 {
        Self::per_sec(
            self.rejected_full + self.rejected_closed + self.stream_events_dropped,
            self.elapsed_us,
        )
    }

    /// Stream chunks per second over the window.
    pub fn chunks_per_sec(&self) -> f64 {
        Self::per_sec(self.stream_chunks, self.elapsed_us)
    }

    /// Chip frames per second over the window.
    pub fn frames_per_sec(&self) -> f64 {
        Self::per_sec(self.frames, self.elapsed_us)
    }
}

/// Exact percentile of a sample by the exclusive nearest-rank rule with a
/// round-half-up rank: `rank = ⌊p·(n+1) + ½⌋` clamped to `[1, n]`, 1-based
/// into the sorted data. p99 of 100 samples is the 100th order statistic —
/// the previous truncating index `⌊(n-1)·p⌋` returned the 99th, i.e. the
/// p98 sample. [`LogHistogram::percentile`] uses the same rank rule, so
/// the two agree to within one bucket's representative-value rounding.
pub fn percentile(xs: &[u64], p: f64) -> u64 {
    if xs.is_empty() {
        return 0;
    }
    let mut v = xs.to_vec();
    v.sort_unstable();
    let n = v.len();
    let rank = ((p * (n as f64 + 1.0)) + 0.5).floor() as usize;
    v[rank.clamp(1, n) - 1]
}

/// One unit of work on a worker lane. Stream jobs are keyed by a unique
/// *session* id (the stream id only picks the pinned lane), so two
/// sessions opened on the same stream id coexist instead of clobbering
/// each other's worker state.
enum Job {
    /// a per-utterance inference request (spillable); `reply` is the
    /// submitting client's mailbox — the completion path delivers there,
    /// routed by request id, never to a global queue
    Utterance {
        req: Request,
        trace: TraceId,
        enqueued: Instant,
        reply: Weak<Mailbox>,
        /// weights resolved (and touched) at submit — the Arc keeps the
        /// table alive on this job even if the registry evicts it mid-queue
        weights: (WeightVersion, Arc<QuantParams>),
    },
    /// a fused group of independent utterances served in lockstep through
    /// the batched-chip path (one weight-row fetch per fired lane per
    /// frame for the whole group); routed as one unit to one worker,
    /// lean-only (`Request::trace` is ignored); `traces` parallels `reqs`
    UtteranceBatch {
        reqs: Vec<Request>,
        traces: Vec<TraceId>,
        enqueued: Instant,
        reply: Weak<Mailbox>,
        /// per-member resolved weights, parallel to `reqs`: the worker
        /// regroups the batch by version so each fused sub-group steps
        /// against one coherent weight table (never a mixed fetch)
        weights: Vec<(WeightVersion, Arc<QuantParams>)>,
    },
    /// open a streaming session pinned to this worker (`config`: per-
    /// session VAD/detector tuning, `None` = pool default; `alive` is
    /// cleared by the client handle so the worker can GC sessions whose
    /// Close was never deliverable)
    StreamOpen {
        session: u64,
        trace: TraceId,
        config: Option<StreamConfig>,
        events: SyncSender<StreamEvent>,
        alive: Arc<AtomicBool>,
        /// the session's weight version, resolved and *pinned* at open
        /// (the worker unpins it when the session finishes)
        weights: (WeightVersion, Arc<QuantParams>),
    },
    /// an audio chunk for an open session
    StreamData { session: u64, chunk: Vec<i64>, enqueued: Instant },
    /// install `version` on an open session at the next frame boundary
    /// (the epoch fence — see DESIGN.md §14). The new version was pinned
    /// at submit; the worker unpins the outgoing one after the swap and
    /// acknowledges with [`StreamEvent::WeightsSwapped`].
    SwapWeights { session: u64, version: WeightVersion, params: Arc<QuantParams> },
    /// close a session (flushes telemetry, emits [`StreamEvent::Closed`])
    StreamClose { session: u64 },
    /// publish a fresh chip-report snapshot into the telemetry shard and
    /// acknowledge (the pull half of [`Coordinator::reports`]; the ack
    /// channel is bounded — capacity = lane count — and the worker side
    /// uses `try_send`, so a slow or dead requester can never block a lane)
    PublishReport { ack: SyncSender<()> },
}

/// Asynchronous output of a [`StreamSession`]. Every event carries the
/// session's [`TraceId`] (minted at open), correlating it with the flight
/// recorder's timeline for that session.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// the wakeword state machine confirmed a detection
    Detection {
        /// the session's trace id
        trace: TraceId,
        /// the detection itself
        event: DetectionEvent,
        /// the weight version active when the detection fired — after a
        /// mid-stream [`Coordinator::swap_weights`] this flips to the new
        /// version from the first post-fence frame onwards
        weights: WeightVersion,
    },
    /// acknowledgement that [`Coordinator::swap_weights`] installed a new
    /// weight version on this session at a frame boundary (the epoch
    /// fence): every frame up to `frame` was decided by the old weights,
    /// every later frame by `version`, none dropped or duplicated
    WeightsSwapped {
        /// the session's trace id
        trace: TraceId,
        /// the newly installed version
        version: WeightVersion,
        /// frames the session's chip had consumed when the fence closed
        frame: u64,
    },
    /// final telemetry, emitted exactly once when the session closes
    Closed {
        /// the session's trace id
        trace: TraceId,
        /// total frames the session's chip consumed
        frames: u64,
        /// frames consumed with the ΔRNN clock-gated
        gated_frames: u64,
    },
}

/// What [`Coordinator::enroll`] produced: the newly registered version,
/// its lineage, and the training telemetry that also lands in
/// [`Stats::enroll_latency`].
#[derive(Debug, Clone, Copy)]
pub struct EnrollOutcome {
    /// the newly registered (content-hashed) weight version
    pub version: WeightVersion,
    /// the version enrollment started from (the new version's parent)
    pub parent: WeightVersion,
    /// fine-tuning steps taken
    pub steps: usize,
    /// cross-entropy loss after the last step
    pub final_loss: f32,
    /// wall-clock enrollment latency, µs
    pub latency_us: u64,
}

/// Why one lane refused an utterance job (the request rides back).
enum LaneError {
    /// lane queue full — another lane (or a later retry) may accept
    Full(Request),
    /// lane disconnected — its worker is gone for good
    Disconnected(Request),
}

/// Why the pinned lane refused a stream job (the job rides back).
enum StreamLaneError {
    Full(Job),
    Disconnected(Job),
}

/// Why every lane refused a fused request group (the group rides back
/// intact so [`Client::submit_fused`] can retry it whole).
enum FusedLaneError {
    Full(Vec<Request>),
    Disconnected(Vec<Request>),
    /// a member named an unknown/evicted weight version: not retryable,
    /// the whole group is handed back with the failed lookup
    Weights(Vec<Request>, crate::custom::RegistryError),
}

/// One worker's request lane (the submit-side view).
struct Lane {
    tx: SyncSender<Job>,
    depth: Arc<AtomicU64>,
    /// failure-injection: worker refuses work while true (tests)
    stalled: Arc<AtomicBool>,
    /// lock-free routing counters, folded into [`Stats::per_worker`] at
    /// read time — the submit hot path must not take any lock
    pinned_full: AtomicU64,
    spilled_in: AtomicU64,
}

/// Shared routing state: what [`Coordinator::submit`] and every [`Client`]
/// operate on. Dropping the coordinator drops the lanes' senders, which is
/// what tells workers to drain and exit.
struct Router {
    lanes: Vec<Lane>,
    /// per-worker telemetry shards (worker w writes shards[w] only)
    shards: Vec<Arc<WorkerShard>>,
    /// submissions rejected with every queue saturated (lock-free)
    rejected_full: AtomicU64,
    /// submissions rejected with every reachable lane disconnected
    rejected_closed: AtomicU64,
    next_id: AtomicU64,
    /// unique ids for [`StreamSession`]s (stream ids may repeat)
    next_session: AtomicU64,
    /// request-scoped trace ids (starts at 1; 0 is [`TraceId::NONE`])
    next_trace: AtomicU64,
    /// per-worker flight recorders (disabled singletons unless the pool
    /// was built with [`CoordinatorBuilder::recorder`]). Submit-side
    /// events land on the *pinned* lane's ring; worker-side events on the
    /// executing lane's.
    recorders: Vec<Arc<FlightRecorder>>,
    /// every mailbox handed out (default + per client), closed at pool
    /// shutdown so blocked ticket waits resolve to `Closed`. Locked only
    /// on client creation and shutdown — never on the submit path.
    mailboxes: Mutex<Vec<Weak<Mailbox>>>,
    /// the versioned weight registry (enrolled heads + the base weights);
    /// shared with the workers, which pin/unpin per live session
    registry: Arc<WeightRegistry>,
    /// the pool's base weights: inserted and permanently pinned at spawn,
    /// so resolving `weights: None` can never fail
    base: (WeightVersion, Arc<QuantParams>),
}

impl Router {
    fn pinned_lane(&self, stream: u64) -> usize {
        (stream as usize) % self.lanes.len()
    }

    fn mint_trace(&self) -> TraceId {
        TraceId(self.next_trace.fetch_add(1, Ordering::Relaxed))
    }

    /// Resolve a request's optional weight version against the registry
    /// (touching its LRU slot). `None` is the pool base, which is
    /// permanently pinned and therefore always resolvable.
    fn resolve_weights(
        &self,
        version: Option<WeightVersion>,
    ) -> Result<(WeightVersion, Arc<QuantParams>), crate::custom::RegistryError> {
        match version {
            Some(v) => Ok((v, self.registry.get(v)?)),
            None => Ok((self.base.0, Arc::clone(&self.base.1))),
        }
    }

    /// Routing: the stream's pinned worker unless its queue is full, then
    /// least-loaded spill. The request id is registered with `mailbox`
    /// *before* enqueueing (a fast worker must find the id expected), and
    /// withdrawn again on rejection. `Err` distinguishes global
    /// backpressure (`QueueFull`, retryable) from a dead pool (`Closed`).
    fn submit(&self, mut req: Request, mailbox: &Arc<Mailbox>) -> Result<Ticket, SubmitError> {
        // resolve the weight version first: an unknown/evicted version is
        // a submit-time rejection, not a worker-side surprise
        let weights = match self.resolve_weights(req.weights) {
            Ok(w) => w,
            Err(e) => return Err(SubmitError::UnknownWeights(req, e)),
        };
        req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let id = req.id;
        let stream = req.stream;
        mailbox.register(id);
        let reply = Arc::downgrade(mailbox);
        // lint:allow(no-wallclock): queue-latency telemetry stamp, taken once per submit on the serving control path (not the frame path)
        let now = Instant::now();
        let pinned = self.pinned_lane(stream);
        let trace = self.mint_trace();
        self.recorders[pinned].record(pinned as u32, trace, EventKind::Submit);
        let mut any_full = false;
        let mut req = match self.try_lane(pinned, req, trace, now, &reply, &weights) {
            Ok(()) => return Ok(Ticket::new(id, stream, Arc::clone(mailbox))),
            Err(LaneError::Full(r)) => {
                self.lanes[pinned].pinned_full.fetch_add(1, Ordering::Relaxed);
                any_full = true;
                r
            }
            Err(LaneError::Disconnected(r)) => r,
        };
        // spill: least-loaded first
        let mut order: Vec<usize> = (0..self.lanes.len()).filter(|&w| w != pinned).collect();
        order.sort_by_key(|&w| self.lanes[w].depth.load(Ordering::Relaxed));
        for w in order {
            req = match self.try_lane(w, req, trace, now, &reply, &weights) {
                Ok(()) => {
                    self.lanes[w].spilled_in.fetch_add(1, Ordering::Relaxed);
                    return Ok(Ticket::new(id, stream, Arc::clone(mailbox)));
                }
                Err(LaneError::Full(r)) => {
                    any_full = true;
                    r
                }
                Err(LaneError::Disconnected(r)) => r,
            };
        }
        mailbox.unregister(id);
        if any_full {
            self.rejected_full.fetch_add(1, Ordering::Relaxed);
            self.recorders[pinned].record(pinned as u32, trace, EventKind::Backpressure);
            Err(SubmitError::QueueFull(req))
        } else {
            self.rejected_closed.fetch_add(1, Ordering::Relaxed);
            Err(SubmitError::Closed(req))
        }
    }

    fn try_lane(
        &self,
        w: usize,
        req: Request,
        trace: TraceId,
        t: Instant,
        reply: &Weak<Mailbox>,
        weights: &(WeightVersion, Arc<QuantParams>),
    ) -> Result<(), LaneError> {
        let job = Job::Utterance {
            req,
            trace,
            enqueued: t,
            reply: reply.clone(),
            weights: (weights.0, Arc::clone(&weights.1)),
        };
        match self.lanes[w].tx.try_send(job) {
            Ok(()) => {
                self.lanes[w].depth.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(TrySendError::Full(Job::Utterance { req, .. })) => Err(LaneError::Full(req)),
            Err(TrySendError::Disconnected(Job::Utterance { req, .. })) => {
                Err(LaneError::Disconnected(req))
            }
            Err(_) => unreachable!("utterance job came back as a different variant"),
        }
    }

    /// Route a whole request group to ONE lane as a single fused job.
    /// Ids are assigned and registered with `mailbox` before enqueueing
    /// (same invariant as [`submit`](Self::submit)); rejection withdraws
    /// every id and hands the group back intact. Lane choice is
    /// least-loaded first: a fused group deliberately ignores per-stream
    /// pinning, since amortizing the weight fetch requires co-locating
    /// the whole group on one worker.
    fn submit_fused(
        &self,
        mut reqs: Vec<Request>,
        mailbox: &Arc<Mailbox>,
    ) -> Result<Batch, FusedLaneError> {
        // resolve every member's weights before minting any id: one bad
        // version rejects the group whole, with nothing registered
        let mut weights = Vec::with_capacity(reqs.len());
        for req in reqs.iter() {
            match self.resolve_weights(req.weights) {
                Ok(w) => weights.push(w),
                Err(e) => return Err(FusedLaneError::Weights(reqs, e)),
            }
        }
        let mut traces = Vec::with_capacity(reqs.len());
        for req in reqs.iter_mut() {
            req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
            mailbox.register(req.id);
            traces.push(self.mint_trace());
        }
        let meta: Vec<(u64, u64)> = reqs.iter().map(|r| (r.id, r.stream)).collect();
        let reply = Arc::downgrade(mailbox);
        // lint:allow(no-wallclock): queue-latency telemetry stamp, taken once per batch submit on the serving control path
        let now = Instant::now();
        let mut order: Vec<usize> = (0..self.lanes.len()).collect();
        order.sort_by_key(|&w| self.lanes[w].depth.load(Ordering::Relaxed));
        let mut any_full = false;
        for w in order {
            let job = Job::UtteranceBatch {
                reqs,
                traces: traces.clone(),
                enqueued: now,
                reply: reply.clone(),
                weights: weights.clone(),
            };
            reqs = match self.lanes[w].tx.try_send(job) {
                Ok(()) => {
                    self.lanes[w].depth.fetch_add(1, Ordering::Relaxed);
                    let tickets = meta
                        .iter()
                        .map(|&(id, stream)| Ticket::new(id, stream, Arc::clone(mailbox)))
                        .collect();
                    return Ok(Batch::new(tickets));
                }
                Err(TrySendError::Full(Job::UtteranceBatch { reqs, .. })) => {
                    any_full = true;
                    reqs
                }
                Err(TrySendError::Disconnected(Job::UtteranceBatch { reqs, .. })) => reqs,
                Err(_) => unreachable!("fused job came back as a different variant"),
            };
        }
        for &(id, _) in &meta {
            mailbox.unregister(id);
        }
        if any_full {
            self.rejected_full.fetch_add(1, Ordering::Relaxed);
            Err(FusedLaneError::Full(reqs))
        } else {
            self.rejected_closed.fetch_add(1, Ordering::Relaxed);
            Err(FusedLaneError::Disconnected(reqs))
        }
    }

    /// Non-blocking stream-job delivery to the stream's pinned lane (no
    /// spill: the session state lives there). `Err` hands the job back
    /// with the cause.
    fn try_stream_job(&self, stream: u64, job: Job) -> Result<(), StreamLaneError> {
        let lane = self.pinned_lane(stream);
        match self.lanes[lane].tx.try_send(job) {
            Ok(()) => {
                self.lanes[lane].depth.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(TrySendError::Full(j)) => Err(StreamLaneError::Full(j)),
            Err(TrySendError::Disconnected(j)) => Err(StreamLaneError::Disconnected(j)),
        }
    }

    /// Blocking stream-job delivery (control messages: open/close). `Err`
    /// only when the worker pool is gone.
    fn send_stream_job(&self, stream: u64, job: Job) -> Result<(), Job> {
        let lane = self.pinned_lane(stream);
        match self.lanes[lane].tx.send(job) {
            Ok(()) => {
                self.lanes[lane].depth.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(e) => Err(e.0),
        }
    }
}

/// Cloneable, thread-safe submission handle with its own completion
/// mailbox: responses to requests submitted through this handle (or its
/// clones, which share the mailbox) are delivered here only, claimed via
/// the returned [`Ticket`]s. Holds only a weak reference to the router:
/// once the owning [`Coordinator`] is dropped, submissions fail cleanly
/// with [`SubmitError::Closed`] instead of keeping dead workers alive.
#[derive(Clone)]
pub struct Client {
    router: Weak<Router>,
    mailbox: Arc<Mailbox>,
}

impl Client {
    /// Submit a request (same routing/backpressure contract as
    /// [`Coordinator::submit`]). `Ok` returns the completion [`Ticket`];
    /// `Err` hands the request back and names the cause —
    /// [`SubmitError::QueueFull`] is transient backpressure (retry),
    /// [`SubmitError::Closed`] is permanent (stop).
    pub fn submit(&self, req: Request) -> Result<Ticket, SubmitError> {
        match self.router.upgrade() {
            Some(router) => router.submit(req, &self.mailbox),
            None => Err(SubmitError::Closed(req)),
        }
    }

    /// Submit a whole workload, blocking through transient backpressure
    /// (bounded-backoff retry on [`SubmitError::QueueFull`]) — the
    /// utterance-benchmark path. Returns the [`Batch`] of tickets in
    /// submission order, or [`SubmitError::Closed`] with the first
    /// undeliverable request once the pool is gone (any tickets already
    /// obtained are dropped; their responses resolve into the void).
    pub fn submit_batch<I>(&self, reqs: I) -> Result<Batch, SubmitError>
    where
        I: IntoIterator<Item = Request>,
    {
        let mut tickets = Vec::new();
        for mut req in reqs {
            loop {
                match self.submit(req) {
                    Ok(t) => {
                        tickets.push(t);
                        break;
                    }
                    Err(SubmitError::QueueFull(r)) => {
                        req = r;
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    // Closed and UnknownWeights are both permanent
                    Err(e) => return Err(e),
                }
            }
        }
        Ok(Batch::new(tickets))
    }

    /// Submit a whole request group as ONE fused job: a single worker
    /// steps every utterance in lockstep through the batched-chip path
    /// ([`crate::accel::DeltaRnnAccel::step_frames_batched`]), fetching
    /// each fired weight row once per frame for the whole group. Each
    /// request still gets its own [`Response`] (bit-identical decision to
    /// a solo submit), claimed through the returned [`Batch`] of tickets
    /// in submission order.
    ///
    /// Contract differences from [`submit_batch`](Self::submit_batch):
    /// the group ignores per-stream worker pinning (co-location is the
    /// point) and always runs lean — [`Request::trace`] is ignored and
    /// [`Response::trace`] is `None`. Blocks through transient
    /// backpressure (the whole group retries as a unit); on a dead pool
    /// returns [`SubmitError::Closed`] with the first request.
    pub fn submit_fused(&self, mut reqs: Vec<Request>) -> Result<Batch, SubmitError> {
        if reqs.is_empty() {
            return Ok(Batch::new(Vec::new()));
        }
        loop {
            let Some(router) = self.router.upgrade() else {
                return Err(SubmitError::Closed(reqs.remove(0)));
            };
            reqs = match router.submit_fused(reqs, &self.mailbox) {
                Ok(batch) => return Ok(batch),
                Err(FusedLaneError::Full(r)) => r,
                Err(FusedLaneError::Disconnected(mut r)) => {
                    return Err(SubmitError::Closed(r.remove(0)));
                }
                Err(FusedLaneError::Weights(mut r, e)) => {
                    return Err(SubmitError::UnknownWeights(r.remove(0), e));
                }
            };
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    /// True once the owning [`Coordinator`] has been dropped: every further
    /// submit will fail with [`SubmitError::Closed`], so a retrying
    /// producer should stop.
    pub fn is_closed(&self) -> bool {
        self.router.strong_count() == 0
    }
}

/// A long-lived streaming session: the client half of one always-on
/// detection pipeline living on the stream's pinned worker.
///
/// Push 12-bit audio chunks of any size with [`push`](Self::push)
/// (non-blocking, backpressured) or [`push_blocking`](Self::push_blocking);
/// detections arrive asynchronously on [`events`](Self::events). Dropping
/// the session (or calling [`close`](Self::close)) tears down the worker
/// state and flushes its chip telemetry into the pool [`Stats`].
pub struct StreamSession {
    stream: u64,
    /// unique id keying the worker-side state (stream ids may repeat)
    session: u64,
    /// trace id minted at open; stamped on every event this session emits
    trace: TraceId,
    router: Weak<Router>,
    /// asynchronous session output ([`StreamEvent`])
    pub events: Receiver<StreamEvent>,
    closed: bool,
    /// cleared on close/drop; the worker GCs sessions with a dead flag
    alive: Arc<AtomicBool>,
}

impl StreamSession {
    pub fn stream_id(&self) -> u64 {
        self.stream
    }

    /// The session's [`TraceId`] (minted at open): matches the `trace`
    /// field on every [`StreamEvent`] it emits and on the flight
    /// recorder's events for this session.
    pub fn trace_id(&self) -> TraceId {
        self.trace
    }

    /// Submit an audio chunk (non-blocking). `Err` hands the chunk back:
    /// [`StreamPushError::Backpressure`] when the pinned worker's queue
    /// is full (pace the producer and retry),
    /// [`StreamPushError::Closed`] when the pool is gone.
    pub fn push(&self, audio12: Vec<i64>) -> Result<(), StreamPushError> {
        let Some(router) = self.router.upgrade() else {
            return Err(StreamPushError::Closed(audio12));
        };
        router
            .try_stream_job(
                self.stream,
                Job::StreamData {
                    session: self.session,
                    chunk: audio12,
                    // lint:allow(no-wallclock): chunk enqueue stamp for stream-latency telemetry, taken on the caller's thread before the lane hop
                    enqueued: Instant::now(),
                },
            )
            .map_err(|e| match e {
                StreamLaneError::Full(Job::StreamData { chunk, .. }) => {
                    let lane = router.pinned_lane(self.stream);
                    router.recorders[lane].record(
                        lane as u32,
                        self.trace,
                        EventKind::Backpressure,
                    );
                    StreamPushError::Backpressure(chunk)
                }
                StreamLaneError::Disconnected(Job::StreamData { chunk, .. }) => {
                    StreamPushError::Closed(chunk)
                }
                _ => unreachable!("data job came back as a different variant"),
            })
    }

    /// Submit an audio chunk, blocking while the pinned queue is full.
    /// `Err` is always [`StreamPushError::Closed`] (the pool is gone).
    pub fn push_blocking(&self, audio12: Vec<i64>) -> Result<(), StreamPushError> {
        let Some(router) = self.router.upgrade() else {
            return Err(StreamPushError::Closed(audio12));
        };
        router
            .send_stream_job(
                self.stream,
                Job::StreamData {
                    session: self.session,
                    chunk: audio12,
                    // lint:allow(no-wallclock): chunk enqueue stamp for stream-latency telemetry, taken on the caller's thread before the lane hop
                    enqueued: Instant::now(),
                },
            )
            .map_err(|j| match j {
                Job::StreamData { chunk, .. } => StreamPushError::Closed(chunk),
                _ => unreachable!("data job came back as a different variant"),
            })
    }

    /// Collect whatever events have arrived so far (non-blocking).
    pub fn try_events(&self) -> Vec<StreamEvent> {
        self.events.try_iter().collect()
    }

    /// Close the session and collect every remaining event, including the
    /// final [`StreamEvent::Closed`] telemetry marker. Waits (bounded) for
    /// the worker to acknowledge; use `drop` for a fire-and-forget close.
    pub fn close(mut self) -> Vec<StreamEvent> {
        self.send_close(true);
        let mut out = Vec::new();
        while let Ok(ev) = self.events.recv_timeout(Duration::from_secs(60)) {
            let done = matches!(ev, StreamEvent::Closed { .. });
            out.push(ev);
            if done {
                break;
            }
        }
        out
    }

    /// `blocking` = wait for lane space (explicit [`close`](Self::close));
    /// the Drop path must never hang, so it retries briefly and then gives
    /// up — the worker GCs the session when it notices the event channel
    /// is disconnected (or at pool shutdown).
    fn send_close(&mut self, blocking: bool) {
        if self.closed {
            return;
        }
        self.closed = true;
        // even if the Close below cannot be delivered, the cleared flag
        // lets the worker GC the session on a later job
        self.alive.store(false, Ordering::Relaxed);
        let Some(router) = self.router.upgrade() else {
            return;
        };
        let mut job = Job::StreamClose { session: self.session };
        if blocking {
            let _ = router.send_stream_job(self.stream, job);
            return;
        }
        for _ in 0..20 {
            job = match router.try_stream_job(self.stream, job) {
                Ok(()) => return,
                // the pinned worker is gone: nothing left to close
                Err(StreamLaneError::Disconnected(_)) => return,
                Err(StreamLaneError::Full(j)) => j,
            };
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

impl Drop for StreamSession {
    fn drop(&mut self) {
        // non-blocking: a wedged lane must not hang a destructor; an
        // undelivered Close is flushed by the worker's shutdown drain
        self.send_close(false);
    }
}

/// The coordinator: worker pool + router state + telemetry shards.
///
/// Construct with [`Coordinator::builder`]; submit through
/// [`submit`](Self::submit) / [`submit_batch`](Self::submit_batch) (which
/// use an internal default [`Client`]) or through per-producer
/// [`client`](Self::client) handles, and claim responses via the returned
/// [`Ticket`]s.
pub struct Coordinator {
    /// `Some` until drop; taken first so lane senders close before joining
    router: Option<Arc<Router>>,
    handles: Vec<JoinHandle<()>>,
    /// backs [`Coordinator::submit`] and the deprecated
    /// [`Coordinator::collect`] shim (its mailbox retains unclaimed
    /// responses, which is what `collect` drains)
    default_client: Client,
    /// metrics-snapshot folder (sequence + previous snapshot for rates);
    /// locked only inside [`Coordinator::metrics`], never on a hot path
    registry: Mutex<MetricsRegistry>,
}

impl Coordinator {
    /// Start configuring a serving pool over trained weights and a chip
    /// configuration. See [`CoordinatorBuilder`] for the knobs and their
    /// validation; `build()` spawns the workers.
    pub fn builder(params: QuantParams, config: ChipConfig) -> CoordinatorBuilder {
        CoordinatorBuilder::new(params, config)
    }

    /// Spawn `n_workers` chip twins, each with its own weight copy
    /// (validated entry point: [`CoordinatorBuilder::build`]).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn spawn(
        params: QuantParams,
        config: ChipConfig,
        n_workers: usize,
        queue_depth: usize,
        default_stream: StreamConfig,
        report_epoch: u64,
        recorder: Option<RecorderConfig>,
        registry_capacity: usize,
    ) -> Self {
        // the base weights become registry version zero-generation: they
        // are pinned once here and never unpinned, so `weights: None`
        // submissions can always resolve
        let registry = Arc::new(WeightRegistry::new(registry_capacity));
        let base_version = registry.insert(params.clone(), None);
        let base_params =
            registry.pin(base_version).expect("base version resident at spawn");
        let base = (base_version, base_params);
        let mut lanes = Vec::with_capacity(n_workers);
        let mut shards = Vec::with_capacity(n_workers);
        let mut handles = Vec::with_capacity(n_workers);
        let mut recorders = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (tx, rx) = sync_channel::<Job>(queue_depth);
            let stalled = Arc::new(AtomicBool::new(false));
            let depth = Arc::new(AtomicU64::new(0));
            let shard = Arc::new(WorkerShard::default());
            let rec = Arc::new(match &recorder {
                Some(cfg) => FlightRecorder::new(cfg.clone()),
                None => FlightRecorder::disabled(),
            });
            let handle = {
                let base = (base.0, Arc::clone(&base.1));
                let config = config.clone();
                let default_stream = default_stream.clone();
                let stalled = Arc::clone(&stalled);
                let depth = Arc::clone(&depth);
                let shard = Arc::clone(&shard);
                let rec = Arc::clone(&rec);
                let registry = Arc::clone(&registry);
                std::thread::Builder::new()
                    .name(format!("chip-worker-{w}"))
                    .spawn(move || {
                        worker_loop(
                            w,
                            base,
                            config,
                            default_stream,
                            report_epoch,
                            rx,
                            shard,
                            stalled,
                            depth,
                            rec,
                            registry,
                        )
                    })
                    .expect("spawn worker")
            };
            lanes.push(Lane {
                tx,
                depth,
                stalled,
                pinned_full: AtomicU64::new(0),
                spilled_in: AtomicU64::new(0),
            });
            shards.push(shard);
            handles.push(handle);
            recorders.push(rec);
        }
        let router = Arc::new(Router {
            lanes,
            shards,
            rejected_full: AtomicU64::new(0),
            rejected_closed: AtomicU64::new(0),
            next_id: AtomicU64::new(0),
            next_session: AtomicU64::new(0),
            next_trace: AtomicU64::new(1),
            recorders,
            mailboxes: Mutex::new(Vec::new()),
            registry,
            base,
        });
        // the default mailbox retains unclaimed responses: that is the
        // queue the deprecated collect() shim drains
        let default_mailbox = Mailbox::new(true);
        router.mailboxes.lock().unwrap().push(Arc::downgrade(&default_mailbox));
        let default_client =
            Client { router: Arc::downgrade(&router), mailbox: default_mailbox };
        Self {
            router: Some(router),
            handles,
            default_client,
            registry: Mutex::new(MetricsRegistry::new()),
        }
    }

    fn router(&self) -> &Router {
        self.router.as_ref().expect("router alive until drop")
    }

    /// Submit a request through the coordinator's default client.
    /// Routing: the stream's pinned worker unless its queue is full, then
    /// least-loaded healthy spill; [`SubmitError::QueueFull`] when every
    /// queue is saturated (global backpressure — retry/shed). The
    /// returned [`Ticket`] claims exactly this request's [`Response`].
    pub fn submit(&self, req: Request) -> Result<Ticket, SubmitError> {
        self.default_client.submit(req)
    }

    /// [`Client::submit_batch`] on the coordinator's default client:
    /// submit a whole workload (blocking through backpressure), wait on
    /// the returned [`Batch`].
    pub fn submit_batch<I>(&self, reqs: I) -> Result<Batch, SubmitError>
    where
        I: IntoIterator<Item = Request>,
    {
        self.default_client.submit_batch(reqs)
    }

    /// [`Client::submit_fused`] on the coordinator's default client:
    /// one worker serves the whole group through the batched-chip path,
    /// amortizing every weight-row fetch across the group's utterances.
    pub fn submit_fused_batch(&self, reqs: Vec<Request>) -> Result<Batch, SubmitError> {
        self.default_client.submit_fused(reqs)
    }

    /// A cloneable submission handle for concurrent producers, with its
    /// own completion mailbox (clones share it; separate `client()`
    /// calls get isolated mailboxes — responses never cross).
    pub fn client(&self) -> Client {
        let router = self.router.as_ref().expect("router alive");
        let mailbox = Mailbox::new(false);
        let mut mailboxes = router.mailboxes.lock().unwrap();
        // prune entries whose client (and all its tickets) are gone, so a
        // long-lived pool creating short-lived clients stays bounded
        mailboxes.retain(|mb| mb.strong_count() > 0);
        mailboxes.push(Arc::downgrade(&mailbox));
        drop(mailboxes);
        Client { router: Arc::downgrade(router), mailbox }
    }

    /// Open a long-lived streaming session on `stream`'s pinned worker:
    /// an always-on detection pipeline (chip + VAD + wakeword state
    /// machine) whose recurrent state persists until the session closes.
    /// Stream ids may be reused — each call creates an independent
    /// session (internally keyed by a unique session id). Sessions
    /// opened without an explicit config use the pool's default
    /// [`StreamConfig`] (a [`CoordinatorBuilder::default_stream`] knob).
    ///
    /// Delivery of the open is a control message on the pinned lane: if
    /// that worker's queue is momentarily full, this call blocks until
    /// space frees (it does not fail on transient backpressure). If the
    /// pinned worker has *died* (its lane is disconnected), the returned
    /// session is already dead: pushes hand the chunk back inside
    /// [`StreamPushError::Closed`] and the event channel is empty — the
    /// same recoverable contract as [`Client::submit`] after shutdown,
    /// instead of a panic.
    pub fn open_stream(&self, stream: u64) -> StreamSession {
        self.open_stream_inner(stream, None, None)
    }

    /// [`open_stream`](Self::open_stream) with per-session VAD/detector
    /// tuning (e.g. [`crate::stream::vad::VadConfig::disabled`] for an
    /// energy A/B stream, or per-microphone detector thresholds).
    ///
    /// The session config's chip settings are validated
    /// ([`ChipConfig::validate`]) before any worker state is created —
    /// [`Error::InvalidConfig`](crate::error::Error::InvalidConfig)
    /// instead of a session that silently computes nothing, the same
    /// contract [`CoordinatorBuilder`] applies to the pool default.
    pub fn open_stream_with(
        &self,
        stream: u64,
        config: StreamConfig,
    ) -> Result<StreamSession, crate::error::Error> {
        config.chip.validate()?;
        Ok(self.open_stream_inner(stream, Some(config), None))
    }

    /// [`open_stream`](Self::open_stream) on a specific registered
    /// [`WeightVersion`] (e.g. a per-user enrolled head): the session's
    /// pipeline is built from that version's weight table and the
    /// version is *pinned* in the registry for the session's whole life —
    /// the LRU can never evict the weights out from under a live stream.
    /// The worker unpins it when the session closes. An optional
    /// per-session [`StreamConfig`] rides along (`None` = pool default).
    ///
    /// Fails up front with [`Error::Registry`](crate::error::Error::Registry)
    /// when `version` is unknown or was evicted, and with the usual
    /// [`Error::InvalidConfig`](crate::error::Error::InvalidConfig) when
    /// the session config is invalid.
    pub fn open_stream_with_weights(
        &self,
        stream: u64,
        config: Option<StreamConfig>,
        version: WeightVersion,
    ) -> Result<StreamSession, crate::error::Error> {
        if let Some(cfg) = &config {
            cfg.chip.validate()?;
        }
        let router = self.router();
        let params = router.registry.pin(version)?;
        Ok(self.open_stream_inner(stream, config, Some((version, params))))
    }

    fn open_stream_inner(
        &self,
        stream: u64,
        config: Option<StreamConfig>,
        weights: Option<(WeightVersion, Arc<QuantParams>)>,
    ) -> StreamSession {
        // bounded: a client that never drains cannot grow worker memory
        let (tx, rx) = sync_channel(STREAM_EVENT_CAP);
        let router = self.router.as_ref().expect("router alive");
        // sessions on the pool base still pin it: finish() unpins
        // unconditionally, and the spawn-time pin keeps base resident
        let weights = weights.unwrap_or_else(|| {
            let params =
                router.registry.pin(router.base.0).expect("base version pinned at spawn");
            (router.base.0, params)
        });
        let version = weights.0;
        let session = router.next_session.fetch_add(1, Ordering::Relaxed);
        let trace = router.mint_trace();
        let lane = router.pinned_lane(stream);
        router.recorders[lane].record(lane as u32, trace, EventKind::Submit);
        let alive = Arc::new(AtomicBool::new(true));
        let job = Job::StreamOpen {
            session,
            trace,
            config,
            events: tx,
            alive: Arc::clone(&alive),
            weights,
        };
        if router.send_stream_job(stream, job).is_err() {
            // the job never reached a worker: release its pin here
            router.registry.unpin(version);
            return StreamSession {
                stream,
                session,
                trace,
                router: Weak::new(),
                events: rx,
                closed: true,
                alive,
            };
        }
        StreamSession {
            stream,
            session,
            trace,
            router: Arc::downgrade(router),
            events: rx,
            closed: false,
            alive,
        }
    }

    /// Install `version` on a live streaming session at its next frame
    /// boundary — the epoch-fenced hot-swap (DESIGN.md §14). The stream
    /// keeps running: no frame is dropped, duplicated, or decided by a
    /// half-written weight table. The fence is the worker's job boundary —
    /// every queued chunk ahead of the swap is fully decided by the old
    /// weights; everything after it by `version`, against the recurrent
    /// state the old weights left behind (bit-identical to a fresh chip
    /// that was seeded with that state, see `rust/tests/customization.rs`).
    ///
    /// `version` is pinned here (submit side) and the outgoing version is
    /// unpinned by the worker once the swap lands, so neither table can be
    /// evicted mid-flight. The worker acknowledges with
    /// [`StreamEvent::WeightsSwapped`] on the session's event channel;
    /// subsequent [`StreamEvent::Detection`]s carry the new version.
    ///
    /// Fails with [`Error::Registry`](crate::error::Error::Registry) when
    /// `version` is unknown/evicted, and with
    /// [`Error::StreamPush`](crate::error::Error::StreamPush)
    /// ([`StreamPushError::Closed`]) when the pool is gone. A swap raced
    /// against session close is not an error: the worker drops it and
    /// releases the pin.
    pub fn swap_weights(
        &self,
        session: &StreamSession,
        version: WeightVersion,
    ) -> Result<(), crate::error::Error> {
        let router = self.router();
        let params = router.registry.pin(version)?;
        let job = Job::SwapWeights { session: session.session, version, params };
        if router.send_stream_job(session.stream, job).is_err() {
            router.registry.unpin(version);
            return Err(StreamPushError::Closed(Vec::new()).into());
        }
        Ok(())
    }

    /// Few-shot enroll a per-user keyword head: fine-tune ONLY the FC
    /// output layer on K≤[`crate::custom::MAX_SHOTS`] synthetic speaker
    /// utterances (recurrent weights frozen — the chip's temporal dynamics
    /// are untouched), requantize through the chip's integer pipeline, and
    /// register the result as a new [`WeightVersion`] with `parent` as its
    /// lineage. Runs on the caller's thread through the native backend —
    /// no worker lane is blocked. Deterministic: the same parent and
    /// config always produce the byte-identical version.
    ///
    /// `parent: None` enrolls from the pool's base weights.
    pub fn enroll(
        &self,
        parent: Option<WeightVersion>,
        cfg: EnrollConfig,
    ) -> crate::Result<EnrollOutcome> {
        let router = self.router();
        let parent_version = parent.unwrap_or(router.base.0);
        let base = router.registry.get(parent_version).map_err(crate::error::Error::from)?;
        // lint:allow(no-wallclock): enrollment-latency telemetry stamp on the control path (few-shot training, never per frame)
        let t0 = Instant::now();
        let backend = NativeBackend::new();
        let out = crate::custom::few_shot(&backend, &base, &cfg)?;
        let version = router.registry.insert(out.params, Some(parent_version));
        let latency_us = t0.elapsed().as_micros() as u64;
        router.registry.record_enroll_us(latency_us);
        Ok(EnrollOutcome {
            version,
            parent: parent_version,
            steps: out.steps,
            final_loss: out.final_loss,
            latency_us,
        })
    }

    /// The pool's weight registry (shared with the workers). Exposed for
    /// inspection — resident count, lineage, pin counts — and for
    /// registering externally trained tables via
    /// [`WeightRegistry::insert`].
    pub fn registry(&self) -> &WeightRegistry {
        &self.router().registry
    }

    /// The pool's base [`WeightVersion`] (the weights the builder was
    /// given), permanently resident.
    pub fn base_version(&self) -> WeightVersion {
        self.router().base.0
    }

    /// Block until `n` responses have been collected from the default
    /// mailbox's *unclaimed* queue — i.e. responses to
    /// [`Coordinator::submit`] calls whose [`Ticket`] was dropped.
    ///
    /// v1 compatibility shim only: it cannot see responses claimed (or
    /// claimable) by live tickets or by per-producer [`Client`]
    /// mailboxes, and the unclaimed queue keeps only the most recent
    /// [`ticket::UNCLAIMED_CAP`] responses (oldest dropped) if nobody
    /// collects. New code waits on tickets ([`Ticket::wait_timeout`],
    /// [`Batch::wait_all`]).
    #[deprecated(
        note = "wait on the Ticket returned by submit (or Batch::wait_all); \
                collect only drains default-mailbox responses whose tickets were dropped"
    )]
    pub fn collect(&self, n: usize, timeout: Duration) -> Vec<Response> {
        self.default_client.mailbox.collect_unclaimed(n, timeout)
    }

    /// Aggregate statistics snapshot: folds the per-worker telemetry
    /// shards (counters, latency histograms, chip activity) and the
    /// lock-free routing counters. Pure read — no worker is interrupted
    /// and no lock on any hot path is taken.
    pub fn stats(&self) -> Stats {
        let router = self.router();
        let mut s = Stats {
            per_worker: Vec::with_capacity(router.lanes.len()),
            ..Stats::default()
        };
        let mut spilled = 0;
        for (lane, shard) in router.lanes.iter().zip(router.shards.iter()) {
            let completed = shard.completed.load(Ordering::Relaxed);
            s.completed += completed;
            s.labelled += shard.labelled.load(Ordering::Relaxed);
            s.correct += shard.correct.load(Ordering::Relaxed);
            s.latency.merge(&shard.latency.snapshot());
            s.chunk_latency.merge(&shard.chunk_latency.snapshot());
            s.activity.merge(&shard.activity.snapshot());
            s.fused_batches += shard.fused_batches.load(Ordering::Relaxed);
            s.stream_events_dropped += shard.events_dropped.load(Ordering::Relaxed);
            s.session_bytes += shard.session_bytes.load(Ordering::Relaxed);
            s.weight_swaps += shard.weight_swaps.load(Ordering::Relaxed);
            let sp = lane.spilled_in.load(Ordering::Relaxed);
            spilled += sp;
            s.per_worker.push(LaneStats {
                completed,
                spilled_in: sp,
                pinned_full: lane.pinned_full.load(Ordering::Relaxed),
                stream_chunks: shard.stream_chunks.load(Ordering::Relaxed),
            });
        }
        s.spilled = spilled;
        s.rejected_full = router.rejected_full.load(Ordering::Relaxed);
        s.rejected_closed = router.rejected_closed.load(Ordering::Relaxed);
        s.resident_versions = router.registry.resident_count() as u64;
        s.enroll_latency = router.registry.enroll_latency();
        s.captured_us = crate::obs::monotonic_us();
        s
    }

    /// Versioned metrics snapshot for exposition: folds [`Coordinator::stats`]
    /// and the flight-recorder counters through the coordinator's
    /// [`MetricsRegistry`], which stamps a monotonically increasing sequence
    /// number and computes rates against the previously folded snapshot.
    /// Serialize with [`MetricsSnapshot::to_prometheus`] /
    /// [`MetricsSnapshot::to_json`].
    pub fn metrics(&self) -> MetricsSnapshot {
        let stats = self.stats();
        let rec = self.recorder_stats();
        self.registry.lock().unwrap().fold(stats, rec)
    }

    /// Aggregate flight-recorder counters across workers, or `None` when the
    /// pool was built without a recorder (the lean default).
    pub fn recorder_stats(&self) -> Option<RecorderStats> {
        let router = self.router();
        let mut merged = RecorderStats::default();
        let mut any = false;
        for rec in &router.recorders {
            if rec.is_enabled() {
                merged.merge(&rec.stats());
                any = true;
            }
        }
        any.then_some(merged)
    }

    /// Drain every worker's frozen post-mortem [`FlightDump`]s (oldest
    /// first per worker). Empty when no anomaly rule has fired since the
    /// last drain, or when the pool has no recorder.
    pub fn flight_dumps(&self) -> Vec<FlightDump> {
        self.router().recorders.iter().flat_map(|r| r.take_dumps()).collect()
    }

    /// Latest per-worker chip reports (power/energy telemetry),
    /// *pull-based*: a publish request is enqueued on every reachable lane
    /// and acknowledged snapshots are read back (bounded wait). Lanes that
    /// are full or stalled fall back to their last epoch/idle snapshot —
    /// reports are never computed on the per-utterance hot path.
    pub fn reports(&self) -> HashMap<usize, ChipReport> {
        let router = self.router();
        // bounded (bounded-channels invariant): each reachable lane gets
        // exactly one publish job and sends at most one ack, so capacity
        // = lane count can never reject a worker's try_send
        let (ack_tx, ack_rx) = sync_channel(router.lanes.len());
        let mut pending = 0usize;
        for lane in &router.lanes {
            if lane.tx.try_send(Job::PublishReport { ack: ack_tx.clone() }).is_ok() {
                lane.depth.fetch_add(1, Ordering::Relaxed);
                pending += 1;
            }
        }
        drop(ack_tx);
        // lint:allow(no-wallclock): bounded wait deadline for report acks during publish — operator-facing control path
        let deadline = Instant::now() + Duration::from_secs(5);
        while pending > 0 {
            // lint:allow(no-wallclock): remaining-budget computation for the ack wait above
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() || ack_rx.recv_timeout(remaining).is_err() {
                break;
            }
            pending -= 1;
        }
        let mut out = HashMap::new();
        for (w, shard) in router.shards.iter().enumerate() {
            if let Some(r) = *shard.report.lock().unwrap() {
                out.insert(w, r);
            }
        }
        out
    }

    /// Failure injection: stall/unstall a worker (its queue still accepts
    /// work until full; the router then spills around it).
    pub fn set_stalled(&self, worker: usize, stalled: bool) {
        self.router().lanes[worker].stalled.store(stalled, Ordering::SeqCst);
    }

    pub fn n_workers(&self) -> usize {
        self.router().lanes.len()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // close request queues (clients only hold weak refs); workers drain
        // their queues and exit, then join. The mailbox registry is taken
        // out first: after the joins no further delivery can happen, so
        // closing the mailboxes then wakes every blocked ticket wait with
        // a definitive `Closed` (already-delivered responses stay
        // claimable).
        let mailboxes = match self.router.take() {
            Some(router) => std::mem::take(&mut *router.mailboxes.lock().unwrap()),
            None => Vec::new(),
        };
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        for mb in mailboxes {
            if let Some(mb) = mb.upgrade() {
                mb.close();
            }
        }
    }
}

/// Worker-side state of one open streaming session.
struct WorkerSession {
    pipeline: StreamPipeline,
    events: SyncSender<StreamEvent>,
    /// cleared by the client handle on close/drop
    alive: Arc<AtomicBool>,
    /// session-scoped trace id, stamped on every recorder event and
    /// every [`StreamEvent`] this session emits
    trace: TraceId,
    /// last observed VAD gate state, threaded across chunks so the
    /// recorder emits gate open/close transitions (not per-frame noise)
    last_gated: Option<bool>,
    /// the session's active weight version: pinned in the registry for as
    /// long as the session lives (updated by [`Job::SwapWeights`], which
    /// unpins the predecessor), unpinned by [`Self::finish`]
    version: WeightVersion,
}

impl WorkerSession {
    /// Deliver one event without ever blocking the worker: a full channel
    /// sheds the event (counted), a disconnected one is a vanished client.
    /// Returns `true` when the event was shed.
    fn deliver(&self, ev: StreamEvent, shard: &WorkerShard) -> bool {
        if let Err(TrySendError::Full(_)) = self.events.try_send(ev) {
            shard.events_dropped.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }

    /// Flush final telemetry into the worker's shard and notify the client.
    /// The `Closed` marker is delivered with a short bounded retry: an
    /// explicit [`StreamSession::close`] is concurrently draining the
    /// channel, so space frees almost immediately; a dead or wedged client
    /// costs the worker at most the retry budget, never a hang.
    fn finish(
        mut self,
        shard: &WorkerShard,
        recorder: &FlightRecorder,
        worker: u32,
        registry: &WeightRegistry,
    ) {
        // release the session's hold on its weight version (the registry
        // may now evict it under LRU pressure)
        registry.unpin(self.version);
        recorder.record(worker, self.trace, EventKind::SessionClose);
        shard.activity.add(&self.pipeline.take_activity_delta());
        let activity = self.pipeline.chip.activity();
        let mut ev = StreamEvent::Closed {
            trace: self.trace,
            frames: activity.frames,
            gated_frames: activity.gated_frames,
        };
        for _ in 0..50 {
            ev = match self.events.try_send(ev) {
                Ok(()) => return,
                Err(TrySendError::Disconnected(_)) => return,
                Err(TrySendError::Full(e)) => e,
            };
            std::thread::sleep(Duration::from_millis(1));
        }
        shard.events_dropped.fetch_add(1, Ordering::Relaxed);
    }
}

/// Refresh the worker's live-session memory gauge (bounded by
/// construction: each pipeline's state is O(1) in the audio consumed).
fn publish_session_bytes(shard: &WorkerShard, sessions: &HashMap<u64, WorkerSession>) {
    let bytes: usize = sessions.values().map(|s| s.pipeline.state_bytes()).sum();
    shard.session_bytes.store(bytes as u64, Ordering::Relaxed);
}

/// Publish a fresh cumulative chip report into the shard's pull slot
/// (only once the chip has actually processed something — an idle worker
/// stays absent from [`Coordinator::reports`], as before).
fn publish_report(shard: &WorkerShard, chip: &KwsChip) {
    if chip.activity().frames > 0 {
        *shard.report.lock().unwrap() = Some(chip.report());
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    index: usize,
    base: (WeightVersion, Arc<QuantParams>),
    config: ChipConfig,
    default_stream: StreamConfig,
    report_epoch: u64,
    rx: Receiver<Job>,
    shard: Arc<WorkerShard>,
    stalled: Arc<AtomicBool>,
    depth: Arc<AtomicU64>,
    recorder: Arc<FlightRecorder>,
    registry: Arc<WeightRegistry>,
) {
    let mut chip = KwsChip::new((*base.1).clone(), config.clone());
    // the weight table currently loaded in this worker's utterance chip;
    // a request on a different version swaps before processing (cheap —
    // one SRAM image load — and utterances reset recurrent state anyway)
    let mut chip_version = base.0;
    let mut sessions: HashMap<u64, WorkerSession> = HashMap::new();
    // chip activity is flushed into the shard as monotonic deltas — the
    // chip's own counters are never reset, so its cumulative report stays
    // meaningful and nothing is double-counted
    let mut flushed = ChipActivity::default();
    let mut jobs_since_report = 0u64;
    // per-worker completion sequence (Response::worker_seq)
    let mut worker_seq = 0u64;
    'outer: loop {
        let job = match rx.try_recv() {
            Ok(j) => j,
            Err(TryRecvError::Empty) => {
                // lane drained: publish a fresh report before blocking, so
                // pull-side reads are never staler than the last idle moment
                publish_report(&shard, &chip);
                jobs_since_report = 0;
                match rx.recv() {
                    Ok(j) => j,
                    Err(_) => break 'outer,
                }
            }
            Err(TryRecvError::Disconnected) => break 'outer,
        };
        while stalled.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        depth.fetch_sub(1, Ordering::Relaxed);
        match job {
            Job::Utterance { req, trace, enqueued, reply, weights } => {
                if recorder.is_enabled() {
                    let queued_us = enqueued.elapsed().as_micros() as u64;
                    recorder.record(index as u32, trace, EventKind::Dequeue { queued_us });
                }
                // serve on the requested weight version: swap the chip's
                // table if a different one is loaded (process_utterance
                // resets recurrent state, so the swap is invisible beyond
                // the weights themselves)
                if weights.0 != chip_version {
                    chip.swap_weights((*weights.1).clone());
                    chip_version = weights.0;
                }
                // default: the lean NoProbe hot path — no per-frame
                // allocation, fixed-size Decision. A request that opted in
                // (`trace: true`) pays for the TraceProbe reconstruction;
                // an enabled flight recorder rides the same probe seam.
                let (decision, diag) = if req.trace {
                    let (d, t) = chip.process_utterance_traced(&req.audio12);
                    (d, Some(t))
                } else if recorder.is_enabled() {
                    let mut rp = RecorderProbe::new(&recorder, index as u32, trace);
                    let d = chip.process_utterance_probed(&req.audio12, &mut rp);
                    rp.flush_frame_batch();
                    (d, None)
                } else {
                    (chip.process_utterance(&req.audio12), None)
                };
                let lat_ms = decision.total_cycles as f64
                    / decision.frames.max(1) as f64
                    / crate::energy::calib::CLOCK_HZ
                    * 1e3;
                let correct = req.label.map(|l| l == decision.class);
                let resp = Response {
                    id: req.id,
                    stream: req.stream,
                    class: decision.class,
                    correct,
                    logits: decision.logits,
                    counted_frames: decision.counted_frames,
                    chip_cycles: decision.total_cycles,
                    chip_latency_ms: lat_ms,
                    service: enqueued.elapsed(),
                    worker: index,
                    worker_seq,
                    trace: diag,
                    trace_id: trace,
                    weights: weights.0,
                };
                worker_seq += 1;
                recorder.record(
                    index as u32,
                    trace,
                    EventKind::Decision {
                        class: decision.class as u8,
                        service_us: resp.service.as_micros() as u64,
                    },
                );
                // hot path: relaxed adds on this worker's own shard — no
                // lock, no allocation, no report rollup
                shard.completed.fetch_add(1, Ordering::Relaxed);
                if let Some(c) = correct {
                    shard.labelled.fetch_add(1, Ordering::Relaxed);
                    if c {
                        shard.correct.fetch_add(1, Ordering::Relaxed);
                    }
                }
                shard.latency.record(resp.service.as_micros() as u64);
                let act = chip.activity();
                shard.activity.add(&act.delta_since(&flushed));
                flushed = act;
                // completion routing: deliver to the submitting client's
                // mailbox, keyed by request id. A vanished client (all
                // tickets and handles dropped) just discards the response.
                if let Some(mailbox) = reply.upgrade() {
                    mailbox.deliver(resp);
                }
            }
            Job::UtteranceBatch { reqs, traces, enqueued, reply, weights } => {
                shard.fused_batches.fetch_add(1, Ordering::Relaxed);
                if recorder.is_enabled() {
                    let queued_us = enqueued.elapsed().as_micros() as u64;
                    recorder.record(
                        index as u32,
                        traces.first().copied().unwrap_or(TraceId::NONE),
                        EventKind::Dequeue { queued_us },
                    );
                }
                // phase 1 — FEx, per request: the feature front end is
                // recurrent per utterance, so each request's audio runs
                // through this worker's chip solo. Frames are popped as
                // raw Q8.8 activations (`pop_frame_activations`) instead
                // of being stepped, leaving the ΔRNN work for phase 2.
                let mut frames: Vec<Vec<[i16; crate::MAX_CHANNELS]>> =
                    Vec::with_capacity(reqs.len());
                for req in &reqs {
                    chip.reset();
                    let mut fr = Vec::new();
                    for piece in req.audio12.chunks(SAFE_CHUNK_SAMPLES) {
                        chip.push_samples(piece)
                            .expect("SAFE_CHUNK_SAMPLES fits the frame buffer");
                        while let Some(q) = chip.pop_frame_activations() {
                            fr.push(q);
                        }
                    }
                    frames.push(fr);
                }
                // phase 2 — ΔRNN, batched *per weight version*: the
                // batched stepper reads the host accel's single weight
                // table, so a mixed-version group is split into
                // sub-groups (first-seen order) and the table is swapped
                // between them. Members sharing a version still step in
                // lockstep against one weight-row fetch per fired lane,
                // and each member's decision stays bit-identical to a
                // solo run on its version (accel::batch module docs).
                let mut groups: Vec<(WeightVersion, Vec<usize>)> = Vec::new();
                for (i, (v, _)) in weights.iter().enumerate() {
                    match groups.iter_mut().find(|(gv, _)| *gv == *v) {
                        Some((_, members)) => members.push(i),
                        None => groups.push((*v, vec![i])),
                    }
                }
                let mut accums: Vec<DecisionAccum> = (0..reqs.len())
                    .map(|_| DecisionAccum::new(chip.config.warmup))
                    .collect();
                let mut activities: Vec<ChipActivity> =
                    vec![ChipActivity::default(); reqs.len()];
                for (version, members) in &groups {
                    if *version != chip_version {
                        chip.swap_weights((*weights[members[0]].1).clone());
                        chip_version = *version;
                    }
                    let mut sessions: Vec<BatchSession> =
                        members.iter().map(|_| BatchSession::new()).collect();
                    let max_t =
                        members.iter().map(|&i| frames[i].len()).max().unwrap_or(0);
                    for t in 0..max_t {
                        for (sess, &i) in sessions.iter_mut().zip(members.iter()) {
                            if let Some(&q) = frames[i].get(t) {
                                sess.stage(q);
                            }
                        }
                        chip.accel.step_frames_batched(&mut sessions);
                        for (sess, &i) in sessions.iter().zip(members.iter()) {
                            if t >= frames[i].len() {
                                continue;
                            }
                            let r = sess.last.expect("staged session stepped");
                            accums[i].push(&FrameOut {
                                index: t as u64,
                                feat: [0i64; crate::MAX_CHANNELS],
                                logits: r.logits,
                                fired: r.fired,
                                cycles: r.cycles,
                                gated: false,
                            });
                        }
                    }
                    for (sess, &i) in sessions.iter().zip(members.iter()) {
                        activities[i] = sess.activity;
                    }
                }
                // phase 3 — per-request responses and telemetry. The RNN
                // side of the activity is booked from each session (the
                // host accel's solo counters were untouched); the FEx
                // side flushes through the usual chip-activity delta.
                for (i, ((req, trace), (version, _))) in
                    reqs.into_iter().zip(traces).zip(weights).enumerate()
                {
                    let decision = accums[i].finish();
                    let lat_ms = decision.total_cycles as f64
                        / decision.frames.max(1) as f64
                        / crate::energy::calib::CLOCK_HZ
                        * 1e3;
                    let correct = req.label.map(|l| l == decision.class);
                    let resp = Response {
                        id: req.id,
                        stream: req.stream,
                        class: decision.class,
                        correct,
                        logits: decision.logits,
                        counted_frames: decision.counted_frames,
                        chip_cycles: decision.total_cycles,
                        chip_latency_ms: lat_ms,
                        service: enqueued.elapsed(),
                        worker: index,
                        worker_seq,
                        trace: None,
                        trace_id: trace,
                        weights: version,
                    };
                    worker_seq += 1;
                    recorder.record(
                        index as u32,
                        trace,
                        EventKind::Decision {
                            class: decision.class as u8,
                            service_us: resp.service.as_micros() as u64,
                        },
                    );
                    shard.completed.fetch_add(1, Ordering::Relaxed);
                    if let Some(c) = correct {
                        shard.labelled.fetch_add(1, Ordering::Relaxed);
                        if c {
                            shard.correct.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    shard.latency.record(resp.service.as_micros() as u64);
                    shard.activity.add(&activities[i]);
                    if let Some(mailbox) = reply.upgrade() {
                        mailbox.deliver(resp);
                    }
                }
                let act = chip.activity();
                shard.activity.add(&act.delta_since(&flushed));
                flushed = act;
            }
            Job::StreamOpen { session, trace, config: stream_cfg, events, alive, weights } => {
                let cfg = stream_cfg.unwrap_or_else(|| default_stream.clone());
                let pipeline = StreamPipeline::new((*weights.1).clone(), cfg);
                recorder.record(index as u32, trace, EventKind::SessionOpen);
                // session ids are unique; a collision would be a router bug,
                // but never leak the old session's telemetry silently
                if let Some(old) = sessions.insert(
                    session,
                    WorkerSession {
                        pipeline,
                        events,
                        alive,
                        trace,
                        last_gated: None,
                        version: weights.0,
                    },
                ) {
                    old.finish(&shard, &recorder, index as u32, &registry);
                }
                publish_session_bytes(&shard, &sessions);
            }
            Job::SwapWeights { session, version, params } => {
                if let Some(sess) = sessions.get_mut(&session) {
                    // the epoch fence: jobs on this lane serialize, and
                    // every StreamData drains all its completed frames
                    // before returning — so right here no frame is
                    // half-stepped, the ΔFIFOs are empty, and installing
                    // the new table is invisible to the frame pipeline
                    sess.pipeline.swap_weights((*params).clone());
                    let outgoing = sess.version;
                    sess.version = version;
                    registry.unpin(outgoing);
                    shard.weight_swaps.fetch_add(1, Ordering::Relaxed);
                    let frame = sess.pipeline.chip.activity().frames;
                    if sess.deliver(
                        StreamEvent::WeightsSwapped { trace: sess.trace, version, frame },
                        &shard,
                    ) {
                        recorder.record(index as u32, sess.trace, EventKind::EventDropped);
                    }
                } else {
                    // swap raced against close: the session is gone, so
                    // release the pin taken at submit
                    registry.unpin(version);
                }
            }
            Job::StreamData { session, chunk, enqueued } => {
                // chunks for unknown/closed sessions are dropped (a late
                // push after close is not an error)
                if let Some(sess) = sessions.get_mut(&session) {
                    if recorder.is_enabled() {
                        let queued_us = enqueued.elapsed().as_micros() as u64;
                        recorder.record(
                            index as u32,
                            sess.trace,
                            EventKind::Dequeue { queued_us },
                        );
                    }
                    // slice hostile oversized chunks so the pipeline's
                    // bounded frame buffer can never reject (and the old
                    // panic path can never kill this worker thread)
                    let bytes_before = sess.pipeline.state_bytes();
                    let mut detections = Vec::new();
                    if recorder.is_enabled() {
                        // recorder path: ride the probe seam so frame
                        // batches and gate transitions land in the ring
                        let mut rp = RecorderProbe::with_gate_state(
                            &recorder,
                            index as u32,
                            sess.trace,
                            sess.last_gated,
                        );
                        for piece in chunk.chunks(crate::chip::SAFE_CHUNK_SAMPLES) {
                            detections.extend(
                                sess.pipeline
                                    .push_audio_probed(piece, &mut rp)
                                    .expect("SAFE_CHUNK_SAMPLES fits the frame buffer"),
                            );
                        }
                        sess.last_gated = rp.gate_state();
                        rp.flush_frame_batch();
                    } else {
                        for piece in chunk.chunks(crate::chip::SAFE_CHUNK_SAMPLES) {
                            detections.extend(
                                sess.pipeline
                                    .push_audio(piece)
                                    .expect("SAFE_CHUNK_SAMPLES fits the frame buffer"),
                            );
                        }
                    }
                    shard.stream_chunks.fetch_add(1, Ordering::Relaxed);
                    shard.chunk_latency.record(enqueued.elapsed().as_micros() as u64);
                    shard.activity.add(&sess.pipeline.take_activity_delta());
                    // hot path: update the memory gauge incrementally for
                    // just this session (O(1), not O(live sessions) — the
                    // full re-sum runs only on open/close/GC)
                    let bytes_after = sess.pipeline.state_bytes();
                    if bytes_after >= bytes_before {
                        shard
                            .session_bytes
                            .fetch_add((bytes_after - bytes_before) as u64, Ordering::Relaxed);
                    } else {
                        shard
                            .session_bytes
                            .fetch_sub((bytes_before - bytes_after) as u64, Ordering::Relaxed);
                    }
                    for d in detections {
                        recorder.record(
                            index as u32,
                            sess.trace,
                            EventKind::Detection { class: d.class as u8 },
                        );
                        if sess.deliver(
                            StreamEvent::Detection {
                                trace: sess.trace,
                                event: d,
                                weights: sess.version,
                            },
                            &shard,
                        ) {
                            recorder.record(
                                index as u32,
                                sess.trace,
                                EventKind::EventDropped,
                            );
                        }
                    }
                }
            }
            Job::StreamClose { session } => {
                if let Some(sess) = sessions.remove(&session) {
                    // gauge first: when the client's close() returns (it
                    // waits on the Closed marker finish() delivers), the
                    // session-memory gauge is already consistent
                    publish_session_bytes(&shard, &sessions);
                    sess.finish(&shard, &recorder, index as u32, &registry);
                }
            }
            Job::PublishReport { ack } => {
                publish_report(&shard, &chip);
                jobs_since_report = 0;
                // non-blocking by construction: the requester sized the
                // channel at one slot per lane (a gone receiver is fine)
                let _ = ack.try_send(());
            }
        }
        // bound report staleness under sustained load (a lane that never
        // drains still publishes every `report_epoch` jobs)
        jobs_since_report += 1;
        if jobs_since_report >= report_epoch {
            publish_report(&shard, &chip);
            jobs_since_report = 0;
        }
        // GC sessions whose client vanished without a deliverable Close
        // (StreamSession::drop on a saturated lane clears `alive` and
        // gives up) — otherwise their pipelines would live until pool
        // shutdown
        if !sessions.is_empty() {
            let dead: Vec<u64> = sessions
                .iter()
                .filter(|(_, s)| !s.alive.load(Ordering::Relaxed))
                .map(|(&k, _)| k)
                .collect();
            if !dead.is_empty() {
                for k in dead {
                    if let Some(sess) = sessions.remove(&k) {
                        sess.finish(&shard, &recorder, index as u32, &registry);
                    }
                }
                publish_session_bytes(&shard, &sessions);
            }
        }
    }
    // pool shutdown with sessions still open: flush their telemetry
    for (_, sess) in sessions.drain() {
        sess.finish(&shard, &recorder, index as u32, &registry);
    }
    publish_session_bytes(&shard, &sessions);
    publish_report(&shard, &chip);
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::error::{StreamPushError, WaitError};
    use crate::util::prng::Pcg;

    fn rng_quant(seed: u64) -> QuantParams {
        let mut rng = Pcg::new(seed);
        let mut q = QuantParams::zeroed();
        q.w_x.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
        q.w_h.iter_mut().flatten().for_each(|w| *w = (rng.below(32) as i8) - 16);
        q.w_fc.iter_mut().flatten().for_each(|w| *w = (rng.below(64) as i8) - 32);
        q
    }

    /// Test pool via the v2 builder.
    fn pool(seed: u64, workers: usize, queue_depth: usize) -> Coordinator {
        Coordinator::builder(rng_quant(seed), ChipConfig::design_point())
            .workers(workers)
            .queue_depth(queue_depth)
            .build()
            .expect("valid test pool")
    }

    fn request(stream: u64, seed: u64) -> Request {
        let mut rng = Pcg::new(seed);
        let label = (seed % 12) as usize;
        let audio = crate::audio::synth_utterance(label, &mut rng);
        Request {
            id: 0,
            stream,
            audio12: crate::audio::quantize_12b(&audio),
            label: Some(label),
            trace: false,
            weights: None,
        }
    }

    /// Wait a set of tickets (bounded), asserting each resolves to its
    /// own request id.
    fn wait_all(tickets: Vec<Ticket>) -> Vec<Response> {
        tickets
            .into_iter()
            .map(|t| {
                let id = t.id();
                let r = t.wait_timeout(Duration::from_secs(60)).expect("response");
                assert_eq!(r.id, id, "ticket resolved to a foreign response");
                r
            })
            .collect()
    }

    #[test]
    fn percentile_uses_round_half_up_rank() {
        let v: Vec<u64> = (1..=100).collect();
        // the old truncating index returned v[98] = 99 (the p98 sample)
        assert_eq!(percentile(&v, 0.99), 100);
        assert_eq!(percentile(&v, 0.50), 51);
        assert_eq!(percentile(&v, 1.0), 100);
        assert_eq!(percentile(&v, 0.0), 1);
        // exact small-N: median of an odd-length sample is the middle
        assert_eq!(percentile(&[5, 1, 3], 0.50), 3);
        assert_eq!(percentile(&[1, 2, 3, 4, 5], 0.50), 3);
        assert_eq!(percentile(&[42], 0.99), 42);
        assert_eq!(percentile(&[], 0.99), 0);
    }

    #[test]
    fn histogram_percentile_within_one_bucket_of_exact() {
        // same rank rule => the histogram lands in exactly the bucket
        // holding the exact order statistic, so the answers differ only by
        // the bucket's midpoint rounding (≤ 1/64 relative)
        let mut rng = Pcg::new(9);
        let mut hist = LogHistogram::new();
        let mut sample = Vec::new();
        for _ in 0..5000 {
            let v = (rng.below(1 << 16) as u64 + 1) * (1 + rng.below(64) as u64);
            sample.push(v);
            hist.record(v);
        }
        for p in [0.50, 0.90, 0.99] {
            let exact = percentile(&sample, p);
            let approx = hist.percentile(p);
            assert_eq!(
                crate::util::hist::bucket_index(exact),
                crate::util::hist::bucket_index(approx),
                "p{p}: exact {exact} vs hist {approx} landed in different buckets"
            );
            let rel = (approx as f64 - exact as f64).abs() / exact as f64;
            assert!(rel <= 1.0 / 64.0 + 1e-12, "p{p}: rel err {rel}");
        }
    }

    #[test]
    fn serves_requests_and_aggregates() {
        let coord = pool(1, 2, 8);
        let n = 6;
        let mut tickets = Vec::new();
        for i in 0..n {
            tickets.push(coord.submit(request(i as u64, i as u64)).expect("submit"));
        }
        let responses = wait_all(tickets);
        assert_eq!(responses.len(), n);
        let stats = coord.stats();
        assert_eq!(stats.completed, n as u64);
        assert_eq!(stats.labelled, n as u64);
        assert_eq!(stats.latency.count(), n as u64);
        assert!(stats.activity.frames >= (n * 62) as u64);
        // no request lost or duplicated
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n);
    }

    #[test]
    fn submit_batch_resolves_every_ticket() {
        let coord = pool(15, 2, 4);
        let reqs: Vec<Request> = (0..10).map(|i| request(i % 3, 70 + i)).collect();
        let batch = coord.submit_batch(reqs).expect("pool alive");
        assert_eq!(batch.len(), 10);
        assert!(!batch.is_empty());
        let ids = batch.ids();
        let responses = batch.wait_all(Duration::from_secs(60));
        assert_eq!(responses.len(), 10, "batch lost responses");
        let got: Vec<u64> = responses.iter().map(|r| r.id).collect();
        assert_eq!(got, ids, "wait_all must preserve submission order");
    }

    #[test]
    fn fused_batch_matches_solo_submissions() {
        let coord = pool(21, 2, 8);
        let reqs: Vec<Request> = (0..5).map(|i| request(i, 40 + i)).collect();
        let solo = coord
            .submit_batch(reqs.clone())
            .expect("pool alive")
            .wait_all(Duration::from_secs(60));
        let fused = coord
            .submit_fused_batch(reqs)
            .expect("pool alive")
            .wait_all(Duration::from_secs(60));
        assert_eq!(solo.len(), 5);
        assert_eq!(fused.len(), 5);
        for (a, b) in solo.iter().zip(fused.iter()) {
            // the fused path must produce bit-identical decisions
            assert_eq!(a.class, b.class);
            assert_eq!(a.logits, b.logits);
            assert_eq!(a.counted_frames, b.counted_frames);
            assert_eq!(a.chip_cycles, b.chip_cycles);
            assert_eq!(a.correct, b.correct);
            assert!(b.trace.is_none(), "fused path is lean-only");
        }
        // one fused group, on one worker, every member counted
        let workers: std::collections::HashSet<usize> =
            fused.iter().map(|r| r.worker).collect();
        assert_eq!(workers.len(), 1, "fused group must stay on one worker");
        let stats = coord.stats();
        assert_eq!(stats.fused_batches, 1);
        assert_eq!(stats.completed, 10);
        assert_eq!(stats.labelled, 10);
        // per-session activity booked solo-equivalently: both passes over
        // the same 5 utterances contribute the same frame count
        assert_eq!(stats.activity.frames % 2, 0);
    }

    #[test]
    fn fused_batch_empty_and_closed_contracts() {
        let coord = pool(22, 1, 4);
        let empty = coord.submit_fused_batch(Vec::new()).expect("empty group is fine");
        assert_eq!(empty.len(), 0);
        let client = coord.client();
        drop(coord);
        match client.submit_fused(vec![request(0, 1)]) {
            Err(SubmitError::Closed(r)) => assert_eq!(r.stream, 0),
            other => panic!("expected Closed, got {:?}", other.map(|b| b.len())),
        }
    }

    #[test]
    fn stream_pinning_is_stable() {
        let coord = pool(2, 3, 8);
        let mut tickets = Vec::new();
        for _ in 0..4 {
            tickets.push(coord.submit(request(7, 1)).unwrap());
        }
        let responses = wait_all(tickets);
        let workers: std::collections::HashSet<usize> =
            responses.iter().map(|r| r.worker).collect();
        assert_eq!(workers.len(), 1, "stream 7 must stay on its pinned worker");
    }

    #[test]
    fn spills_around_stalled_worker() {
        let coord = pool(3, 2, 1);
        // stall worker 0 (stream 0 pins there), saturate its queue of 1,
        // further submissions must spill to worker 1 and still complete
        coord.set_stalled(0, true);
        let mut tickets = Vec::new();
        for i in 0..4 {
            if let Ok(t) = coord.submit(request(0, 10 + i)) {
                tickets.push(t);
            }
        }
        assert!(tickets.len() >= 2, "spill path dead: {}", tickets.len());
        coord.set_stalled(0, false);
        let accepted = tickets.len();
        let responses = wait_all(tickets);
        assert_eq!(responses.len(), accepted);
    }

    #[test]
    fn backpressure_rejects_with_queue_full_and_request_intact() {
        let coord = pool(4, 1, 1);
        coord.set_stalled(0, true);
        let mut rejected = 0;
        let mut tickets = Vec::new();
        for i in 0..6 {
            let req = request(i, i);
            let audio_len = req.audio12.len();
            match coord.submit(req) {
                Ok(t) => tickets.push(t),
                Err(e) => {
                    // typed cause + payload handed back intact
                    assert!(e.is_queue_full(), "saturation must be QueueFull: {e}");
                    assert_eq!(e.request().audio12.len(), audio_len);
                    assert_eq!(e.into_request().stream, i);
                    rejected += 1;
                }
            }
        }
        assert!(rejected >= 3, "backpressure missing: only {rejected} rejected");
        let s = coord.stats();
        assert!(s.rejected_full >= 3);
        assert_eq!(s.rejected_closed, 0, "a stalled-but-alive pool is not Closed");
        coord.set_stalled(0, false);
    }

    #[test]
    fn accuracy_accounting() {
        let coord = pool(5, 2, 8);
        let mut tickets = Vec::new();
        for i in 0..4 {
            tickets.push(coord.submit(request(i, i)).unwrap());
        }
        wait_all(tickets);
        let s = coord.stats();
        assert_eq!(s.labelled, 4);
        assert!(s.accuracy() >= 0.0 && s.accuracy() <= 1.0);
        assert!(s.p50_us() > 0);
        assert!(s.p99_us() >= s.p50_us());
    }

    #[test]
    fn stats_memory_is_independent_of_request_count() {
        let coord = pool(13, 2, 8);
        let t = coord.submit(request(0, 1)).unwrap();
        t.wait_timeout(Duration::from_secs(60)).expect("response");
        let before = coord.stats().telemetry_bytes();
        let mut tickets = Vec::new();
        for i in 0..12 {
            tickets.push(coord.submit(request(i % 3, 60 + i)).unwrap());
        }
        wait_all(tickets);
        let after = coord.stats();
        assert_eq!(after.completed, 13);
        assert_eq!(after.telemetry_bytes(), before, "telemetry grew with requests");
    }

    #[test]
    fn reports_are_pull_based_and_fresh() {
        let coord = pool(14, 2, 8);
        // an idle pool has no reports (no chip has processed anything)
        assert!(coord.reports().is_empty(), "idle workers must not report");
        let mut tickets = Vec::new();
        for i in 0..4 {
            tickets.push(coord.submit(request(i, i)).unwrap());
        }
        wait_all(tickets);
        let reports = coord.reports();
        assert!(!reports.is_empty(), "pull returned nothing after work");
        let frames: u64 = reports.values().map(|r| r.frames).sum();
        assert_eq!(frames, 4 * 62, "reports must reflect cumulative work");
        for r in reports.values() {
            assert!(r.power.total_uw() > 0.0);
            assert!(r.latency_ms > 0.0, "report computed on zeroed activity");
        }
    }

    #[test]
    fn per_worker_counters_track_spill_and_rejection() {
        let coord = pool(7, 2, 1);
        coord.set_stalled(0, true);
        let mut tickets = Vec::new();
        for i in 0..6 {
            if let Ok(t) = coord.submit(request(0, 40 + i)) {
                tickets.push(t);
            }
        }
        coord.set_stalled(0, false);
        let accepted = tickets.len();
        let responses = wait_all(tickets);
        assert_eq!(responses.len(), accepted);
        let s = coord.stats();
        assert_eq!(s.per_worker.len(), 2);
        assert!(s.per_worker[0].pinned_full >= 1, "pinned-full stalls not visible");
        assert!(s.spilled >= 1, "no spill counted");
        assert_eq!(s.spilled, s.per_worker[1].spilled_in, "spill target mismatch");
        let done: u64 = s.per_worker.iter().map(|w| w.completed).sum();
        assert_eq!(done, s.completed, "per-worker completions don't sum up");
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_collect_shim_drains_dropped_ticket_responses() {
        // the v1 pattern: submit through the coordinator, ignore the
        // return value, drain with collect — still works through the
        // default mailbox's unclaimed queue
        let coord = pool(16, 2, 8);
        for i in 0..3 {
            let _ = coord.submit(request(i, i)).expect("submit");
        }
        let responses = coord.collect(3, Duration::from_secs(60));
        assert_eq!(responses.len(), 3, "shim lost responses");
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 3);
        // but it cannot steal a live ticket's response
        let t = coord.submit(request(0, 9)).expect("submit");
        let id = t.id();
        assert!(coord.collect(1, Duration::from_secs(1)).is_empty());
        assert_eq!(t.wait_timeout(Duration::from_secs(60)).expect("response").id, id);
    }

    #[test]
    fn try_take_polls_without_blocking() {
        let coord = pool(17, 1, 4);
        let mut ticket = coord.submit(request(0, 3)).expect("submit");
        // poll until delivered: every miss hands the ticket back
        let deadline = Instant::now() + Duration::from_secs(60);
        let resp = loop {
            ticket = match ticket.try_take() {
                Ok(r) => break r,
                Err(WaitError::Timeout(t)) => t,
                Err(WaitError::Closed) => panic!("pool closed mid-test"),
            };
            assert!(Instant::now() < deadline, "response never delivered");
            std::thread::sleep(Duration::from_millis(1));
        };
        assert!(resp.class < crate::NUM_CLASSES);
    }

    #[test]
    fn default_response_is_lean_and_trace_flag_opts_in() {
        let coord = pool(20, 2, 8);
        // default: no per-frame payload rides through the mailbox
        let lean = coord
            .submit(request(0, 1))
            .unwrap()
            .wait_timeout(Duration::from_secs(60))
            .expect("response");
        assert!(lean.trace.is_none(), "untraced request grew a trace");
        assert!(lean.counted_frames > 0);
        assert!(lean.chip_cycles > 0);
        assert_eq!(
            (0..crate::NUM_CLASSES).max_by_key(|&k| lean.logits[k]).unwrap(),
            lean.class,
            "summed logits must rank to the reported class"
        );
        // trace: true — the worker reconstructs the Fig. 11 traces
        let mut req = request(0, 1);
        req.trace = true;
        let traced = coord
            .submit(req)
            .unwrap()
            .wait_timeout(Duration::from_secs(60))
            .expect("response");
        let trace = traced.trace.expect("traced request lost its trace");
        assert_eq!(trace.frame_cycles.len(), 62);
        assert_eq!(trace.frame_cycles.iter().sum::<u64>(), traced.chip_cycles);
        // identical audio on the same pinned worker chip: the lean and
        // traced submissions agree on everything but the trace
        assert_eq!(traced.class, lean.class);
        assert_eq!(traced.logits, lean.logits);
        assert_eq!(traced.counted_frames, lean.counted_frames);
    }

    #[test]
    fn flooded_session_backpressures_and_worker_survives() {
        // ISSUE-5 regression: flooding a session without the worker
        // polling used to be able to kill the worker thread through the
        // CDC-FIFO expect. Now the lane applies typed Backpressure, a
        // hostile oversized chunk is sliced worker-side, and the worker
        // stays alive for subsequent work.
        let coord = pool(21, 1, 2);
        let sess = coord.open_stream(0);
        coord.set_stalled(0, true);
        // flood the pinned lane without anything draining
        let mut backpressured = 0;
        for _ in 0..64 {
            match sess.push(vec![0i64; 256]) {
                Ok(()) => {}
                Err(StreamPushError::Backpressure(chunk)) => {
                    assert_eq!(chunk.len(), 256, "chunk not handed back intact");
                    backpressured += 1;
                }
                Err(e) => panic!("flooding a live pool must be Backpressure, not {e}"),
            }
        }
        assert!(backpressured > 0, "flood never hit backpressure");
        coord.set_stalled(0, false);
        // a hostile chunk bigger than the chip's whole frame buffer: the
        // worker slices it instead of dying
        let monster = vec![0i64; (crate::chip::PENDING_FRAME_CAP + 8) * crate::FRAME_SAMPLES];
        let monster_frames = (monster.len() / crate::FRAME_SAMPLES) as u64;
        sess.push_blocking(monster).expect("pool alive");
        let events = sess.close();
        let closed = events.iter().find_map(|e| match e {
            StreamEvent::Closed { frames, .. } => Some(*frames),
            _ => None,
        });
        let frames = closed.expect("worker died: no Closed marker");
        assert!(frames >= monster_frames, "worker lost the sliced chunk: {frames}");
        // the worker thread is still serving requests
        let r = coord
            .submit(request(0, 2))
            .expect("worker alive after flood")
            .wait_timeout(Duration::from_secs(60))
            .expect("response after flood");
        assert!(r.class < crate::NUM_CLASSES);
        // all live sessions closed: the session-memory gauge is back to 0
        assert_eq!(coord.stats().session_bytes, 0);
    }

    #[test]
    fn stream_session_lifecycle_and_telemetry() {
        let coord = pool(8, 2, 8);
        let sess = coord.open_stream(3);
        let cfg = crate::audio::track::TrackConfig {
            duration_s: 4,
            keywords: 2,
            fillers: 0,
            noise: (0.001, 0.002),
        };
        let (audio12, _) = crate::audio::track::synth_track(&cfg, 9);
        let n_chunks = audio12.chunks(512).count() as u64;
        for c in audio12.chunks(512) {
            sess.push_blocking(c.to_vec()).expect("pool alive");
        }
        let events = sess.close();
        let closed_frames = events.iter().find_map(|e| match e {
            StreamEvent::Closed { frames, .. } => Some(*frames),
            _ => None,
        });
        assert_eq!(
            closed_frames,
            Some((audio12.len() / crate::FRAME_SAMPLES) as u64),
            "session lost frames"
        );
        let s = coord.stats();
        let chunks: u64 = s.per_worker.iter().map(|w| w.stream_chunks).sum();
        assert_eq!(chunks, n_chunks);
        assert_eq!(s.chunk_latency.count(), n_chunks);
        assert!(s.activity.frames >= (audio12.len() / crate::FRAME_SAMPLES) as u64);
    }

    #[test]
    fn sessions_and_requests_share_the_pool() {
        let coord = pool(9, 2, 8);
        let sess = coord.open_stream(0);
        let mut tickets = Vec::new();
        for i in 0..4 {
            tickets.push(coord.submit(request(i, i)).unwrap());
        }
        sess.push_blocking(vec![0i64; 1280]).unwrap();
        let responses = wait_all(tickets);
        assert_eq!(responses.len(), 4);
        let events = sess.close();
        assert!(
            events.iter().any(|e| matches!(e, StreamEvent::Closed { .. })),
            "no Closed marker"
        );
    }

    #[test]
    fn open_stream_with_applies_custom_vad_config() {
        let coord = pool(12, 2, 8);
        let sess = coord
            .open_stream_with(
                4,
                StreamConfig::for_chip(ChipConfig::design_point())
                    .with_vad(crate::stream::vad::VadConfig::disabled()),
            )
            .expect("valid session config");
        // an invalid per-session chip config is rejected up front — the
        // same contract the builder applies to the pool default
        let mut bad = StreamConfig::for_chip(ChipConfig::design_point());
        bad.chip.accel.delta_th_q8 = -1;
        assert!(coord.open_stream_with(5, bad).is_err());
        // pure silence: the default VAD would gate every frame, a disabled
        // one must clock the ΔRNN on all 10
        sess.push_blocking(vec![0i64; 1280]).unwrap();
        let events = sess.close();
        let closed = events.iter().find_map(|e| match e {
            StreamEvent::Closed { frames, gated_frames, .. } => Some((*frames, *gated_frames)),
            _ => None,
        });
        assert_eq!(closed, Some((10, 0)), "disabled VAD must never gate");
    }

    #[test]
    fn builder_default_stream_applies_to_plain_open_stream() {
        // a pool whose *default* session config disables the VAD: a
        // session opened without per-session tuning inherits it
        let coord = Coordinator::builder(rng_quant(18), ChipConfig::design_point())
            .workers(2)
            .queue_depth(8)
            .default_stream(
                StreamConfig::for_chip(ChipConfig::design_point())
                    .with_vad(crate::stream::vad::VadConfig::disabled()),
            )
            .build()
            .expect("valid pool");
        let sess = coord.open_stream(2);
        sess.push_blocking(vec![0i64; 1280]).unwrap();
        let events = sess.close();
        let closed = events.iter().find_map(|e| match e {
            StreamEvent::Closed { frames, gated_frames, .. } => Some((*frames, *gated_frames)),
            _ => None,
        });
        assert_eq!(closed, Some((10, 0)), "pool default stream config ignored");
    }

    #[test]
    fn builder_rejects_invalid_pool_shapes() {
        let q = rng_quant(19);
        let cfg = ChipConfig::design_point();
        assert!(Coordinator::builder(q.clone(), cfg.clone()).workers(0).build().is_err());
        assert!(Coordinator::builder(q.clone(), cfg.clone())
            .queue_depth(0)
            .build()
            .is_err());
        assert!(Coordinator::builder(q.clone(), cfg.clone())
            .report_epoch(0)
            .build()
            .is_err());
        let err = Coordinator::builder(q, cfg)
            .workers(builder::MAX_WORKERS + 1)
            .build()
            .err()
            .expect("oversized pool must be rejected");
        assert!(matches!(err, crate::Error::InvalidConfig { field: "workers", .. }));
    }

    #[test]
    fn duplicate_stream_ids_are_independent_sessions() {
        let coord = pool(11, 2, 8);
        let a = coord.open_stream(5);
        let b = coord.open_stream(5);
        a.push_blocking(vec![0i64; 256]).unwrap();
        b.push_blocking(vec![0i64; 512]).unwrap();
        let ea = a.close();
        // closing `a` must not tear down `b`'s worker state
        b.push_blocking(vec![0i64; 256]).unwrap();
        let eb = b.close();
        let frames = |evs: &[StreamEvent]| {
            evs.iter().find_map(|e| match e {
                StreamEvent::Closed { frames, .. } => Some(*frames),
                _ => None,
            })
        };
        assert_eq!(frames(&ea), Some(2), "session a lost frames");
        assert_eq!(frames(&eb), Some(6), "session b died with a, or lost frames");
    }

    #[test]
    fn session_outlives_coordinator_safely() {
        let coord = pool(10, 1, 4);
        let sess = coord.open_stream(1);
        sess.push_blocking(vec![0i64; 256]).unwrap();
        drop(coord);
        // pool gone: pushes fail cleanly, typed Closed, chunk handed back
        let chunk = vec![1i64; 128];
        match sess.push(chunk.clone()) {
            Err(StreamPushError::Closed(c)) => assert_eq!(c, chunk),
            other => panic!("expected Closed with the chunk back, got {other:?}"),
        }
        // the worker flushed a Closed marker during shutdown
        let events: Vec<StreamEvent> = sess.events.try_iter().collect();
        assert!(events.iter().any(|e| matches!(e, StreamEvent::Closed { .. })));
    }

    #[test]
    fn client_submits_and_outlives_coordinator_safely() {
        let coord = pool(6, 2, 8);
        let client = coord.client();
        let t = client.submit(request(1, 1)).expect("client submit");
        let resp = t.wait_timeout(Duration::from_secs(60)).expect("response");
        assert_eq!(resp.stream, 1);
        assert!(!client.is_closed());
        // a ticket still in flight when the pool dies resolves Closed …
        let pending = client.submit(request(1, 3)).expect("client submit");
        drop(coord);
        assert!(client.is_closed());
        // … or claims its response if the shutdown drain completed it
        match pending.wait_timeout(Duration::from_secs(60)) {
            Ok(r) => assert_eq!(r.stream, 1),
            Err(WaitError::Closed) => {}
            Err(WaitError::Timeout(_)) => panic!("post-shutdown wait must not hang"),
        }
        // the weak handle fails cleanly after the pool is gone, with the
        // typed cause and the request handed back
        match client.submit(request(1, 2)) {
            Err(e) => {
                assert!(e.is_closed());
                assert_eq!(e.into_request().stream, 1);
            }
            Ok(_) => panic!("submit into a dropped pool must fail"),
        }
    }

    #[test]
    fn responses_carry_serving_version_and_unknown_is_rejected() {
        let coord = pool(30, 2, 8);
        let base = coord.base_version();
        let resp = coord
            .submit(request(0, 1))
            .unwrap()
            .wait_timeout(Duration::from_secs(60))
            .expect("response");
        assert_eq!(resp.weights, base, "default submission must serve the base version");
        // an unregistered version is rejected at submit, payload intact
        let mut req = request(0, 2);
        let bogus = WeightVersion::of(&rng_quant(4096));
        req.weights = Some(bogus);
        let audio_len = req.audio12.len();
        match coord.submit(req) {
            Err(e) => {
                assert!(e.is_unknown_weights(), "expected UnknownWeights: {e}");
                assert!(!e.is_queue_full() && !e.is_closed());
                assert_eq!(e.request().audio12.len(), audio_len);
                assert_eq!(e.into_request().stream, 0);
            }
            Ok(_) => panic!("unknown weight version must be rejected at submit"),
        }
        // a registered version resolves and is echoed back
        let v2 = coord.registry().insert(rng_quant(77), Some(base));
        let mut req = request(0, 3);
        req.weights = Some(v2);
        let resp = coord
            .submit(req)
            .unwrap()
            .wait_timeout(Duration::from_secs(60))
            .expect("response");
        assert_eq!(resp.weights, v2);
        assert_eq!(coord.registry().parent(v2), Some(base));
    }

    #[test]
    fn fused_mixed_versions_match_solo_per_tenant() {
        // ISSUE-9 satellite: the fused lane used to assume one global
        // weight table. A fused group mixing weight versions must now
        // produce, per member, the bit-identical decision of a solo
        // submission on that member's version.
        let coord = pool(31, 2, 8);
        let v2 = coord.registry().insert(rng_quant(78), None);
        let mut reqs: Vec<Request> = (0..6).map(|i| request(i, 50 + i)).collect();
        for (i, r) in reqs.iter_mut().enumerate() {
            // interleave tenants: base, v2, base, v2, …
            r.weights = if i % 2 == 0 { None } else { Some(v2) };
        }
        let solo = coord
            .submit_batch(reqs.clone())
            .expect("pool alive")
            .wait_all(Duration::from_secs(60));
        let fused = coord
            .submit_fused_batch(reqs)
            .expect("pool alive")
            .wait_all(Duration::from_secs(60));
        assert_eq!(solo.len(), 6);
        assert_eq!(fused.len(), 6);
        for (i, (a, b)) in solo.iter().zip(fused.iter()).enumerate() {
            assert_eq!(a.class, b.class, "member {i} diverged");
            assert_eq!(a.logits, b.logits, "member {i} logits diverged");
            assert_eq!(a.counted_frames, b.counted_frames, "member {i}");
            assert_eq!(a.chip_cycles, b.chip_cycles, "member {i}");
            let expect = if i % 2 == 0 { coord.base_version() } else { v2 };
            assert_eq!(a.weights, expect, "solo member {i} served wrong version");
            assert_eq!(b.weights, expect, "fused member {i} served wrong version");
        }
        // still one fused job on one worker
        let workers: std::collections::HashSet<usize> =
            fused.iter().map(|r| r.worker).collect();
        assert_eq!(workers.len(), 1, "fused group must stay on one worker");
        assert_eq!(coord.stats().fused_batches, 1);
    }

    #[test]
    fn stream_swap_keeps_every_frame_and_acknowledges() {
        let coord = pool(32, 1, 8);
        let v2 = coord.registry().insert(rng_quant(79), None);
        let sess = coord.open_stream(0);
        sess.push_blocking(vec![0i64; 1280]).unwrap(); // 10 frames on base
        coord.swap_weights(&sess, v2).expect("swap on a live session");
        sess.push_blocking(vec![0i64; 1280]).unwrap(); // 10 frames on v2
        let events = sess.close();
        let closed = events.iter().find_map(|e| match e {
            StreamEvent::Closed { frames, .. } => Some(*frames),
            _ => None,
        });
        assert_eq!(closed, Some(20), "hot-swap dropped or duplicated frames");
        let swapped = events.iter().find_map(|e| match e {
            StreamEvent::WeightsSwapped { version, frame, .. } => Some((*version, *frame)),
            _ => None,
        });
        assert_eq!(
            swapped,
            Some((v2, 10)),
            "swap must land exactly at the 10-frame fence"
        );
        let s = coord.stats();
        assert_eq!(s.weight_swaps, 1);
        assert!(s.resident_versions >= 2);
        // the session is closed: its pin on v2 was released
        assert_eq!(coord.registry().pins(v2), 0, "closed session leaked a pin");
        // swapping to an unknown version is a typed registry error
        let sess2 = coord.open_stream(0);
        let bogus = WeightVersion::of(&rng_quant(4097));
        match coord.swap_weights(&sess2, bogus) {
            Err(crate::error::Error::Registry(e)) => assert_eq!(e.version(), bogus),
            other => panic!("expected Registry error, got {other:?}"),
        }
        sess2.close();
    }
}
